"""Schedule-tree IR: lossless round-trip, single-source-of-truth emitters,
and the full-corpus differential against the program-order oracle.

The corpus mirrors the golden-schedule gate: every kernel × strategy
combo, the fusion-variant extremes, and the static-autotune winners —
for each, the tree-walking numpy emitter must reproduce the original
program semantics exactly.
"""
import json

import numpy as np
import pytest

from repro.core import config as CFG
from repro.core.cbackend import CCodeGenerator, init_arrays
from repro.core.codegen import CodeGenerator, interpret_scop
from repro.core.postproc import tile_schedule
from repro.core.schedtree import build_tree, schedule_tree, tree_from_json, tree_to_json
from repro.core.scheduler import schedule_scop
from repro.core.scops_npu import make_lu16, make_trsml, make_trsmu
from repro.core.scops_polybench import REGISTRY

# small shapes for every registry kernel (runtime-feasible numpy scans)
SMALL = {
    "gemm": 13, "mm2": 9, "mm3": 8, "atax": 17, "bicg": 12, "mvt": 14,
    "gesummv": 12, "gemver": 11, "symm": 10, "syrk": 10, "syr2k": 9,
    "trmm": 11, "trisolv": 14, "cholesky": 10, "lu": 11,
    "gramschmidt": 9, "covariance": 10, "correlation": 10,
    "doitgen": (4, 5, 6), "jacobi1d": (5, 17), "jacobi2d": (4, 11),
    "heat3d": (3, 8), "fdtd2d": (4, 9), "seidel2d": (3, 10), "durbin": 11,
}
SCALARS = {"alpha": 1.5, "beta": 0.7, "zero": 0.0, "one": 1.0,
           "fn": 10.0, "eps": 0.1}

FUSION_KERNELS = ("fdtd2d", "gemm", "gesummv", "mm2", "mm3", "mvt")
AUTOTUNE_KERNELS = ("gemm", "gesummv", "jacobi1d", "jacobi2d", "mvt", "trmm")


def _makers():
    out = dict(REGISTRY)
    out.update({"npu_trsml": make_trsml, "npu_trsmu": make_trsmu,
                "npu_lu16": make_lu16})
    return out


def _small_scop(name):
    if name.startswith("npu_"):
        return _makers()[name]()
    return REGISTRY[name](SMALL[name])


def _arrays(scop, seed=0):
    return init_arrays(scop, seed)


def _check_equivalence(scop, sched, scan=None, tree=None):
    fn, src = CodeGenerator(sched, scan=scan, tree=tree).build()
    a1, a2 = _arrays(scop), _arrays(scop)
    sc = {k: SCALARS.get(k, 1.0) for k in scop.scalars}
    interpret_scop(scop, a1, sc)
    fn(**a2, **sc, **scop.params)
    for k in a1:
        # NaN == NaN under assert_allclose: a kernel whose oracle goes
        # non-finite (cholesky's old init) would "pass" vacuously
        assert np.isfinite(a1[k]).all(), \
            f"{scop.name} {k}: oracle output is not finite"
        np.testing.assert_allclose(
            a1[k], a2[k], rtol=1e-7, atol=1e-9,
            err_msg=f"{scop.name} {k}\n{src}")


# ---------------------------------------------------------------------------
# lossless JSON round-trip (incl. tiled / wavefronted trees)
# ---------------------------------------------------------------------------

ROUNDTRIP = [("gemm", None, False), ("mvt", None, False),
             ("jacobi1d", 4, True), ("jacobi2d", 4, True),
             ("trmm", 8, False), ("fdtd2d", None, False)]


@pytest.mark.parametrize("name,tile,wf", ROUNDTRIP)
def test_tree_json_roundtrip(name, tile, wf):
    scop = _small_scop(name)
    sched = schedule_scop(scop, CFG.pluto_style())
    scan = tile_schedule(sched, tile, wavefront=wf) if tile else None
    tree = build_tree(sched, scan=scan)
    blob = json.dumps(tree_to_json(tree), sort_keys=True)
    tree2 = tree_from_json(json.loads(blob), scop)
    assert tree_to_json(tree2) == tree_to_json(tree)
    # a deserialized tree drives BOTH emitters to identical output
    assert (CodeGenerator(sched, tree=tree2).generate()
            == CodeGenerator(sched, tree=tree).generate())


@pytest.mark.parametrize("name,tile,wf", [("gemm", None, False),
                                          ("jacobi2d", 4, True)])
def test_c_emitter_from_deserialized_tree(name, tile, wf):
    scop = _small_scop(name)
    sched = schedule_scop(scop, CFG.pluto_style())
    scan = tile_schedule(sched, tile, wavefront=wf) if tile else None
    tree = build_tree(sched, scan=scan, concrete=True)
    tree2 = tree_from_json(tree_to_json(tree), scop)
    src1 = CCodeGenerator(sched, tree=tree, scalars=SCALARS).generate()
    src2 = CCodeGenerator(sched, tree=tree2, scalars=SCALARS).generate()
    assert src1 == src2


def test_tree_marks_vocabulary():
    """Tile and wavefront transformations surface as named marks."""
    scop = _small_scop("jacobi2d")
    sched = schedule_scop(scop, CFG.pluto_style())
    scan = tile_schedule(sched, 4, wavefront=True)
    marks = [m for b in build_tree(sched, scan=scan).bands() for m in b.marks]
    assert "wavefront" in marks
    assert any(m.startswith("tile(") for m in marks)
    assert "parallel" in marks
    # the wavefront-inner tile counter is the parallel one
    tree = build_tree(sched, scan=scan)
    wave_par = [b for b in tree.bands() if b.role == "wave_par"]
    assert wave_par and all(b.parallel for b in wave_par)


def test_vector_mark_on_innermost_parallel_band():
    scop = _small_scop("gemm")
    sched = schedule_scop(scop, CFG.pluto_style())
    tree = schedule_tree(sched)
    vec = [b for b in tree.bands() if b.vector]
    assert vec and all(b.innermost for b in vec)


def test_bounds_context_concrete_vs_parametric():
    """The C backend's concrete-context tree may prune bound chains the
    parametric tree keeps, never the other way around."""
    scop = _small_scop("jacobi2d")
    sched = schedule_scop(scop, CFG.pluto_style())
    scan = tile_schedule(sched, 4, wavefront=True)
    t_par = build_tree(sched, scan=tile_schedule(sched, 4, wavefront=True))
    t_con = build_tree(sched, scan=scan, concrete=True)
    n_par = sum(len(lo) + len(hi) for b in t_par.bands()
                for lo, hi in b.bounds.values())
    n_con = sum(len(lo) + len(hi) for b in t_con.bands()
                for lo, hi in b.bounds.values())
    assert n_con <= n_par


# ---------------------------------------------------------------------------
# no duplicated scheduler-output analysis in the emitters
# ---------------------------------------------------------------------------

def test_emitters_have_no_private_analysis():
    """codegen/cbackend are pure tree walkers: separation, FM bounds and
    parallel marking live only in schedtree."""
    import repro.core.cbackend as cb
    import repro.core.codegen as cg

    for mod in (cg, cb):
        path = mod.__file__
        src = open(path).read()
        for needle in ("fm_eliminate", "bounds_of(", "_scc_groups",
                       "stmt_parallel_at_set", "_full_system(",
                       "find_tilable_bands"):
            assert needle not in src, f"{path} re-derives {needle}"
    # the walk itself never calls back into the Schedule for legality
    assert not hasattr(CodeGenerator, "_separate")
    assert not hasattr(CodeGenerator, "_gen_level")


# ---------------------------------------------------------------------------
# full-corpus differential: numpy emitter ≡ program-order oracle
# ---------------------------------------------------------------------------

ALL_KERNELS = sorted(SMALL) + ["npu_trsml", "npu_trsmu", "npu_lu16"]


@pytest.mark.parametrize("name", ALL_KERNELS)
@pytest.mark.parametrize("style", ["pluto", "tensor"])
def test_corpus_equivalence(name, style):
    scop = _small_scop(name)
    sched = schedule_scop(scop, CFG.STRATEGIES[style]())
    _check_equivalence(scop, sched)


@pytest.mark.parametrize("name", FUSION_KERNELS)
@pytest.mark.parametrize("fmode", ["max", "no"])
def test_fusion_variant_equivalence(name, fmode):
    scop = _small_scop(name)
    cfg = CFG.pluto_style()
    cfg.fusion_mode = fmode
    sched = schedule_scop(scop, cfg)
    _check_equivalence(scop, sched)


@pytest.mark.parametrize("name", AUTOTUNE_KERNELS)
def test_autotune_winner_equivalence(name):
    """The statically-ranked autotune winner (the 74-combo corpus's
    third family) generates numpy code equivalent to the oracle."""
    from repro.core.autotune import autotune
    from repro.core.cachemodel import CacheSpec
    from repro.core.schedcache import ScheduleCache

    scop = _small_scop(name)
    r = autotune(scop, measure=False, use_cache=False,
                 cache=ScheduleCache(disk=False), spec=CacheSpec())
    tc = r.config
    sched = schedule_scop(scop, tc.scheduler_config())
    scan = (tile_schedule(sched, tc.tile, wavefront=tc.wavefront)
            if tc.tile is not None else None)
    _check_equivalence(scop, sched, scan=scan)
