"""Incremental ILP core + per-SCC decomposition + schedule cache.

Covers the PR-1 performance work under the exact default backend: the
float HiGHS cross-check must agree with the exact engine on random
ILPs, per-component decomposition must reproduce the monolithic solve,
seed and incremental pipelines must produce identical schedules, and
repeat scheduling must be a structural-cache lookup.
"""
import random
from fractions import Fraction

import pytest

from repro.core import config as CFG
from repro.core.deps import compute_dependences
from repro.core.ilp import ILPProblem
from repro.core.schedcache import ScheduleCache, cached_schedule_scop, schedule_key
from repro.core.scheduler import PolyTOPSScheduler
from repro.core.scop import Scop
from repro.core.scops_polybench import REGISTRY


def _sig(s):
    """Full structural signature of a Schedule."""
    return (
        {i: [(r.kind, tuple(sorted(r.coeffs.items()))) for r in rr]
         for i, rr in s.rows.items()},
        tuple(s.bands), tuple(s.parallel), s.fallback,
    )


def _schedule(scop, cfg, **kw):
    return PolyTOPSScheduler(scop, cfg, deps=compute_dependences(scop),
                             **kw).schedule()


# ---------------------------------------------------------------------------
# incremental lexmin vs the exact-rational oracle
# ---------------------------------------------------------------------------

def _random_problem(rng, engine):
    p = ILPProblem(engine)
    p.var("x", ub=7)
    p.var("y", ub=7)
    p.var("z", ub=5)
    for _ in range(rng.randint(1, 5)):
        expr = {v: Fraction(rng.randint(-3, 3)) for v in ("x", "y", "z")}
        expr[1] = Fraction(rng.randint(-6, 6))
        p.add(expr, ">=0" if rng.random() < 0.8 else "==0")
    return p


def test_lexmin_engines_agree_randomized():
    """highs (incremental: append-only fixing rows, warm-skip, combined
    tail) and the exact simplex+B&B must give the same lexicographic
    optima on random small ILPs."""
    rng = random.Random(20260730)
    checked = 0
    for case in range(60):
        state = rng.getstate()
        stages = [
            {v: Fraction(rng.randint(-2, 2)) for v in ("x", "y", "z")}
            for _ in range(rng.randint(1, 3))
        ]
        rng.setstate(state)
        ph = _random_problem(rng, "highs")
        rng.setstate(state)
        pe = _random_problem(rng, "exact")
        rng.setstate(state)
        _ = _random_problem(rng, "highs")  # advance rng deterministically
        for _ in range(len(stages)):
            rng.randint(-2, 2), rng.randint(-2, 2), rng.randint(-2, 2)
        sh = ph.lexmin(stages)
        se = pe.lexmin(stages)
        if sh is None or se is None:
            assert sh is None and se is None, f"case {case}: feasibility differs"
            continue
        checked += 1
        # lexicographic optimality: every stage value must agree
        for i, obj in enumerate(stages):
            vh = sum((c * sh[k] for k, c in obj.items() if k != 1),
                     obj.get(1, Fraction(0)))
            ve = sum((c * se[k] for k, c in obj.items() if k != 1),
                     obj.get(1, Fraction(0)))
            assert vh == ve, f"case {case} stage {i}: {vh} != {ve}"
    assert checked >= 10   # a healthy share of feasible cases


def test_lexmin_incremental_matches_cloned():
    """The append-only lexmin must match the seed clone-per-lexmin path
    stage for stage."""
    rng = random.Random(7)
    for case in range(40):
        state = rng.getstate()
        p1 = _random_problem(rng, "highs")
        rng.setstate(state)
        p2 = _random_problem(rng, "highs")
        p2.incremental = False
        stages = [{"x": Fraction(1), "y": Fraction(2)},
                  {"z": Fraction(1), "x": Fraction(-1)},
                  {"y": Fraction(1)}]
        s1 = p1.lexmin(stages)
        s2 = p2.lexmin(stages)
        if s1 is None or s2 is None:
            assert s1 is None and s2 is None
            continue
        for obj in stages:
            v1 = sum((c * s1[k] for k, c in obj.items() if k != 1),
                     obj.get(1, Fraction(0)))
            v2 = sum((c * s2[k] for k, c in obj.items() if k != 1),
                     obj.get(1, Fraction(0)))
            assert v1 == v2


def test_lexmin_rewinds_problem():
    """lexmin must leave the live model exactly as it found it."""
    p = ILPProblem()
    p.var("x", ub=9)
    p.var("y", ub=9)
    p.add({"x": 1, "y": 1, 1: -4})
    ncons, nvars = len(p.cons), len(p.vars)
    p.lexmin([{"x": 1}, {"y": 1}])
    assert len(p.cons) == ncons and len(p.vars) == nvars
    # and the model still solves the same afterwards
    v, _ = p.solve_min({"x": 1, "y": 1})
    assert v == 4


def test_push_pop_restores_compiled_state():
    p = ILPProblem()
    p.var("a", ub=3)
    p.add({"a": 1, 1: -1})
    assert p.solve_min({"a": 1})[0] == 1
    mark = p.push()
    p.var("b", ub=3)
    p.add({"b": 1, "a": 1, 1: -4})
    assert p.solve_min({"a": 1})[0] == 1
    p.pop(mark)
    assert "b" not in p.vars
    assert p.solve_min({"a": 1})[0] == 1


# ---------------------------------------------------------------------------
# per-SCC decomposition vs monolithic
# ---------------------------------------------------------------------------

DECOMP_KERNELS = ["gemm", "mm2", "atax", "trisolv", "covariance", "fdtd2d"]
DECOMP_STYLES = ["pluto", "tensor", "isl", "feautrier"]


@pytest.mark.parametrize("name", DECOMP_KERNELS)
@pytest.mark.parametrize("style", DECOMP_STYLES)
def test_decomposition_matches_monolithic(name, style):
    """Solving one ILP per dependence-graph component (with the
    proximity u/w coupling guard) must reproduce the monolithic
    schedule exactly."""
    scop = REGISTRY[name]()
    cfg = CFG.STRATEGIES[style]
    mono = _schedule(scop, cfg(), decompose=False)
    deco = _schedule(REGISTRY[name](), cfg(), decompose=True)
    assert _sig(mono) == _sig(deco)


def test_decomposition_no_deps_components():
    """Statements with no dependences at all decompose into singleton
    ILPs and still get the paper's Listing-1 interchange."""
    k = Scop("listing1", params={})
    with k.loop("i", 0, 100):
        with k.loop("j", 0, 10):
            k.stmt("c[j,i] = a[j,i] * b")
            k.stmt("d[i,j] = e[i,j] * x")
    sched = _schedule(k, CFG.tensor_style(), decompose=True)
    s0 = sched.it_matrix(sched.scop.statements[0])
    s1 = sched.it_matrix(sched.scop.statements[1])
    assert s0[0] == [0, 1] and s0[1] == [1, 0]
    assert s1[0] == [1, 0] and s1[1] == [0, 1]


@pytest.mark.parametrize("name", ["gemm", "mm2", "jacobi1d"])
def test_incremental_legality_vs_seed(name):
    """The incremental path must be *identical* to the seed pipeline
    under the exact engine: every dependence strongly satisfied and the
    full schedule signature equal (no fallback asymmetry — the float-era
    mis-report recovery paths are gone)."""
    for style in ("pluto", "tensor"):
        seed = _schedule(REGISTRY[name](), CFG.STRATEGIES[style](),
                         incremental=False)
        fast = _schedule(REGISTRY[name](), CFG.STRATEGIES[style]())
        assert all(d.satisfied_at is not None for d in fast.deps)
        assert seed.fallback == fast.fallback
        assert _sig(seed) == _sig(fast)


def test_gramschmidt_seed_equals_incremental():
    """gramschmidt/pluto was the poster child of the HiGHS-era
    divergence (the seed path fell back to original order while the
    incremental path scheduled it).  Under the exact backend both paths
    must produce the same real (non-fallback) schedule with every
    dependence satisfied — no special-casing left anywhere."""
    seed = _schedule(REGISTRY["gramschmidt"](), CFG.pluto_style(),
                     incremental=False)
    assert not seed.fallback
    assert all(d.satisfied_at is not None for d in seed.deps)
    fast = _schedule(REGISTRY["gramschmidt"](), CFG.pluto_style())
    assert not fast.fallback
    assert _sig(seed) == _sig(fast)


def test_lexmin_canonical_under_row_reordering():
    """The exact lexmin's canonical tie-break must make the returned
    point independent of constraint order — the property that makes
    seed ≡ incremental equality structural rather than accidental."""
    rows = [
        ({"x": 1, "y": 1, 1: -4}, ">=0"),     # x + y >= 4
        ({"x": 1, "y": -1, 1: 6}, ">=0"),     # x - y >= -6 (slack)
        ({"x": 2, "y": 1, 1: -5}, ">=0"),     # redundant-ish extra row
    ]
    sols = []
    for order in (rows, rows[::-1], [rows[1], rows[2], rows[0]]):
        p = ILPProblem()
        p.var("x", ub=5)
        p.var("y", ub=5)
        for e, k in order:
            p.add(dict(e), k)
        sols.append(p.lexmin([{"x": Fraction(1), "y": Fraction(1)}]))
    assert sols[0] == sols[1] == sols[2]
    assert sols[0]["x"] + sols[0]["y"] == 4


# ---------------------------------------------------------------------------
# schedule cache
# ---------------------------------------------------------------------------

def test_schedule_key_stability_and_sensitivity():
    k1 = schedule_key(REGISTRY["gemm"](), CFG.pluto_style(), "highs")
    k2 = schedule_key(REGISTRY["gemm"](), CFG.pluto_style(), "highs")
    assert k1 == k2
    assert k1 != schedule_key(REGISTRY["gemm"](), CFG.tensor_style(), "highs")
    assert k1 != schedule_key(REGISTRY["mm2"](), CFG.pluto_style(), "highs")
    assert k1 != schedule_key(REGISTRY["gemm"](), CFG.pluto_style(), "exact")
    cfg = CFG.pluto_style()
    cfg.coeff_bound = 7
    assert k1 != schedule_key(REGISTRY["gemm"](), cfg, "highs")
    # dynamic strategies are uncacheable
    assert schedule_key(REGISTRY["gemm"](), CFG.isl_style(), "highs") is None


def test_schedule_cache_memory_and_disk(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    s1 = cached_schedule_scop(REGISTRY["atax"](), CFG.pluto_style(), cache=cache)
    s2 = cached_schedule_scop(REGISTRY["atax"](), CFG.pluto_style(), cache=cache)
    assert s1 is s2                       # in-memory hit
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1
    # a fresh cache over the same directory hits via disk pickle
    cache2 = ScheduleCache(cache_dir=str(tmp_path))
    s3 = cached_schedule_scop(REGISTRY["atax"](), CFG.pluto_style(), cache=cache2)
    assert cache2.stats["disk_hits"] == 1
    assert _sig(s3) == _sig(s1)
    assert all(d._compiled is None for d in s3.deps)  # lean pickles


def test_schedule_cache_uncacheable_strategy(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    s1 = cached_schedule_scop(REGISTRY["atax"](), CFG.isl_style(), cache=cache)
    s2 = cached_schedule_scop(REGISTRY["atax"](), CFG.isl_style(), cache=cache)
    assert s1 is not s2                   # bypasses the cache entirely
    assert cache.stats["hits"] == 0
    assert _sig(s1) == _sig(s2)
