"""Serving-engine tests: slot-reuse hygiene, admission ordering,
ragged-prefill interleave determinism, and Pallas-vs-jnp parity.

The engines sample greedily, so every property here is asserted as
bit-identical token sequences — not allclose.  The reference for a
request is always the same request run in isolation (batch-1 prefill +
decode loop): continuous batching, chunked prefill, paged KV, and the
Pallas kernels must not change a single argmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_arch
from repro.launch.serve import (ContinuousEngine, Request, ServeEngine,
                                _merge_slot)
from repro.model import pallas_mode
from repro.model import transformer as T

CFG = get_arch("granite_3_2b").smoke()


@functools.lru_cache(maxsize=1)
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def prompt(seed: int, plen: int):
    return jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(7),
                                                 seed),
                              (1, plen), 2, CFG.vocab)


def solo_greedy(pr, gen: int, max_len: int):
    """Reference: the request alone in a batch-1 alternating engine."""
    eng = ServeEngine(CFG, params(), 1, max_len)
    req = Request(0, pr)
    eng.admit(req, slot=0)
    for _ in range(gen - 1):
        eng.step()
    return req.generated


def run_continuous(prompts, gen, max_len, batch, **kw):
    eng = ContinuousEngine(CFG, params(), batch, max_len, max_new=gen, **kw)
    reqs = [Request(i, p) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, reqs


# ---------------------------------------------------------------------------
# ServeEngine slot reuse (the admit cache-merge regression)
# ---------------------------------------------------------------------------

def test_admit_slot_reuse_zeroes_stale_rows():
    """Two sequential requests through one slot: the second must see a
    slot wiped of the first occupant's KV rows.  The old shape-heuristic
    merge (`bdim is None` silent skip) left request A's decode rows in
    the gap between B's prompt and the shared max(lengths) mask, which
    B then attended."""
    plen, j, k, max_len = 8, 4, 4, 32
    eng = ServeEngine(CFG, params(), 2, max_len)
    a, long_req = Request(0, prompt(1, plen)), Request(1, prompt(2, plen))
    eng.admit(a, slot=0)
    eng.admit(long_req, slot=1)
    for _ in range(j):
        eng.step()           # A's decode writes rows [plen, plen+j)
    a.done = True
    b = Request(2, prompt(3, plen))
    eng.admit(b, slot=0)     # reuse: must zero slot 0 first

    # structural check: every slot-0 cache row past B's prompt is zero,
    # while slot 1 still holds its occupant's rows there
    for entry in eng.cache["slots"]:
        kc = entry["k"]      # (repeats, batch, S, hkv, hd)
        assert not jnp.any(kc[:, 0, plen:])
        assert jnp.any(kc[:, 1, plen:plen + j])

    for _ in range(k):
        eng.step()

    # bit-identical reference: B prefilled into a fresh slot, decoding
    # behind the same shared mask trajectory (slot 1 is j tokens ahead,
    # so B attends j zero rows it never wrote — same as in the engine)
    logits, pre = jax.jit(lambda p, t: T.prefill(p, CFG, t))(params(),
                                                             b.prompt)
    cache = _merge_slot(T.init_cache(CFG, 1, max_len), pre, 0)
    toks = [int(jnp.argmax(logits[0]))]
    step = jax.jit(lambda p, t, c, n: T.decode_step(p, CFG, t, c, n))
    for t in range(k):
        lg, cache = step(params(), jnp.asarray([[toks[-1]]], jnp.int32),
                         cache, jnp.int32(plen + j + t))
        toks.append(int(jnp.argmax(lg[0])))
    assert b.generated == toks


# ---------------------------------------------------------------------------
# continuous engine: ordering, determinism, parity
# ---------------------------------------------------------------------------

def test_admission_ordering_and_slot_recycling():
    """FIFO admission through fewer slots than requests: every request
    completes with its full budget, and identical prompts produce
    identical tokens whether served in the first wave or after a slot
    was recycled."""
    gen, max_len = 6, 32
    prompts = [prompt(1, 8), prompt(2, 8), prompt(3, 8),
               prompt(1, 8), prompt(2, 8)]
    eng, reqs = run_continuous(prompts, gen, max_len, batch=2, chunk=8)
    assert all(r.done for r in reqs)
    assert [len(r.generated) for r in reqs] == [gen] * 5
    assert eng.state == [0, 0] and not eng.queue
    # same prompt, one served through a recycled slot: same tokens
    assert reqs[0].generated == reqs[3].generated
    assert reqs[1].generated == reqs[4].generated
    assert reqs[0].generated != reqs[1].generated


def test_ragged_prefill_interleave_determinism():
    """Ragged prompt lengths under chunked prefill: each request's
    tokens are bit-identical to the request run alone — the interleave
    (whose chunk lands on which tick, which slots decode beside it)
    must be invisible — and a reset re-run reproduces them exactly."""
    gen, max_len, chunk = 6, 48, 8
    plens = [7, 19, 13]
    prompts = [prompt(i + 10, pl) for i, pl in enumerate(plens)]
    eng, reqs = run_continuous(prompts, gen, max_len, batch=2, chunk=chunk)
    for r, pl in zip(reqs, plens):
        assert r.generated == solo_greedy(r.prompt, gen, max_len), \
            f"request with plen={pl} diverged under interleaving"
    first = [r.generated for r in reqs]
    eng.reset()
    reqs2 = [Request(i, p) for i, p in enumerate(prompts)]
    for r in reqs2:
        eng.submit(r)
    eng.run()
    assert [r.generated for r in reqs2] == first


def test_pallas_parity_bit_identical():
    """The Pallas fast path (flash attention on prefill chunks, planned
    matmul in the MLP) generates bit-identical greedy tokens to the jnp
    path on the smoke config.  Thresholds are lowered so the tiny test
    shapes actually route through the kernels."""
    gen, max_len, chunk = 5, 48, 16
    prompts = [prompt(21, 32), prompt(22, 32)]
    _, jnp_reqs = run_continuous(prompts, gen, max_len, batch=2,
                                 chunk=chunk)
    _, pl_reqs = run_continuous(
        prompts, gen, max_len, batch=2, chunk=chunk, use_pallas=True,
        pallas_opts=dict(min_attn_q=16, min_matmul_rows=16))
    pallas_mode.configure(enabled=False)
    assert [r.generated for r in pl_reqs] == \
        [r.generated for r in jnp_reqs]


def test_continuous_matches_alternating():
    """Equal-length batch: the continuous engine and the alternating
    baseline agree token for token (the bench's identity gate)."""
    gen, max_len, plen, batch = 6, 48, 16, 3
    prompts = [prompt(30 + i, plen) for i in range(batch)]
    base = ServeEngine(CFG, params(), batch, max_len)
    base_reqs = [Request(i, p) for i, p in enumerate(prompts)]
    for i, r in enumerate(base_reqs):
        base.admit(r, slot=i)
    for _ in range(gen - 1):
        base.step()
    _, cont_reqs = run_continuous(prompts, gen, max_len, batch=batch,
                                  chunk=8)
    assert [r.generated for r in cont_reqs] == \
        [r.generated for r in base_reqs]


def test_submit_validation():
    eng = ContinuousEngine(CFG, params(), 1, 16, max_new=4)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(Request(0, prompt(1, 16)))
    with pytest.raises(ValueError, match="exceeds token buffer"):
        eng.submit(Request(1, prompt(1, 4), max_new=12))


def test_mamba_chunked_prefill_state_carry():
    """Chunked prefill of a Mamba arch matches whole-prompt prefill:
    the conv tail + hidden-state carry across chunks is exact on the
    jnp path (bit-identical logits); the fused scan+gate kernel
    accumulates y = h·C in a different f32 order, so it is held to a
    bf16-ULP tolerance instead (its f32 exactness is pinned by
    ``kernels/bench.py --smoke``)."""
    cfg = get_arch("falcon_mamba_7b").smoke()
    p = T.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 2, cfg.vocab)
    logits_full, _ = jax.jit(lambda pp, t: T.prefill(pp, cfg, t))(p, toks)

    def chunked(enabled):
        with pallas_mode.pallas_mode(enabled=enabled, min_scan_seq=8,
                                     min_attn_q=8):
            cache = T.init_cache(cfg, 1, 32)
            step = jax.jit(
                lambda pp, t, c, off: T.chunk_step(pp, cfg, t, c, off, 32),
                static_argnames=())
            _, cache = step(p, toks[:, :8], cache, jnp.int32(0))
            lg, _ = step(p, toks[:, 8:], cache, jnp.int32(8))
        return lg[:, -1]

    assert jnp.array_equal(chunked(False), logits_full)
    fused = chunked(True).astype(jnp.float32)
    assert jnp.allclose(fused, logits_full.astype(jnp.float32),
                        rtol=0.02, atol=0.02)
