"""Codegen equivalence: transformed code ≡ original semantics.

Includes the flagship property test: random SCoPs × random strategies →
schedule → generate → execute → allclose against the independent
interpreter oracle.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import config as CFG
from repro.core.cbackend import init_arrays
from repro.core.codegen import CodeGenerator, interpret_scop
from repro.core.postproc import tile_schedule
from repro.core.scheduler import schedule_scop
from repro.core.scop import Scop
from repro.core.scops_polybench import REGISTRY

SMALL = {"gemm": 13, "mm2": 9, "atax": 17, "symm": 10, "trmm": 11,
         "trisolv": 14, "lu": 11, "durbin": 11, "gesummv": 12,
         "jacobi1d": (5, 17), "jacobi2d": (4, 11), "fdtd2d": (4, 9),
         "seidel2d": (3, 10), "doitgen": (4, 5, 6)}
SCALARS = {"alpha": 1.5, "beta": 0.7, "zero": 0.0, "one": 1.0,
           "fn": 10.0, "eps": 0.1}


def _arrays(scop, seed=0):
    return init_arrays(scop, seed)


def _check(scop, cfg, tile=None, wavefront=False):
    sched = schedule_scop(scop, cfg)
    scan = tile_schedule(sched, tile, wavefront=wavefront) if tile else None
    fn, src = CodeGenerator(sched, scan=scan).build()
    a1, a2 = _arrays(scop), _arrays(scop)
    sc = {k: v for k, v in SCALARS.items() if k in scop.scalars}
    interpret_scop(scop, a1, sc)
    fn(**a2, **sc, **scop.params)
    for k in a1:
        np.testing.assert_allclose(a1[k], a2[k], rtol=1e-7, atol=1e-9,
                                   err_msg=f"{scop.name} {cfg.name} {k}\n{src}")


@pytest.mark.parametrize("name", list(SMALL))
@pytest.mark.parametrize("style", ["pluto", "tensor", "isl"])
def test_polybench_equivalence(name, style):
    scop = REGISTRY[name](SMALL[name])
    _check(scop, CFG.STRATEGIES[style]())


@pytest.mark.parametrize("name,tile,wf", [
    ("gemm", 8, False), ("jacobi1d", 4, False), ("jacobi1d", 4, True),
    ("jacobi2d", 4, True), ("trmm", 8, False)])
def test_tiled_equivalence(name, tile, wf):
    scop = REGISTRY[name](SMALL[name])
    _check(scop, CFG.pluto_style(), tile=tile, wavefront=wf)


# ---------------------------------------------------------------------------
# property test: random SCoPs stay semantically equivalent
# ---------------------------------------------------------------------------

_subscript = st.sampled_from(["i", "i-1", "i+1", "j", "j-1", "j+1"])


@st.composite
def random_scop(draw):
    n1 = draw(st.integers(4, 9))
    n2 = draw(st.integers(4, 9))
    k = Scop("rand", params={"N": n1, "M": n2})
    n_stmts = draw(st.integers(1, 3))
    with k.loop("i", 1, "N-1"):
        with k.loop("j", 1, "M-1"):
            for s in range(n_stmts):
                arr_w = draw(st.sampled_from(["A", "B"]))
                arr_r1 = draw(st.sampled_from(["A", "B", "C"]))
                arr_r2 = draw(st.sampled_from(["A", "B", "C"]))
                w1, w2 = draw(_subscript), draw(_subscript)
                r1, r2 = draw(_subscript), draw(_subscript)
                r3, r4 = draw(_subscript), draw(_subscript)
                k.stmt(f"{arr_w}[{w1},{w2}] = 0.5*{arr_r1}[{r1},{r2}]"
                       f" + 0.25*{arr_r2}[{r3},{r4}]")
    return k


@settings(max_examples=20, deadline=None)
@given(scop=random_scop(), style=st.sampled_from(["pluto", "tensor", "isl",
                                                  "feautrier"]))
def test_random_scop_equivalence(scop, style):
    _check(scop, CFG.STRATEGIES[style]())


@settings(max_examples=10, deadline=None)
@given(scop=random_scop(), tile=st.sampled_from([2, 4]))
def test_random_scop_tiled_equivalence(scop, tile):
    _check(scop, CFG.pluto_style(), tile=tile)
