"""schedd daemon + schedclient: protocol, coalescing, shedding,
deadlines, breaker, journal, fallback.

The daemon here runs *in-process* (threads on a temp Unix socket) —
fast, and the REGISTRY/caches are visible to assertions.  The real
subprocess + kill -9 scenarios live in scripts/chaos_sweep.py.
"""
import os
import socket as socketlib
import struct
import threading
import time
from contextlib import contextmanager

import pytest

from repro.core import schedclient as wire
from repro.core.resilience import Deadline
from repro.core.schedclient import (CircuitBreaker, DaemonUnavailable,
                                    Overloaded, ProtocolError, SchedClient,
                                    VersionSkew, local_only, wire_versions)
from repro.core.schedcache import schedule_fingerprint
from repro.core.scop import Scop
from repro.launch.schedd import AutotuneJournal, SchedDaemon


def tiny_scop(name="schedd_t", n=24):
    s = Scop(name, params={"N": n})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("A[i,j] = A[i,j] + B[j,i]")
    return s


def other_scop():
    """Structurally distinct from tiny_scop: the cache key fingerprints
    structure, not the scop's name."""
    s = Scop("schedd_other", params={"M": 16})
    with s.loop("i", 0, "M"):
        s.stmt("X[i] = X[i] * 2.0")
    return s


@contextmanager
def daemon(tmp_path, **kwargs):
    sock = str(tmp_path / "schedd.sock")
    kwargs.setdefault("cache_dir", str(tmp_path / "pool"))
    kwargs.setdefault("chaos", True)
    d = SchedDaemon(sock, **kwargs)
    d.start()
    try:
        yield d, sock
    finally:
        d.stop()


# ---------------------------------------------------------------------------
# protocol + roundtrips
# ---------------------------------------------------------------------------


def test_schedule_roundtrip_and_frame_cache(tmp_path):
    with daemon(tmp_path) as (d, sock):
        c = SchedClient(sock, retries=0)
        scop = tiny_scop()
        s1 = c.schedule(scop)
        assert not s1.degraded
        s2 = c.schedule(tiny_scop())
        assert schedule_fingerprint(s1) == schedule_fingerprint(s2)
        assert d.counters["computed"] == 1
        assert d.counters["frame_hits"] == 1
        assert c.stats.remote_ok == 2 and c.stats.fallbacks == 0


def test_plan_roundtrip_matches_local(tmp_path):
    with daemon(tmp_path) as (_, sock):
        c = SchedClient(sock, retries=0)
        remote = c.plan("matmul", 48, 48, 48, "tensor")
        with local_only():
            from repro.core import akg
            akg.plan_matmul.cache_clear()
            local = akg.plan_matmul(48, 48, 48)
        assert remote == local
        assert c.stats.fallbacks == 0


def test_autotune_roundtrip(tmp_path):
    with daemon(tmp_path) as (d, sock):
        c = SchedClient(sock, retries=0)
        r1 = c.autotune(tiny_scop("schedd_at"), measure=False)
        assert r1.config.label
        r2 = c.autotune(tiny_scop("schedd_at"), measure=False)
        assert r2.config.label == r1.config.label
        assert d.counters["computed"] == 1      # second was a frame hit


def test_ping_stats_shutdown(tmp_path):
    with daemon(tmp_path) as (d, sock):
        c = SchedClient(sock, retries=0)
        assert c.ping()["op"] == "pong"
        st = c.daemon_stats()
        assert st["counters"]["requests"] >= 1
        assert st["versions"] == wire_versions()
        c.shutdown()
        assert d._stop.wait(timeout=5.0)


def test_unknown_op_is_typed(tmp_path):
    with daemon(tmp_path) as (_, sock):
        c = SchedClient(sock, retries=0)
        with pytest.raises(ProtocolError, match="unknown op"):
            c._request({"op": "frobnicate"}, 5.0)


def test_garbage_and_truncated_frames_are_survivable(tmp_path):
    with daemon(tmp_path) as (d, sock):
        # garbage magic -> typed bad_frame reply (or clean close)
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(b"NOPE" + b"\x00" * 64)
        reply = s.recv(1 << 16)
        s.close()
        assert not reply or b"bad_frame" in reply
        # truncated frame -> dropped connection, daemon survives
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC + struct.pack(">I", 1024) + b"short")
        s.close()
        time.sleep(0.1)
        assert SchedClient(sock, retries=0).ping()["op"] == "pong"
        assert d.counters["bad_frames"] >= 1


def test_oversized_length_rejected(tmp_path):
    with daemon(tmp_path) as (_, sock):
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC + struct.pack(">I", 0xFFFFFFF0))
        reply = s.recv(1 << 16)
        s.close()
        assert not reply or b"bad_frame" in reply


def test_slow_loris_dropped(tmp_path):
    with daemon(tmp_path, conn_timeout=0.3) as (d, sock):
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC[:2])        # stall mid-header
        assert s.recv(1) == b""          # daemon hangs up
        s.close()
        assert d.counters["slow_loris"] >= 1
        assert SchedClient(sock, retries=0).ping()["op"] == "pong"


# ---------------------------------------------------------------------------
# coalescing + shedding
# ---------------------------------------------------------------------------


def test_identical_concurrent_requests_coalesce(tmp_path):
    with daemon(tmp_path) as (d, sock):
        scop = tiny_scop("schedd_co")
        metas = []

        def go():
            c = SchedClient(sock, retries=0, request_timeout=30.0)
            resp = c._request({"op": "schedule", "scop": scop,
                               "test_delay_s": 0.4}, 30.0)
            metas.append(resp["meta"])

        threads = [threading.Thread(target=go) for _ in range(3)]
        for t in threads:
            t.start()
            time.sleep(0.05)
        for t in threads:
            t.join(timeout=30.0)
        assert len(metas) == 3
        assert d.counters["computed"] == 1
        assert d.counters["coalesced"] == 2


def test_overload_sheds_typed(tmp_path):
    with daemon(tmp_path, max_inflight=1) as (d, sock):
        done = threading.Event()

        def hold():
            c = SchedClient(sock, retries=0, request_timeout=30.0)
            c._request({"op": "schedule", "scop": tiny_scop("schedd_h"),
                        "test_delay_s": 1.0}, 30.0)
            done.set()

        t = threading.Thread(target=hold)
        t.start()
        time.sleep(0.3)
        c = SchedClient(sock, retries=0)
        with pytest.raises(Overloaded):
            c._request({"op": "schedule", "scop": other_scop()}, 10.0)
        # the total API serves in-process while the daemon is saturated
        sched = c.schedule(other_scop())
        assert sched is not None
        assert c.stats.fallbacks == 1 and c.stats.overloaded >= 1
        assert done.wait(timeout=30.0)
        t.join(timeout=5.0)
        assert d.counters["shed"] >= 1


# ---------------------------------------------------------------------------
# deadlines + degraded results
# ---------------------------------------------------------------------------


def test_expired_deadline_degrades_and_is_never_frame_cached(tmp_path):
    with daemon(tmp_path) as (d, sock):
        c = SchedClient(sock, retries=0)
        scop = tiny_scop("schedd_dl")
        r1 = c._request({"op": "schedule", "scop": scop,
                         "deadline_s": 0.0}, 10.0)
        assert r1["meta"]["degraded"]
        r2 = c._request({"op": "schedule", "scop": scop,
                         "deadline_s": 0.0}, 10.0)
        assert r2["meta"]["degraded"]
        # both computed: a degraded response must never be served warm
        assert d.counters["computed"] == 2
        assert d.counters["frame_hits"] == 0
        assert d.counters["degraded"] == 2


def test_client_exhausted_deadline_falls_back_without_dialing(tmp_path):
    from repro.core.schedcache import ScheduleCache

    with daemon(tmp_path) as (d, sock):
        # isolated fallback cache: the key is structural, so a warm hit
        # from the process-global pool would serve a clean schedule and
        # mask the deadline degradation this test asserts
        c = SchedClient(sock, retries=0,
                        cache=ScheduleCache(cache_dir=str(tmp_path / "fb")))
        dl = Deadline(0.0)
        time.sleep(0.01)
        sched = c.schedule(tiny_scop("schedd_dl2"), deadline=dl)
        assert sched.degraded              # local ladder, identity rung
        assert c.stats.fallbacks == 1
        assert d.counters["requests"] == 0  # never reached the daemon


# ---------------------------------------------------------------------------
# version handshake + breaker + fallback
# ---------------------------------------------------------------------------


def test_version_skew_rejected_and_breaker_opens(tmp_path):
    with daemon(tmp_path) as (d, sock):
        stale = dict(wire_versions(), cache=-99)
        c = SchedClient(sock, retries=2, versions=stale)
        with pytest.raises(VersionSkew):
            c.remote_plan("matmul", 32, 32, 32, "tensor")
        assert c.stats.retries == 0        # skew is not transient
        assert c.breaker.state != "closed"
        sched = c.schedule(tiny_scop("schedd_vs"))
        assert sched is not None
        assert c.stats.fallbacks == 1
        assert c.stats.breaker_skips == 1  # went straight to fallback
        assert d.counters["version_skew"] >= 1


def test_missing_socket_falls_back_and_breaker_trips(tmp_path):
    c = SchedClient(str(tmp_path / "nope.sock"), retries=1,
                    connect_timeout=0.2, breaker_threshold=2)
    with pytest.raises(DaemonUnavailable):
        c.remote_plan("matmul", 32, 32, 32, "tensor")
    sched = c.schedule(tiny_scop("schedd_ms"))
    assert sched is not None and not sched.degraded
    assert c.stats.fallbacks == 1
    assert c.breaker.state == "open"
    before = c.stats.remote_errors
    c.schedule(tiny_scop("schedd_ms"))
    assert c.stats.breaker_skips >= 1
    assert c.stats.remote_errors == before   # open breaker: no dialing


def test_breaker_half_open_recovers():
    t = [0.0]
    b = CircuitBreaker(threshold=2, reset_s=5.0, clock=lambda: t[0])
    assert b.state == "closed"
    b.failure()
    assert b.allow()
    b.failure()
    assert b.state == "open" and not b.allow()
    t[0] = 6.0
    assert b.allow()                   # the single half-open probe
    assert b.state == "half-open" and not b.allow()
    b.success()
    assert b.state == "closed" and b.allow()
    # a failing probe re-opens for another window
    b.failure()
    b.failure()
    t[0] = 12.0
    assert b.allow()
    b.failure()
    assert b.state == "open" and not b.allow()


def test_maybe_client_respects_env_and_server_guard(tmp_path, monkeypatch):
    monkeypatch.delenv(wire.SOCKET_ENV, raising=False)
    assert wire.maybe_client() is None
    monkeypatch.setenv(wire.SOCKET_ENV, str(tmp_path / "x.sock"))
    wire._DEFAULT = None
    assert wire.maybe_client() is not None
    monkeypatch.setattr(wire, "_SERVER_PROCESS", True)
    assert wire.maybe_client() is None
    monkeypatch.setattr(wire, "_SERVER_PROCESS", False)
    with local_only():
        assert wire.maybe_remote_plan("matmul", 8, 8, 8, "tensor") is None
    wire._DEFAULT = None


def test_akg_routes_through_daemon(tmp_path, monkeypatch):
    from repro.core import akg

    with daemon(tmp_path) as (d, sock):
        monkeypatch.setenv(wire.SOCKET_ENV, sock)
        wire._DEFAULT = None
        akg.plan_matmul.cache_clear()
        try:
            plan = akg.plan_matmul(40, 40, 40)
            assert not plan.degraded
            assert d.counters["requests"] >= 1
            assert d.counters["computed"] == 1
        finally:
            akg.plan_matmul.cache_clear()
            wire._DEFAULT = None


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------


def test_journal_recover_counts_orphans(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = AutotuneJournal(path)
    j.begin("aaa")
    j.done("aaa")
    j.begin("bbb")                      # orphan: a crash mid-request
    j.begin("ccc")
    with open(path, "a") as f:
        f.write('{"ev": "beg')          # torn tail from a kill -9
    assert AutotuneJournal(path).recover() == ["bbb", "ccc"]
    # recovery truncates: a second recover sees a clean journal
    assert AutotuneJournal(path).recover() == []


def test_daemon_surfaces_recovered_journal(tmp_path):
    pool = tmp_path / "pool"
    pool.mkdir()
    j = AutotuneJournal(str(pool / "schedd_journal.jsonl"))
    j.begin("orphaned-by-kill9")
    with daemon(tmp_path, cache_dir=str(pool)) as (d, sock):
        st = SchedClient(sock, retries=0).daemon_stats()
        assert st["journal_recovered"] == 1
        assert st["journal_recovered_keys"] == ["orphaned-by-kill9"]
