"""schedd daemon + schedclient: protocol, coalescing, shedding,
deadlines, breaker, journal, worker pool, fallback.

The daemon here runs *in-process* (threads on a temp Unix socket) —
fast, and the REGISTRY/caches are visible to assertions.  The real
subprocess + kill -9 scenarios live in scripts/chaos_sweep.py.

Deflake rules for this file (2-core CI, xdist):

* sockets live in a short per-test ``tempfile.mkdtemp`` under /tmp —
  pytest's ``tmp_path`` can exceed the ~108-byte AF_UNIX path limit
  under xdist worker nesting;
* no fixed ``time.sleep`` to "let the daemon catch up" — every
  ordering assumption waits on an observable daemon counter via
  :func:`wait_until` (monotonic clock, generous cap);
* tests exercising the keyed-computation path run at both worker
  levels (``workers=0`` inline and ``workers=2`` pool) via
  ``WORKER_LEVELS`` so the two dispatch paths can never drift apart.
"""
import os
import shutil
import socket as socketlib
import struct
import tempfile
import threading
import time
from contextlib import contextmanager

import pytest

from repro.core import schedclient as wire
from repro.core.resilience import Deadline
from repro.core.schedclient import (CircuitBreaker, DaemonUnavailable,
                                    Overloaded, ProtocolError, SchedClient,
                                    VersionSkew, WorkerCrashed, local_only,
                                    wire_versions)
from repro.core.schedcache import schedule_fingerprint
from repro.core.scop import Scop
from repro.launch.schedd import AutotuneJournal, SchedDaemon

#: worker levels every keyed-path test runs at: inline and pooled
WORKER_LEVELS = [0, 2]


def wait_until(pred, timeout=15.0, interval=0.01, msg="condition"):
    """Poll ``pred`` on the monotonic clock — the only sanctioned way
    to wait for daemon-side state in this file."""
    end = time.monotonic() + timeout
    while time.monotonic() < end:
        if pred():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {msg}")


def tiny_scop(name="schedd_t", n=24):
    s = Scop(name, params={"N": n})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("A[i,j] = A[i,j] + B[j,i]")
    return s


def other_scop():
    """Structurally distinct from tiny_scop: the cache key fingerprints
    structure, not the scop's name."""
    s = Scop("schedd_other", params={"M": 16})
    with s.loop("i", 0, "M"):
        s.stmt("X[i] = X[i] * 2.0")
    return s


@contextmanager
def daemon(tmp_path, **kwargs):
    # short unique socket dir: AF_UNIX paths cap at ~108 bytes and
    # xdist-nested tmp_path can blow past that
    sdir = tempfile.mkdtemp(prefix="sd-", dir="/tmp")
    sock = os.path.join(sdir, "s.sock")
    kwargs.setdefault("cache_dir", str(tmp_path / "pool"))
    kwargs.setdefault("chaos", True)
    d = SchedDaemon(sock, **kwargs)
    d.start()
    try:
        yield d, sock
    finally:
        d.stop()
        shutil.rmtree(sdir, ignore_errors=True)


# ---------------------------------------------------------------------------
# protocol + roundtrips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_schedule_roundtrip_and_frame_cache(tmp_path, workers):
    with daemon(tmp_path, workers=workers) as (d, sock):
        c = SchedClient(sock, retries=0)
        scop = tiny_scop()
        s1 = c.schedule(scop)
        assert not s1.degraded
        s2 = c.schedule(tiny_scop())
        assert schedule_fingerprint(s1) == schedule_fingerprint(s2)
        assert d.counters["computed"] == 1
        assert d.counters["frame_hits"] == 1
        assert c.stats.remote_ok == 2 and c.stats.fallbacks == 0
        if workers:
            assert d.counters["pool_jobs"] == 1


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_plan_roundtrip_matches_local(tmp_path, workers):
    with daemon(tmp_path, workers=workers) as (_, sock):
        c = SchedClient(sock, retries=0)
        remote = c.plan("matmul", 48, 48, 48, "tensor")
        with local_only():
            from repro.core import akg
            akg.plan_matmul.cache_clear()
            local = akg.plan_matmul(48, 48, 48)
        assert remote == local
        assert c.stats.fallbacks == 0


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_autotune_roundtrip(tmp_path, workers):
    with daemon(tmp_path, workers=workers) as (d, sock):
        c = SchedClient(sock, retries=0)
        r1 = c.autotune(tiny_scop("schedd_at"), measure=False)
        assert r1.config.label
        r2 = c.autotune(tiny_scop("schedd_at"), measure=False)
        assert r2.config.label == r1.config.label
        assert d.counters["computed"] == 1      # second was a frame hit


def test_ping_stats_shutdown(tmp_path):
    with daemon(tmp_path) as (d, sock):
        c = SchedClient(sock, retries=0)
        assert c.ping()["op"] == "pong"
        st = c.daemon_stats()
        assert st["counters"]["requests"] >= 1
        assert st["versions"] == wire_versions()
        assert st["workers"] == 0 and st["pool"] is None
        assert st["frames"]["entries"] == st["frame_cache"]
        c.shutdown()
        assert d._stop.wait(timeout=5.0)


def test_unknown_op_is_typed(tmp_path):
    with daemon(tmp_path) as (_, sock):
        c = SchedClient(sock, retries=0)
        with pytest.raises(ProtocolError, match="unknown op"):
            c._request({"op": "frobnicate"}, 5.0)


def test_garbage_and_truncated_frames_are_survivable(tmp_path):
    with daemon(tmp_path) as (d, sock):
        # garbage magic -> typed bad_frame reply (or clean close)
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(b"NOPE" + b"\x00" * 64)
        reply = s.recv(1 << 16)
        s.close()
        assert not reply or b"bad_frame" in reply
        # truncated frame -> dropped connection, daemon survives
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC + struct.pack(">I", 1024) + b"short")
        s.close()
        wait_until(lambda: d.counters["bad_frames"] >= 1,
                   msg="bad_frames counted")
        assert SchedClient(sock, retries=0).ping()["op"] == "pong"


def test_oversized_length_rejected(tmp_path):
    with daemon(tmp_path) as (_, sock):
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC + struct.pack(">I", 0xFFFFFFF0))
        reply = s.recv(1 << 16)
        s.close()
        assert not reply or b"bad_frame" in reply


def test_slow_loris_dropped(tmp_path):
    with daemon(tmp_path, conn_timeout=0.3) as (d, sock):
        s = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock)
        s.sendall(wire.MAGIC[:2])        # stall mid-header
        assert s.recv(1) == b""          # daemon hangs up
        s.close()
        assert d.counters["slow_loris"] >= 1
        assert SchedClient(sock, retries=0).ping()["op"] == "pong"


# ---------------------------------------------------------------------------
# coalescing + shedding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_identical_concurrent_requests_coalesce(tmp_path, workers):
    with daemon(tmp_path, workers=workers) as (d, sock):
        scop = tiny_scop("schedd_co")
        metas = []

        def go():
            c = SchedClient(sock, retries=0, request_timeout=30.0)
            resp = c._request({"op": "schedule", "scop": scop,
                               "test_delay_s": 0.4}, 30.0)
            metas.append(resp["meta"])

        first = threading.Thread(target=go)
        first.start()
        # the rest must arrive while the first owns the flight
        wait_until(lambda: d.counters["computed"] >= 1,
                   msg="first request owns the flight")
        rest = [threading.Thread(target=go) for _ in range(2)]
        for t in rest:
            t.start()
        for t in [first] + rest:
            t.join(timeout=30.0)
        assert len(metas) == 3
        assert d.counters["computed"] == 1
        assert d.counters["coalesced"] == 2


def test_overload_sheds_typed(tmp_path):
    with daemon(tmp_path, max_inflight=1) as (d, sock):
        done = threading.Event()

        def hold():
            c = SchedClient(sock, retries=0, request_timeout=30.0)
            c._request({"op": "schedule", "scop": tiny_scop("schedd_h"),
                        "test_delay_s": 1.0}, 30.0)
            done.set()

        t = threading.Thread(target=hold)
        t.start()
        wait_until(lambda: d.counters["computed"] >= 1,
                   msg="holder occupies the flight table")
        c = SchedClient(sock, retries=0)
        with pytest.raises(Overloaded):
            c._request({"op": "schedule", "scop": other_scop()}, 10.0)
        # the total API serves in-process while the daemon is saturated
        sched = c.schedule(other_scop())
        assert sched is not None
        assert c.stats.fallbacks == 1 and c.stats.overloaded >= 1
        assert done.wait(timeout=30.0)
        t.join(timeout=5.0)
        assert d.counters["shed"] >= 1


# ---------------------------------------------------------------------------
# deadlines + degraded results
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_expired_deadline_degrades_and_is_never_frame_cached(tmp_path,
                                                             workers):
    with daemon(tmp_path, workers=workers) as (d, sock):
        c = SchedClient(sock, retries=0)
        scop = tiny_scop("schedd_dl")
        r1 = c._request({"op": "schedule", "scop": scop,
                         "deadline_s": 0.0}, 10.0)
        assert r1["meta"]["degraded"]
        r2 = c._request({"op": "schedule", "scop": scop,
                         "deadline_s": 0.0}, 10.0)
        assert r2["meta"]["degraded"]
        # both computed: a degraded response must never be served warm
        assert d.counters["computed"] == 2
        assert d.counters["frame_hits"] == 0
        assert d.counters["degraded"] == 2


def test_client_exhausted_deadline_falls_back_without_dialing(tmp_path):
    from repro.core.schedcache import ScheduleCache

    with daemon(tmp_path) as (d, sock):
        # isolated fallback cache: the key is structural, so a warm hit
        # from the process-global pool would serve a clean schedule and
        # mask the deadline degradation this test asserts
        c = SchedClient(sock, retries=0,
                        cache=ScheduleCache(cache_dir=str(tmp_path / "fb")))
        dl = Deadline(0.0)
        wait_until(lambda: dl.elapsed() > 0.0, msg="deadline clock ticks")
        sched = c.schedule(tiny_scop("schedd_dl2"), deadline=dl)
        assert sched.degraded              # local ladder, identity rung
        assert c.stats.fallbacks == 1
        assert d.counters["requests"] == 0  # never reached the daemon


# ---------------------------------------------------------------------------
# worker pool
# ---------------------------------------------------------------------------


def test_pool_distinct_keys_overlap(tmp_path):
    """Two distinct-key holds on two workers must overlap — the proof
    the pool actually escapes the single-process serialization."""
    with daemon(tmp_path, workers=2) as (d, sock):
        results = []

        def go(i, n, delay):
            c = SchedClient(sock, retries=0, request_timeout=30.0)
            results.append(c._request(
                {"op": "schedule", "scop": tiny_scop(f"schedd_p{i}", n),
                 "test_delay_s": delay}, 30.0))

        def both(n0, delay):
            # two *structurally distinct* scops (the key fingerprints
            # structure, so the sizes must differ), one per worker
            threads = [threading.Thread(target=go, args=(i, n0 + i, delay))
                       for i in range(2)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)

        # warmup: the first job on each worker pays one-time lazy-init
        # cost; measuring it would only test fork latency
        both(18, 0.2)
        results.clear()
        t0 = time.monotonic()
        both(24, 0.8)
        elapsed = time.monotonic() - t0
        assert len(results) == 2 and all(r["ok"] for r in results)
        # steady state measures ~0.83s; serialized would be >= 1.6s
        assert elapsed < 1.5, f"holds serialized: {elapsed:.2f}s"
        assert d.counters["pool_jobs"] == 4
        # the holds ran in workers, not the daemon process
        pids = {r["meta"]["pid"] for r in results}
        assert os.getpid() not in pids


def test_pool_poison_request_is_typed_and_bounded(tmp_path):
    """A request that SIGKILLs its worker burns exactly two workers
    (one retry on a fresh fork), then surfaces as WorkerCrashed; the
    pool respawns and stays healthy."""
    with daemon(tmp_path, workers=2) as (d, sock):
        c = SchedClient(sock, retries=0, request_timeout=60.0)
        with pytest.raises(WorkerCrashed):
            c._request({"op": "schedule", "scop": tiny_scop("schedd_px"),
                        "test_kill_worker": True}, 60.0)
        assert d.counters["worker_crashes"] == 2
        assert d.pool.stats()["crashes"] == 2
        # respawn restored the pool size
        wait_until(lambda: d.pool.stats()["idle"] == 2,
                   msg="pool respawned to full strength")
        # and it still serves
        sched = c.schedule(tiny_scop("schedd_px2"))
        assert not sched.degraded
        # WorkerCrashed is a SchedClientError, so the client's total
        # API (schedule/autotune) falls back in-process on it — same
        # contract the breaker/fallback tests pin for the other kinds
        from repro.core.schedclient import SchedClientError
        assert issubclass(WorkerCrashed, SchedClientError)


def test_pool_worker_kill9_between_jobs_is_respawned(tmp_path):
    """kill -9 of an idle worker: the corpse is detected at the next
    acquire, counted, replaced, and the job runs on the fresh fork."""
    import signal as _signal

    with daemon(tmp_path, workers=1) as (d, sock):
        victim = d.pool._procs[0].proc
        os.kill(victim.pid, _signal.SIGKILL)
        victim.join(timeout=10.0)
        assert not victim.is_alive()
        c = SchedClient(sock, retries=0, request_timeout=30.0)
        sched = c.schedule(tiny_scop("schedd_k9"))
        assert not sched.degraded
        assert d.pool.stats()["crashes"] == 1
        assert d.pool.stats()["spawned"] == 2


@pytest.mark.parametrize("workers", WORKER_LEVELS)
def test_winner_push_warms_schedule_frame(tmp_path, workers):
    """An autotune winner's schedule is pushed into the frame cache, so
    the follow-up schedule request for the tuned config is a warm hit
    that never touches the solver."""
    with daemon(tmp_path, workers=workers) as (d, sock):
        c = SchedClient(sock, retries=0, request_timeout=60.0)
        r = c.autotune(tiny_scop("schedd_wp"), measure=False, top_k=2)
        assert not r.degraded
        assert d.counters["winner_pushes"] == 1
        computed = d.counters["computed"]
        sched = c.schedule(tiny_scop("schedd_wp"),
                           config=r.config.scheduler_config())
        assert not sched.degraded
        assert d.counters["computed"] == computed      # no new flight
        assert d.counters["frame_hits"] == 1


def test_pool_crash_is_witnessed_not_orphaned(tmp_path):
    """A worker kill -9 mid-autotune is journalled as `crashed` by the
    surviving daemon — so a later restart does NOT re-count it as an
    unwitnessed orphan."""
    pool_dir = tmp_path / "pool"
    with daemon(tmp_path, workers=1, cache_dir=str(pool_dir)) as (d, sock):
        c = SchedClient(sock, retries=0, request_timeout=60.0)
        with pytest.raises(WorkerCrashed):
            c._request({"op": "autotune", "scop": tiny_scop("schedd_jw"),
                        "kwargs": {"measure": False},
                        "test_kill_worker": True}, 60.0)
        assert d.counters["worker_crashes"] == 2
    journal = AutotuneJournal(str(pool_dir / "schedd_journal.jsonl"))
    assert journal.recover() == []         # witnessed, not orphaned


def test_frames_snapshot_accounts_eviction(tmp_path):
    """The daemon's stats surface the latency-saved frame cache: entry
    cap enforced, evictions counted, retained latency tracked."""
    with daemon(tmp_path, frame_cache_cap=2) as (d, sock):
        c = SchedClient(sock, retries=0)
        for i in range(4):
            c.plan("matmul", 32 + 8 * i, 32, 32, "tensor")
        st = c.daemon_stats()
        assert st["frames"]["entries"] <= 2
        assert st["frames"]["stats"]["evicted"] >= 2
        assert st["frames"]["retained_latency_s"] >= 0.0
        assert st["frame_cache"] == st["frames"]["entries"]


# ---------------------------------------------------------------------------
# version handshake + breaker + fallback
# ---------------------------------------------------------------------------


def test_version_skew_rejected_and_breaker_opens(tmp_path):
    with daemon(tmp_path) as (d, sock):
        stale = dict(wire_versions(), cache=-99)
        c = SchedClient(sock, retries=2, versions=stale)
        with pytest.raises(VersionSkew):
            c.remote_plan("matmul", 32, 32, 32, "tensor")
        assert c.stats.retries == 0        # skew is not transient
        assert c.breaker.state != "closed"
        sched = c.schedule(tiny_scop("schedd_vs"))
        assert sched is not None
        assert c.stats.fallbacks == 1
        assert c.stats.breaker_skips == 1  # went straight to fallback
        assert d.counters["version_skew"] >= 1


def test_missing_socket_falls_back_and_breaker_trips(tmp_path):
    c = SchedClient(str(tmp_path / "nope.sock"), retries=1,
                    connect_timeout=0.2, breaker_threshold=2)
    with pytest.raises(DaemonUnavailable):
        c.remote_plan("matmul", 32, 32, 32, "tensor")
    sched = c.schedule(tiny_scop("schedd_ms"))
    assert sched is not None and not sched.degraded
    assert c.stats.fallbacks == 1
    assert c.breaker.state == "open"
    before = c.stats.remote_errors
    c.schedule(tiny_scop("schedd_ms"))
    assert c.stats.breaker_skips >= 1
    assert c.stats.remote_errors == before   # open breaker: no dialing


def test_breaker_half_open_recovers():
    t = [0.0]
    b = CircuitBreaker(threshold=2, reset_s=5.0, clock=lambda: t[0])
    assert b.state == "closed"
    b.failure()
    assert b.allow()
    b.failure()
    assert b.state == "open" and not b.allow()
    t[0] = 6.0
    assert b.allow()                   # the single half-open probe
    assert b.state == "half-open" and not b.allow()
    b.success()
    assert b.state == "closed" and b.allow()
    # a failing probe re-opens for another window
    b.failure()
    b.failure()
    t[0] = 12.0
    assert b.allow()
    b.failure()
    assert b.state == "open" and not b.allow()


def test_maybe_client_respects_env_and_server_guard(tmp_path, monkeypatch):
    monkeypatch.delenv(wire.SOCKET_ENV, raising=False)
    assert wire.maybe_client() is None
    monkeypatch.setenv(wire.SOCKET_ENV, str(tmp_path / "x.sock"))
    wire._DEFAULT = None
    assert wire.maybe_client() is not None
    monkeypatch.setattr(wire, "_SERVER_PROCESS", True)
    assert wire.maybe_client() is None
    monkeypatch.setattr(wire, "_SERVER_PROCESS", False)
    with local_only():
        assert wire.maybe_remote_plan("matmul", 8, 8, 8, "tensor") is None
    wire._DEFAULT = None


def test_akg_routes_through_daemon(tmp_path, monkeypatch):
    from repro.core import akg

    with daemon(tmp_path) as (d, sock):
        monkeypatch.setenv(wire.SOCKET_ENV, sock)
        wire._DEFAULT = None
        akg.plan_matmul.cache_clear()
        try:
            plan = akg.plan_matmul(40, 40, 40)
            assert not plan.degraded
            assert d.counters["requests"] >= 1
            assert d.counters["computed"] == 1
        finally:
            akg.plan_matmul.cache_clear()
            wire._DEFAULT = None


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------


def test_journal_recover_counts_orphans(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = AutotuneJournal(path)
    j.begin("aaa")
    j.done("aaa")
    j.begin("bbb")                      # orphan: a crash mid-request
    j.begin("ccc")
    with open(path, "a") as f:
        f.write('{"ev": "beg')          # torn tail from a kill -9
    assert AutotuneJournal(path).recover() == ["bbb", "ccc"]
    # recovery truncates: a second recover sees a clean journal
    assert AutotuneJournal(path).recover() == []


def test_journal_crashed_completes_begin(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = AutotuneJournal(path)
    j.begin("xx")
    j.crashed("xx", "worker pid 123 died")   # witnessed: not an orphan
    j.begin("yy")                            # unwitnessed: an orphan
    assert AutotuneJournal(path).recover() == ["yy"]


def test_daemon_surfaces_recovered_journal(tmp_path):
    pool = tmp_path / "pool"
    pool.mkdir()
    j = AutotuneJournal(str(pool / "schedd_journal.jsonl"))
    j.begin("orphaned-by-kill9")
    with daemon(tmp_path, cache_dir=str(pool)) as (d, sock):
        st = SchedClient(sock, retries=0).daemon_stats()
        assert st["journal_recovered"] == 1
        assert st["journal_recovered_keys"] == ["orphaned-by-kill9"]


# ---------------------------------------------------------------------------
# TCP transport + auth
# ---------------------------------------------------------------------------

TCP_KEY = b"test-shared-key"


@contextmanager
def tcp_daemon(tmp_path, key=TCP_KEY, **kwargs):
    kwargs.setdefault("cache_dir", str(tmp_path / "pool"))
    kwargs.setdefault("chaos", True)
    d = SchedDaemon(None, listen="127.0.0.1:0", auth_key=key, **kwargs)
    d.start()
    try:
        yield d, f"127.0.0.1:{d.tcp_port}"
    finally:
        d.stop()


def test_tcp_requires_key():
    with pytest.raises(ValueError, match="without a shared key"):
        SchedDaemon(None, listen="127.0.0.1:0", auth_key=None)


def test_tcp_roundtrip_and_frame_cache(tmp_path):
    with tcp_daemon(tmp_path) as (d, addr):
        c = SchedClient(addr, retries=0, key=TCP_KEY)
        s1 = c.schedule(tiny_scop())
        assert not s1.degraded
        s2 = c.schedule(tiny_scop())
        assert schedule_fingerprint(s1) == schedule_fingerprint(s2)
        assert d.counters["computed"] == 1
        assert d.counters["frame_hits"] == 1
        assert c.stats.remote_ok == 2 and c.stats.fallbacks == 0
        c.close()


def test_tcp_connection_reuse_one_handshake(tmp_path):
    """Pooled connections: N sequential requests cost ONE dial (one
    version/auth handshake), not N — the whole point of reuse over TCP."""
    with tcp_daemon(tmp_path) as (d, addr):
        c = SchedClient(addr, retries=0, key=TCP_KEY)
        for _ in range(5):
            assert c.ping()["ok"]
        snap = c.stats.as_dict()
        assert snap["dials"] == 1
        assert snap["reuses"] == 4
        c.close()


def test_tcp_wrong_key_typed_and_daemon_survives(tmp_path):
    with tcp_daemon(tmp_path) as (d, addr):
        bad = SchedClient(addr, retries=0, key=b"not-the-key")
        with pytest.raises(wire.AuthFailed):
            bad.ping()                 # raw path raises typed
        wait_until(lambda: d.counters["auth_failed"] >= 1,
                   msg="daemon-side auth_failed count")
        # the public API degrades to the fallback, not a raise — and
        # the auth failure trips the breaker immediately (not transient)
        sched = bad.schedule(tiny_scop("schedd_tcpw"))
        assert sched is not None
        assert bad.stats.fallbacks == 1
        assert bad.stats.auth_failed == 1
        assert bad.breaker.state == "open"
        # the daemon keeps serving authenticated clients
        good = SchedClient(addr, retries=0, key=TCP_KEY)
        assert good.ping()["ok"]
        good.close()


def test_tcp_missing_key_is_typed(tmp_path):
    with tcp_daemon(tmp_path) as (d, addr):
        c = SchedClient(addr, retries=0, key=None)
        c.key = None                   # defeat any ambient env key
        with pytest.raises(wire.AuthFailed, match="no key"):
            c.ping()


def test_tcp_tampered_mac_rejected_conn_dropped(tmp_path):
    """A post-handshake frame whose MAC does not verify gets a typed
    auth_failed reply and a dropped connection — never unpickled."""
    with tcp_daemon(tmp_path) as (d, addr):
        host, port = addr.rsplit(":", 1)
        s = socketlib.create_connection((host, int(port)), timeout=5.0)
        try:
            _, session = wire.client_handshake(
                s, {"op": "hello", **wire_versions()}, key=TCP_KEY)
            frame = bytearray(wire.encode_frame({"op": "ping"},
                                                session=session))
            frame[-1] ^= 0xFF                      # corrupt the MAC
            s.sendall(bytes(frame))
            reply = wire.recv_frame(s, session=session, eof_ok=True)
            assert reply is not None and reply["error"] == "auth_failed"
            wait_until(lambda: d.counters["auth_failed"] >= 1,
                       msg="auth_failed counter")
        finally:
            s.close()
        # unpoisoned: the daemon still serves
        good = SchedClient(addr, retries=0, key=TCP_KEY)
        assert good.ping()["ok"]
        good.close()


def test_tcp_idle_conn_closed_quietly_then_redialed(tmp_path):
    """A pooled connection the daemon idle-closes is NOT a slow-loris
    (separate counter) and the client transparently redials."""
    with tcp_daemon(tmp_path, conn_timeout=0.3) as (d, addr):
        c = SchedClient(addr, retries=0, key=TCP_KEY)
        assert c.ping()["ok"]
        wait_until(lambda: d.counters["idle_closed"] >= 1,
                   msg="idle close")
        assert d.counters["slow_loris"] == 0
        assert c.ping()["ok"]          # stale pooled conn -> one redial
        assert c.stats.dials == 2
        assert c.stats.remote_errors == 0
        c.close()


def test_addr_env_routes_client(tmp_path, monkeypatch):
    with tcp_daemon(tmp_path) as (d, addr):
        monkeypatch.setenv(wire.ADDR_ENV, addr)
        monkeypatch.setenv(wire.KEY_ENV, TCP_KEY.decode())
        monkeypatch.delenv(wire.SOCKET_ENV, raising=False)
        wire._DEFAULT = None
        try:
            c = wire.maybe_client()
            assert c is not None and c.sock_path == addr
            assert c.ping()["ok"]
        finally:
            wire._DEFAULT = None


def test_peer_winner_push_between_daemons(tmp_path):
    """Daemon A's autotune winner lands in daemon B's frame cache: a
    schedule request for the tuned config on B is a warm frame hit
    with zero computes."""
    with tcp_daemon(tmp_path, cache_dir=str(tmp_path / "pb")) as (db, addr_b):
        with tcp_daemon(tmp_path, cache_dir=str(tmp_path / "pa"),
                        peers=(addr_b,)) as (da, addr_a):
            ca = SchedClient(addr_a, retries=0, key=TCP_KEY,
                             request_timeout=60.0)
            r = ca.autotune(tiny_scop("schedd_pp"), measure=False, top_k=2)
            assert not r.degraded
            assert da.counters["winner_pushes"] == 1
            wait_until(lambda: db.counters["peer_pushes_recv"] >= 1,
                       msg="peer push arrival")
            wait_until(lambda: da.counters["peer_pushes_sent"] >= 1,
                       msg="peer push sent count")
            cb = SchedClient(addr_b, retries=0, key=TCP_KEY)
            sched = cb.schedule(tiny_scop("schedd_pp"),
                                config=r.config.scheduler_config())
            assert not sched.degraded
            assert db.counters["frame_hits"] == 1
            assert db.counters["computed"] == 0
            ca.close(); cb.close()


def test_winner_push_storm_cap(tmp_path):
    """Admitted peer pushes are bounded per sliding window: a push storm
    cannot churn the frame cache.  Refusals are typed (admitted=False,
    capped=True) and tallied on both the daemon counters and the frame
    cache's CacheStats; once the window slides past, pushes admit
    again."""
    with tcp_daemon(tmp_path, push_storm_max=2,
                    push_storm_window=60.0) as (d, addr):
        c = SchedClient(addr, retries=0, key=TCP_KEY)

        def push(i):
            return c._request(
                {"op": "winner_push",
                 "key": ("schedule", f"storm-{i}", False),
                 "resp": {"ok": True, "schedule": None,
                          "meta": {"degraded": False}},
                 "compute_s": 1.0}, 5.0)

        rs = [push(i) for i in range(5)]
        assert [bool(r.get("admitted")) for r in rs] == \
            [True, True, False, False, False]
        assert all(rs[i].get("capped") for i in range(2, 5))
        assert d.counters["peer_pushes_recv"] == 2
        assert d.counters["peer_pushes_capped"] == 3
        assert d._frames.stats["push_capped"] == 3
        # slide the window: pretend the admits happened long ago
        with d._lock:
            d._push_admits.clear()
        assert push(9).get("admitted") is True
        c.close()


def test_winner_push_op_validates(tmp_path):
    """The winner_push op rejects degraded/malformed pushes with a
    typed error instead of admitting poison."""
    with tcp_daemon(tmp_path) as (d, addr):
        c = SchedClient(addr, retries=0, key=TCP_KEY)
        with pytest.raises(ProtocolError):
            c._request({"op": "winner_push"}, 5.0)
        with pytest.raises(ProtocolError):
            c._request({"op": "winner_push", "key": ("schedule", "k", False),
                        "resp": {"ok": True,
                                 "meta": {"degraded": True}}}, 5.0)
        assert d.counters["peer_pushes_recv"] == 0
        c.close()
