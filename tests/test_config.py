"""Conformance tests for the paper-Listing-2 JSON configuration
interface (``SchedulerConfig.from_json`` / ``to_json``).

Three layers:

* round-trip — every Listing-2 key (``new_variables``, per-dim
  ``ILP_construction`` cost functions/constraints/require_parallel,
  ``custom_constraints``, ``fusion`` with explicit statement groups,
  ``directives``, ``auto_vectorization``, bounds, ``parametric_shift``)
  survives ``from_json(to_json(cfg))`` exactly;
* acceptance — the wrapped/unwrapped forms, file input, coercions the
  scheduler relies on (string statement indices), and defaults;
* rejection — malformed input raises :class:`ConfigError` (a
  ``ValueError`` naming the offending key), never a bare
  ``KeyError``/``TypeError`` from deep inside the scheduler.
"""
import json

import pytest

from repro.core import config as CFG
from repro.core.config import (ConfigError, DimConfig, Directive, FusionSpec,
                               SchedulerConfig)


def _full_config() -> SchedulerConfig:
    """One config exercising every JSON-expressible field."""
    cfg = SchedulerConfig(name="full")
    cfg.new_variables = ["slack"]
    cfg.ilp[0] = DimConfig(cost_functions=["contiguity", "proximity"],
                           constraints=["no-skewing"])
    cfg.ilp[1] = DimConfig(cost_functions=["proximity"], require_parallel=True)
    cfg.ilp["default"] = DimConfig(cost_functions=["proximity", "slack"])
    cfg.custom_constraints["default"] = ["S0_it_0 >= 1"]
    cfg.custom_constraints[2] = ["Si_cst <= 3"]
    cfg.fusion = [FusionSpec(0, groups=[[0, 1], [2]]),
                  FusionSpec("default", total_distribution=True)]
    cfg.directives = [Directive("vectorize", [0], 1),
                      Directive("parallel", [0, 1], None),
                      Directive("sequential", [2], 0)]
    cfg.auto_vectorize = True
    cfg.fusion_mode = "no"
    cfg.coeff_bound = 7
    cfg.cst_bound = 11
    cfg.parametric_shift = True
    return cfg


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------


def test_roundtrip_every_key():
    cfg = _full_config()
    assert SchedulerConfig.from_json(cfg.to_json()) == cfg


def test_roundtrip_predefined_strategies():
    for name, factory in CFG.STRATEGIES.items():
        cfg = factory()
        if cfg.strategy is not None:      # dynamic callback: not JSON-able
            continue
        got = SchedulerConfig.from_json(cfg.to_json())
        assert got == cfg, name


def test_roundtrip_defaults():
    cfg = SchedulerConfig(name="json")
    assert SchedulerConfig.from_json(cfg.to_json()) == cfg


def test_roundtrip_through_json_text_and_file(tmp_path):
    cfg = _full_config()
    text = json.dumps(cfg.to_json())
    assert SchedulerConfig.from_json(json.loads(text)) == cfg
    path = tmp_path / "cfg.json"
    path.write_text(text)
    assert SchedulerConfig.from_json(str(path)) == cfg


def test_roundtrip_is_stable():
    """to_json ∘ from_json is the identity on the JSON side too."""
    d = _full_config().to_json()
    assert SchedulerConfig.from_json(d).to_json() == d


# ---------------------------------------------------------------------------
# acceptance details
# ---------------------------------------------------------------------------


def test_unwrapped_dict_accepted():
    cfg = SchedulerConfig.from_json(
        {"ILP_construction": [{"scheduling_dimension": "default",
                               "cost_functions": ["proximity"]}],
         "fusion_mode": "max"})
    assert cfg.fusion_mode == "max"
    assert cfg.ilp["default"].cost_functions == ["proximity"]


def test_string_statement_indices_coerced():
    cfg = SchedulerConfig.from_json({
        "fusion": [{"scheduling_dimension": 0,
                    "stmts_fusion": [["1"], ["0"]]}],
        "directives": [{"type": "vectorize", "stmts": "2", "iterator": "1"}],
    })
    assert cfg.fusion[0].groups == [[1], [0]]
    assert cfg.directives[0] == Directive("vectorize", [2], 1)


def test_new_variable_usable_as_cost_function():
    cfg = SchedulerConfig.from_json({
        "new_variables": ["mu"],
        "ILP_construction": [{"cost_functions": ["mu", "proximity"]}],
    })
    assert cfg.ilp["default"].cost_functions == ["mu", "proximity"]


def test_defaults_applied():
    cfg = SchedulerConfig.from_json({})
    assert cfg.fusion_mode == "smart"
    assert cfg.coeff_bound == 4 and cfg.cst_bound == 32
    assert not cfg.auto_vectorize and not cfg.parametric_shift
    assert cfg.name == "json"


# ---------------------------------------------------------------------------
# rejection: malformed input → ConfigError (a ValueError), with a
# message naming the offending key
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("data,needle", [
    ([1, 2], "JSON object"),
    ({"scheduling_strategy": [1]}, "scheduling_strategy"),
    ({"new_variables": "x"}, "new_variables"),
    ({"new_variables": [1]}, "new_variables"),
    ({"ILP_construction": {"a": 1}}, "ILP_construction"),
    ({"ILP_construction": ["proximity"]}, "entries must be objects"),
    ({"ILP_construction": [{"scheduling_dimension": -1}]},
     "scheduling_dimension"),
    ({"ILP_construction": [{"scheduling_dimension": 1.5}]},
     "scheduling_dimension"),
    ({"ILP_construction": [{"cost_functions": []}]}, "cost_functions"),
    ({"ILP_construction": [{"cost_functions": "proximity"}]},
     "cost_functions"),
    ({"ILP_construction": [{"cost_functions": ["nearness"]}]}, "nearness"),
    ({"ILP_construction": [{"cost_functions": ["proximity"],
                            "constraints": [1]}]}, "constraints"),
    ({"custom_constraints": [{"scheduling_dimension": "x"}]},
     "scheduling_dimension"),
    ({"custom_constraints": [{"constraints": "S0_cst >= 1"}]}, "constraints"),
    ({"fusion": [{"scheduling_dimension": -2}]}, "scheduling_dimension"),
    ({"fusion": [{"stmts_fusion": "01"}]}, "stmts_fusion"),
    ({"fusion": [{"stmts_fusion": [["a"]]}]}, "statement indices"),
    ({"fusion": [{"stmts_fusion": [[0, 1], [1, 2]]}]}, "disjoint"),
    ({"directives": [{"stmts": [0]}]}, "type"),
    ({"directives": [{"type": "unroll", "stmts": [0]}]}, "unroll"),
    ({"directives": [{"type": "vectorize", "stmts": ["a"]}]}, "stmts"),
    ({"directives": [{"type": "vectorize", "stmts": [0],
                      "iterator": "x"}]}, "iterator"),
    ({"fusion_mode": "merge"}, "fusion_mode"),
    ({"coeff_bound": 0}, "coeff_bound"),
    ({"coeff_bound": True}, "coeff_bound"),
    ({"cst_bound": -3}, "cst_bound"),
    ({"cst_bound": "32"}, "cst_bound"),
])
def test_malformed_rejected(data, needle):
    with pytest.raises(ConfigError) as exc:
        SchedulerConfig.from_json(data)
    assert needle in str(exc.value)
    assert isinstance(exc.value, ValueError)


def test_malformed_file_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"fusion_mode": "everything"}))
    with pytest.raises(ConfigError):
        SchedulerConfig.from_json(str(path))
