"""Wire-layer unit tests: partial TCP delivery, MAC sessions, the
HMAC handshake, and the client-side retry/stats fixes.

Frames over AF_UNIX arrive whole in practice, so the framing code's
reassembly paths were never exercised before the TCP transport existed.
These tests dribble bytes through socketpairs — headers split from
bodies, MACs split across segments, EOF mid-frame — exactly the
arrival patterns a real TCP stream produces.
"""
import socket
import threading
import time

import pytest

from repro.core import wire
from repro.core.resilience import Deadline
from repro.core.schedclient import (
    MIN_RETRY_BUDGET_S,
    AuthFailed,
    ClientStats,
    DaemonUnavailable,
    ProtocolError,
    SchedClient,
)


def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def _dribble(sock, data, chunk=1, delay=0.0):
    """Write ``data`` in ``chunk``-byte segments from a thread."""
    def run():
        for i in range(0, len(data), chunk):
            sock.sendall(data[i:i + chunk])
            if delay:
                time.sleep(delay)
    t = threading.Thread(target=run, daemon=True)
    t.start()
    return t


# ---------------------------------------------------------------------------
# partial delivery
# ---------------------------------------------------------------------------


def test_frame_dribbled_byte_by_byte():
    a, b = _pair()
    try:
        payload = {"op": "ping", "blob": list(range(50))}
        t = _dribble(a, wire.encode_frame(payload), chunk=1)
        assert wire.recv_frame(b) == payload
        t.join(timeout=5.0)
    finally:
        a.close(); b.close()


def test_header_split_from_body():
    a, b = _pair()
    try:
        frame = wire.encode_frame({"x": 1})
        # header in two pieces, then a pause, then the body in two pieces
        mid = wire.HEADER_LEN - 2
        for part in (frame[:3], frame[3:mid], frame[mid:mid + 4],
                     frame[mid + 4:]):
            t = _dribble(a, part, chunk=len(part) or 1)
            t.join(timeout=5.0)
        assert wire.recv_frame(b) == {"x": 1}
    finally:
        a.close(); b.close()


def test_mac_split_across_segments():
    """A MAC'd frame whose 32-byte tag arrives one byte at a time still
    verifies — and verifies BEFORE the body is decoded."""
    a, b = _pair()
    try:
        tx = wire.Session(b"k" * 32, is_client=True)
        rx = wire.Session(b"k" * 32, is_client=False)
        frame = wire.encode_frame({"n": 7}, session=tx)
        # everything up to mid-MAC at once, then dribble the rest
        cut = len(frame) - wire.MAC_LEN // 2
        a.sendall(frame[:cut])
        t = _dribble(a, frame[cut:], chunk=1)
        assert wire.recv_frame(b, session=rx) == {"n": 7}
        t.join(timeout=5.0)
    finally:
        a.close(); b.close()


def test_eof_mid_header_and_mid_body():
    for cut in (2, wire.HEADER_LEN + 3):
        a, b = _pair()
        try:
            frame = wire.encode_frame({"x": 1})
            a.sendall(frame[:cut])
            a.close()
            with pytest.raises(ProtocolError, match="truncated"):
                wire.recv_frame(b)
        finally:
            b.close()
    # EOF exactly at a frame boundary is clean when eof_ok
    a, b = _pair()
    try:
        a.close()
        assert wire.recv_frame(b, eof_ok=True) is None
    finally:
        b.close()


def test_eof_mid_mac_is_truncated():
    a, b = _pair()
    try:
        tx = wire.Session(b"k" * 32, is_client=True)
        rx = wire.Session(b"k" * 32, is_client=False)
        frame = wire.encode_frame({"n": 1}, session=tx)
        a.sendall(frame[:-5])       # everything but the MAC tail
        a.close()
        with pytest.raises(ProtocolError, match="truncated"):
            wire.recv_frame(b, session=rx)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# MAC sessions
# ---------------------------------------------------------------------------


def test_tampered_body_fails_before_decode():
    a, b = _pair()
    try:
        tx = wire.Session(b"k" * 32, is_client=True)
        rx = wire.Session(b"k" * 32, is_client=False)
        frame = bytearray(wire.encode_frame({"n": 7}, session=tx))
        frame[wire.HEADER_LEN] ^= 0xFF        # flip a body byte
        a.sendall(bytes(frame))
        with pytest.raises(AuthFailed, match="MAC mismatch"):
            wire.recv_frame(b, session=rx)
    finally:
        a.close(); b.close()


def test_reordered_frames_fail_sequence_check():
    """Per-direction sequence numbers: swapping two frames in flight
    breaks both MACs (no replay / reorder within a connection)."""
    a, b = _pair()
    try:
        tx = wire.Session(b"k" * 32, is_client=True)
        rx = wire.Session(b"k" * 32, is_client=False)
        f1 = wire.encode_frame({"n": 1}, session=tx)
        f2 = wire.encode_frame({"n": 2}, session=tx)
        a.sendall(f2 + f1)                     # swapped
        with pytest.raises(AuthFailed):
            wire.recv_frame(b, session=rx)
    finally:
        a.close(); b.close()


def test_direction_bytes_prevent_reflection():
    """A frame signed by the client cannot be verified as if it came
    from the server (and vice versa)."""
    tx = wire.Session(b"k" * 32, is_client=True)
    reflected = wire.Session(b"k" * 32, is_client=True)  # same direction
    a, b = _pair()
    try:
        a.sendall(wire.encode_frame({"n": 1}, session=tx))
        with pytest.raises(AuthFailed):
            wire.recv_frame(b, session=reflected)
    finally:
        a.close(); b.close()


# ---------------------------------------------------------------------------
# pre-auth cap + JSON codec
# ---------------------------------------------------------------------------


def test_pre_auth_cap_rejects_large_header():
    a, b = _pair()
    try:
        import struct
        a.sendall(wire.MAGIC
                  + struct.pack(">I", wire.PRE_AUTH_MAX_FRAME_BYTES + 1))
        with pytest.raises(ProtocolError, match="cap"):
            wire.recv_frame(b, max_bytes=wire.PRE_AUTH_MAX_FRAME_BYTES)
    finally:
        a.close(); b.close()


def test_json_codec_rejects_garbage_and_non_dict():
    for body in (b"\x80\x04notjson", b"[1,2,3]"):
        a, b = _pair()
        try:
            import struct
            a.sendall(wire.MAGIC + struct.pack(">I", len(body)) + body)
            with pytest.raises(ProtocolError):
                wire.recv_frame(b, json_codec=True)
        finally:
            a.close(); b.close()


# ---------------------------------------------------------------------------
# the handshake
# ---------------------------------------------------------------------------


def _server_side(conn, key, require_auth):
    hello = wire.recv_frame(conn, json_codec=True,
                            max_bytes=wire.PRE_AUTH_MAX_FRAME_BYTES)
    return wire.server_handshake(
        conn, hello, key=key, require_auth=require_auth,
        hello_ok={"ok": True, "op": "hello", **wire.wire_versions()})


def _client_side(sock, key, out):
    try:
        out["resp"], out["session"] = wire.client_handshake(
            sock, {"op": "hello", **wire.wire_versions()}, key=key)
    except Exception as e:          # surfaced by the test thread join
        out["error"] = e
        sock.close()    # like SchedClient._dial: abort is visible as EOF


def test_handshake_roundtrip_with_macs():
    a, b = _pair()
    try:
        out = {}
        t = threading.Thread(target=_client_side,
                             args=(a, b"shared-key", out), daemon=True)
        t.start()
        server_session = _server_side(b, b"shared-key", True)
        t.join(timeout=5.0)
        assert "error" not in out, out.get("error")
        assert out["resp"].get("authed") is True
        # both sides derived the same session key; MAC'd traffic flows
        wire.send_frame(a, {"op": "ping"}, session=out["session"])
        assert wire.recv_frame(b, session=server_session) == {"op": "ping"}
        wire.send_frame(b, {"ok": True}, session=server_session)
        assert wire.recv_frame(a, session=out["session"]) == {"ok": True}
    finally:
        a.close(); b.close()


def test_handshake_wrong_key_typed_both_sides():
    a, b = _pair()
    try:
        out = {}
        t = threading.Thread(target=_client_side,
                             args=(a, b"wrong", out), daemon=True)
        t.start()
        with pytest.raises(wire.AuthFailed):
            _server_side(b, b"right", True)
        t.join(timeout=5.0)
        assert isinstance(out.get("error"), wire.AuthFailed)
    finally:
        a.close(); b.close()


def test_handshake_unix_no_auth_no_session():
    a, b = _pair()
    try:
        out = {}
        t = threading.Thread(target=_client_side, args=(a, None, out),
                             daemon=True)
        t.start()
        assert _server_side(b, None, False) is None
        t.join(timeout=5.0)
        assert "error" not in out
        assert out["session"] is None
    finally:
        a.close(); b.close()


# ---------------------------------------------------------------------------
# address parsing + keys
# ---------------------------------------------------------------------------


def test_parse_address():
    assert wire.parse_address("127.0.0.1:9000") == \
        ("tcp", ("127.0.0.1", 9000))
    assert wire.parse_address("example.com:80") == \
        ("tcp", ("example.com", 80))
    assert wire.parse_address("/tmp/x/s.sock") == ("unix", "/tmp/x/s.sock")
    assert wire.parse_address("/tmp/odd:name.sock") == \
        ("unix", "/tmp/odd:name.sock")          # path separator wins
    assert wire.parse_address("sock")[0] == "unix"
    assert wire.parse_address("host:")[0] == "unix"
    assert wire.parse_address(":123")[0] == "unix"


def test_load_key_sources(tmp_path, monkeypatch):
    monkeypatch.delenv(wire.KEY_ENV, raising=False)
    assert wire.load_key() is None
    monkeypatch.setenv(wire.KEY_ENV, "envkey")
    assert wire.load_key() == b"envkey"
    kf = tmp_path / "key"
    kf.write_bytes(b"filekey\n")
    assert wire.load_key(str(kf)) == b"filekey"   # keyfile beats env
    (tmp_path / "empty").write_bytes(b"")
    with pytest.raises(ValueError):
        wire.load_key(str(tmp_path / "empty"))


# ---------------------------------------------------------------------------
# retry backoff must not eat the whole deadline (regression)
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_retry_skipped_when_budget_below_nap_plus_floor(monkeypatch):
    """With less budget left than the backoff nap + a minimum useful
    request, the retry would be dead on arrival — the client must raise
    the last typed error immediately instead of napping through the
    deadline and double-counting a breaker failure."""
    clock = _FakeClock()
    deadline = Deadline(1.0, clock=clock)
    c = SchedClient("/nonexistent/sock", retries=3, backoff_s=0.9)
    calls = []

    def failing_request(payload, timeout):
        clock.t += 0.2            # each attempt burns fake time
        calls.append(timeout)
        raise DaemonUnavailable("down")

    monkeypatch.setattr(c, "_request", failing_request)
    naps = []
    monkeypatch.setattr(time, "sleep", lambda s: naps.append(s))

    with pytest.raises(DaemonUnavailable):
        c._call({"op": "ping"}, deadline)
    # one attempt burns 0.2s leaving 0.8s < 0.9 nap + floor: no retry
    assert len(calls) == 1
    assert naps == []
    assert c.stats.as_dict()["retries"] == 0
    # exactly ONE breaker failure for the whole call
    assert c.breaker.failures == 1


def test_retry_proceeds_with_ample_budget(monkeypatch):
    clock = _FakeClock()
    deadline = Deadline(10.0, clock=clock)
    c = SchedClient("/nonexistent/sock", retries=2, backoff_s=0.05)
    calls = []

    def failing_request(payload, timeout):
        clock.t += 0.01
        calls.append(payload["deadline_s"])
        raise DaemonUnavailable("down")

    monkeypatch.setattr(c, "_request", failing_request)
    naps = []
    monkeypatch.setattr(time, "sleep", lambda s: naps.append(s))
    with pytest.raises(DaemonUnavailable):
        c._call({"op": "ping"}, deadline)
    assert len(calls) == 3                  # initial + 2 retries
    assert naps == [0.05, 0.1]              # exponential, never clipped
    assert c.stats.as_dict()["retries"] == 2
    # the wire deadline shrinks as fake time passes
    assert calls == sorted(calls, reverse=True)


def test_min_retry_budget_floor_constant():
    assert 0.0 < MIN_RETRY_BUDGET_S < 1.0


# ---------------------------------------------------------------------------
# ClientStats under thread contention (regression)
# ---------------------------------------------------------------------------


def test_client_stats_threaded_hammer():
    """Concurrent increments from many threads lose no updates — the
    old dataclass ``+=`` did, once SchedClient was shared across
    connection threads."""
    stats = ClientStats()
    threads, per_thread = 8, 2000
    fields = ["remote_ok", "retries", "fallbacks", "remote_errors"]

    def hammer():
        for _ in range(per_thread):
            for f in fields:
                stats.incr(f)

    ts = [threading.Thread(target=hammer) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = stats.as_dict()
    for f in fields:
        assert snap[f] == threads * per_thread, f
    assert snap["breaker_skips"] == 0
