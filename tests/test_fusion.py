"""Fusion-axis property tests (paper §III-E).

Every fusion configuration the autotuner can enumerate — the
``smart``/``max``/``no`` modes and explicit SCC-derived statement
groups — must yield a schedule that passes the *exact* legality check
against every dependence (``PolyTOPSScheduler._lex_satisfied``, the
piecewise-emptiness test over the dependence polyhedra: no dependence
may ever be lexicographically violated, strongly satisfied or not).

Property layer (hypothesis via ``tests/_hypothesis_compat``, plus a
seeded sweep that always runs): *arbitrary* explicit statement
partitions either schedule legally or are rejected with
``SchedulingError`` at config application — never a silently illegal
schedule; partitions that respect the SCC topological order are always
accepted.

Structural layer: ``max``/``no`` fusion produce the expected band-count
extremes on 2mm/3mm (one fused outer group with a depth-≥2 permutable
band vs one group per SCC).
"""
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import config as CFG
from repro.core.autotune import TunedConfig, base_configs
from repro.core.scheduler import (PolyTOPSScheduler, SchedulingError,
                                  _scc_groups)
from repro.core.scops_polybench import (make_gemm, make_gesummv, make_mm2,
                                        make_mm3, make_mvt)

SMALL_KERNELS = {
    "gemm": lambda: make_gemm(12),
    "mvt": lambda: make_mvt(12),
    "gesummv": lambda: make_gesummv(12),
    "mm2": lambda: make_mm2(8),
    "mm3": lambda: make_mm3(8),
}


def _schedule_and_check(scop, cfg):
    """Schedule and run the exact legality check against ALL deps."""
    sch = PolyTOPSScheduler(scop, cfg)
    sched = sch.schedule()
    for dep in sched.deps:
        assert sch._lex_satisfied(dep, sched), \
            f"dependence {dep} violated by {cfg.name}/{cfg.fusion_mode}"
    return sched


def _outer_groups(sched) -> int:
    """Number of statement groups at the outermost distribution level
    (1 when the leading dimension is already linear = fully fused)."""
    stmts = sched.scop.statements
    for d in range(sched.n_dims):
        rows = [sched.rows[s.index][d] for s in stmts]
        if all(r.kind == "scalar" for r in rows):
            return len({r.cst() for r in rows})
        if any(r.kind == "linear" for r in rows):
            return 1
    return 1


# ---------------------------------------------------------------------------
# every enumerated configuration is legal
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SMALL_KERNELS))
def test_enumerated_configs_pass_exact_legality(name):
    """The full autotuner enumeration (fusion modes, explicit SCC
    groups, cost mixes) on each kernel: every base that schedules must
    satisfy every dependence exactly."""
    scop = SMALL_KERNELS[name]()
    n_checked = 0
    for base in base_configs(scop):
        try:
            cfg = base.scheduler_config()
        except KeyError:
            pytest.fail(f"unknown strategy/mix in {base}")
        _schedule_and_check(SMALL_KERNELS[name](), cfg)
        n_checked += 1
    assert n_checked == len(base_configs(scop))   # nothing skipped


@pytest.mark.parametrize("fm", ["smart", "max", "no"])
@pytest.mark.parametrize("name", sorted(SMALL_KERNELS))
def test_fusion_modes_legal(name, fm):
    scop = SMALL_KERNELS[name]()
    cfg = CFG.pluto_style()
    cfg.fusion_mode = fm
    _schedule_and_check(scop, cfg)


# ---------------------------------------------------------------------------
# arbitrary explicit partitions: legal schedule or loud rejection
# ---------------------------------------------------------------------------


def _check_partition(name: str, order, cuts):
    """Build an explicit statement partition from a permutation + cut
    set; the scheduler must either raise SchedulingError (partition
    violates a dependence) or produce an exactly-legal schedule."""
    scop = SMALL_KERNELS[name]()
    n = len(scop.statements)
    perm = list(dict.fromkeys(i % n for i in order))
    perm += [i for i in range(n) if i not in perm]
    groups, cur = [], []
    for pos, i in enumerate(perm):
        cur.append(i)
        if pos in cuts:
            groups.append(cur)
            cur = []
    if cur:
        groups.append(cur)
    tc = TunedConfig("pluto", fusion="groups",
                     fusion_groups=tuple(tuple(g) for g in groups))
    try:
        _schedule_and_check(scop, tc.scheduler_config())
    except SchedulingError:
        pass                      # loud rejection is a correct outcome


@settings(max_examples=40, deadline=None)
@given(
    name=st.sampled_from(sorted(SMALL_KERNELS)),
    order=st.lists(st.integers(0, 7), min_size=1, max_size=8),
    cuts=st.sets(st.integers(0, 7)),
)
def test_property_arbitrary_partitions(name, order, cuts):
    _check_partition(name, order, cuts)


def test_seeded_partition_sweep():
    """The same property as a seeded sweep — runs without hypothesis."""
    rng = random.Random(20260731)
    names = sorted(SMALL_KERNELS)
    for _ in range(60):
        name = names[rng.randrange(len(names))]
        order = [rng.randrange(8) for _ in range(rng.randint(1, 8))]
        cuts = {rng.randrange(8) for _ in range(rng.randint(0, 4))}
        _check_partition(name, order, cuts)


def test_topological_partitions_always_accepted():
    """Partitions that respect the SCC topological order never raise:
    any grouping of adjacent SCCs is legal by construction."""
    from repro.core.deps import compute_dependences

    for name in ("mm2", "mm3", "mvt"):
        scop = SMALL_KERNELS[name]()
        deps = compute_dependences(scop)
        for d in deps:
            d.satisfied_at = None
        sccs = _scc_groups(scop.statements, deps)
        rng = random.Random(hash(name) & 0xFFFF)
        for _ in range(8):
            groups, cur = [], []
            for scc in sccs:
                cur.extend(scc)
                if rng.random() < 0.5:
                    groups.append(sorted(cur))
                    cur = []
            if cur:
                groups.append(sorted(cur))
            tc = TunedConfig("pluto", fusion="groups",
                             fusion_groups=tuple(tuple(g) for g in groups))
            _schedule_and_check(SMALL_KERNELS[name](), tc.scheduler_config())


# ---------------------------------------------------------------------------
# band-count extremes on 2mm / 3mm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,n_sccs", [("mm2", 4), ("mm3", 6)])
def test_fusion_extremes_band_counts(name, n_sccs):
    """max fusion: one fused outer group with a depth-≥2 permutable
    leading band; no fusion: one outer group per SCC."""
    outs = {}
    for fm in ("smart", "max", "no"):
        cfg = CFG.pluto_style()
        cfg.fusion_mode = fm
        sched = _schedule_and_check(SMALL_KERNELS[name](), cfg)
        outs[fm] = (_outer_groups(sched), sched)
    assert outs["max"][0] == 1
    assert outs["no"][0] == n_sccs
    assert outs["max"][0] <= outs["smart"][0] <= outs["no"][0]
    # max: the leading dims form one fused permutable band of depth ≥ 2
    max_sched = outs["max"][1]
    assert max_sched.bands[0] == max_sched.bands[1]
    # no: the leading dim is the scalar distribution dim
    no_sched = outs["no"][1]
    stmts = no_sched.scop.statements
    assert all(no_sched.rows[s.index][0].kind == "scalar" for s in stmts)


def test_explicit_groups_apply_once():
    """A 'default'-dimension FusionSpec with groups must distribute
    exactly once — not emit scalar dims at every dimension (the
    apply-once scheduler invariant)."""
    scop = SMALL_KERNELS["mm2"]()
    cfg = CFG.pluto_style()
    cfg.fusion = [CFG.FusionSpec("default",
                                 groups=[[0, 1], [2, 3]])]
    sched = _schedule_and_check(scop, cfg)
    assert not sched.fallback
    scalar_dims = [
        d for d in range(sched.n_dims)
        if all(sched.rows[s.index][d].kind == "scalar"
               for s in scop.statements)
    ]
    # one distribution dim from the spec + the final textual-order dim
    assert len(scalar_dims) <= 2
    # every statement still got its full linear depth
    for s in scop.statements:
        lin = [r for r in sched.rows[s.index] if r.kind == "linear"
               and any(r.it_vector(s.dim))]
        assert len(lin) == s.dim
