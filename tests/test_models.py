"""Per-architecture smoke tests: reduced configs, forward + decode on CPU,
shape and NaN assertions (the FULL configs are exercised by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch, runnable_cells
from repro.model import transformer as T


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_loss(arch_id, key):
    cfg = get_arch(arch_id).smoke()
    params = T.init_params(key, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["frontend"] = jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        kw["enc_frontend"] = jnp.ones((b, 16, cfg.d_model), jnp.bfloat16)
    logits, aux = jax.jit(lambda p, t: T.forward(p, cfg, t, **kw))(params, tokens)
    exp_seq = s + (cfg.frontend_len if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_seq, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    loss = T.lm_loss(params, cfg, tokens, labels, **kw)
    assert jnp.isfinite(loss)
    # reasonable initial loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode(arch_id, key):
    cfg = get_arch(arch_id).smoke()
    params = T.init_params(key, cfg)
    b = 2
    cache = T.init_cache(cfg, b, 32)
    memory = None
    if cfg.enc_layers:
        enc = jnp.ones((b, 16, cfg.d_model), jnp.bfloat16) @ params["frontend_proj"]
        pos = jnp.broadcast_to(jnp.arange(16)[None], (b, 16))
        memory, _ = T._run_stack(params["encoder"], cfg, "encoder", enc, pos)
    token = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, new_cache = jax.jit(
        lambda p, t, c: T.decode_step(p, cfg, t, c, jnp.int32(3), memory)
    )(params, token, cache)
    assert logits.shape == (b, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_prefill_matches_decode_prefix():
    """Decoding token-by-token must reproduce prefill logits (same cache
    semantics) — checked on a tiny dense model."""
    cfg = get_arch("granite_3_2b").smoke()
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    b, s = 1, 8
    tokens = jax.random.randint(key, (b, s), 2, cfg.vocab)
    last_logits, _ = T.prefill(params, cfg, tokens)
    # step-by-step decode
    cache = T.init_cache(cfg, b, s + 1)
    for i in range(s):
        logits_i, cache = T.decode_step(params, cfg, tokens[:, i:i + 1],
                                        cache, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(last_logits, np.float32),
                               np.asarray(logits_i, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_long_500k_skips_full_attention():
    cells = runnable_cells()
    assert ("falcon_mamba_7b", "long_500k") in cells
    assert ("jamba_v0_1_52b", "long_500k") in cells
    assert ("gemma3_4b", "long_500k") in cells
    assert ("qwen3_8b", "long_500k") not in cells
    assert ("granite_3_2b", "long_500k") not in cells
    assert len(cells) == 33


def test_pattern_periods():
    assert T.pattern_period(get_arch("jamba_v0_1_52b")) == 8
    assert T.pattern_period(get_arch("gemma3_4b")) == 6
    assert T.pattern_period(get_arch("falcon_mamba_7b")) == 1
    assert T.pattern_period(get_arch("llama4_scout_17b_a16e")) == 2


def test_jamba_layer_mix():
    cfg = get_arch("jamba_v0_1_52b")
    specs = T.layer_specs(cfg)
    attn = [i for i, sp in enumerate(specs) if sp.mixer == "attn"]
    moe = [i for i, sp in enumerate(specs) if sp.ffn == "moe"]
    assert len(attn) == 4 and len(specs) == 32      # 1:7 interleave
    assert len(moe) == 16                            # every other layer


def test_gemma_local_global_mix():
    cfg = get_arch("gemma3_4b")
    specs = T.layer_specs(cfg)
    local = [sp for sp in specs if sp.window]
    glob = [sp for sp in specs if not sp.window]
    assert len(local) + len(glob) == 34
    assert len(local) > 4 * len(glob) - 5            # ≈ 5:1
