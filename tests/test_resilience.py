"""Hardened-pipeline contract: fault injection, deadlines, the
degradation ladder, crash-safe caches, and the autotuner's typed
measurement-failure policy.

The invariants under test mirror the chaos sweep (scripts/chaos_sweep.py)
at unit granularity:

* an armed fault either degrades the answer down the ladder or is
  absorbed by a cache layer — it never escapes as a raw exception;
* every degraded schedule is still *legal* (differential against the
  program-order numpy oracle) and carries provenance;
* degradation is bit-deterministic: same faults → same fingerprints;
* corrupt cache entries are quarantined and counted, never raised;
* degraded results are never persisted (no cache poisoning).
"""
import json
import multiprocessing
import os
import shutil

import numpy as np
import pytest

from repro.core.cbackend import init_arrays
from repro.core.codegen import CodeGenerator, interpret_scop
from repro.core.config import pluto_style, tensor_style
from repro.core.resilience import (FAULT_SITES, LADDER, REGISTRY, Deadline,
                                   DeadlineExceeded, FaultRegistry,
                                   InjectedFault, MeasurementError,
                                   identity_schedule, inject, provenance,
                                   schedule_with_ladder)
from repro.core.schedcache import (ScheduleCache, cached_schedule_scop,
                                   global_cache, load_measurements,
                                   record_measurements, schedule_fingerprint)
from repro.core.scheduler import schedule_scop
from repro.core.scop import Scop
from repro.core.scops_polybench import make_gemm, make_mm2, make_mvt

HAVE_GCC = shutil.which("gcc") is not None

SCALARS = {"alpha": 1.5, "beta": 0.7}


@pytest.fixture(autouse=True)
def _clean_registry():
    REGISTRY.reset()
    yield
    REGISTRY.reset()


def _oracle_check(scop, sched):
    """Scheduled numpy emitter vs program-order oracle — the legality
    differential every ladder rung must pass."""
    fn, src = CodeGenerator(sched).build()
    a1 = init_arrays(scop)
    a2 = {k: v.copy() for k, v in a1.items()}
    sc = {k: SCALARS.get(k, 1.0) for k in scop.scalars}
    interpret_scop(scop, a1, sc)
    fn(**a2, **sc, **scop.params)
    for k in a1:
        np.testing.assert_allclose(a1[k], a2[k], rtol=1e-7, atol=1e-9,
                                   err_msg=f"{scop.name} {k}\n{src}")


# ---------------------------------------------------------------------------
# fault registry semantics
# ---------------------------------------------------------------------------


def test_registry_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        REGISTRY.arm("no.such.site")


def test_registry_times_semantics():
    reg = FaultRegistry()
    reg.arm("ilp.solve", times=2)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            reg.fire("ilp.solve")
    reg.fire("ilp.solve")                    # exhausted: no-op
    assert reg.fired["ilp.solve"] == 2
    reg.arm("ilp.solve", times=-1)           # unlimited
    for _ in range(5):
        with pytest.raises(InjectedFault):
            reg.fire("ilp.solve")
    assert reg.fired["ilp.solve"] == 7


def test_registry_skip_lets_early_calls_pass():
    reg = FaultRegistry()
    reg.arm("ilp.solve", times=1, skip=2)
    reg.fire("ilp.solve")
    reg.fire("ilp.solve")                    # two clean passes
    with pytest.raises(InjectedFault):
        reg.fire("ilp.solve")
    assert reg.fired["ilp.solve"] == 1


def test_registry_delay_only_arm():
    reg = FaultRegistry()
    reg.arm("measure", error=None, times=1, delay_s=0.0)
    reg.fire("measure")                      # delays (0 s) but never raises
    assert reg.fired["measure"] == 1


def test_registry_seeded_probabilistic_determinism():
    def pattern():
        reg = FaultRegistry()
        reg.arm("ilp.solve", times=-1, p=0.5, seed=1234)
        out = []
        for _ in range(20):
            try:
                reg.fire("ilp.solve")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = pattern(), pattern()
    assert a == b
    assert 0 < sum(a) < 20                   # actually probabilistic


def test_registry_custom_error_and_inject_cm():
    with inject("fm.bounds", error=RuntimeError, times=1):
        with pytest.raises(RuntimeError):
            REGISTRY.fire("fm.bounds")
    REGISTRY.fire("fm.bounds")               # context manager disarmed it


def test_fault_sites_frozen():
    # the chaos sweep enumerates this tuple; renaming a site silently
    # un-covers its call site
    assert FAULT_SITES == (
        "ilp.solve", "farkas.project", "fm.bounds", "cache.read",
        "cache.write", "cc.compile", "cc.run", "measure",
        "pool.dispatch")
    assert LADDER == ("full", "partial", "pluto_default", "identity")


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_none_never_expires():
    d = Deadline(None)
    assert not d.expired() and d.remaining() == float("inf")
    d.check("anywhere")                      # no-op


def test_deadline_breach_carries_stage():
    t = [0.0]
    d = Deadline(1.0, clock=lambda: t[0])
    d.check("early")
    t[0] = 2.0
    assert d.expired() and d.remaining() < 0
    with pytest.raises(DeadlineExceeded) as ei:
        d.check("scheduler dim 2")
    assert ei.value.stage == "scheduler dim 2"
    assert ei.value.budget_s == 1.0


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------


def test_ladder_clean_is_level0_and_matches_plain_schedule():
    scop = make_gemm(10)
    sched = schedule_with_ladder(scop, tensor_style())
    prov = provenance(sched)
    assert prov == {"degraded": False, "fallback_level": 0, "rung": "full",
                    "reasons": []}
    plain = schedule_scop(make_gemm(10), tensor_style())
    assert schedule_fingerprint(sched) == schedule_fingerprint(plain)


def test_ladder_partial_prefix_salvage():
    """A fault after the first completed dimension salvages that dim as
    a legal prefix (rung 1) instead of throwing the work away."""
    scop = make_gemm(10)
    REGISTRY.arm("ilp.solve", times=-1, skip=1)   # dim 0 solves, rest fail
    sched = schedule_with_ladder(scop, pluto_style())
    REGISTRY.reset()
    prov = provenance(sched)
    assert prov["fallback_level"] == 1 and prov["rung"] == "partial"
    assert sched.degraded and prov["reasons"]
    _oracle_check(make_gemm(10), sched)


def test_ladder_solver_loss_salvage_is_legal():
    # scalar-distribution dims complete without the ILP, so even a
    # forever-armed solver fault leaves a salvageable prefix
    scop = make_mm2(8)
    REGISTRY.arm("ilp.solve", times=-1)
    sched = schedule_with_ladder(scop, tensor_style())
    REGISTRY.reset()
    assert sched.degraded and sched.fallback_level >= 1
    _oracle_check(make_mm2(8), sched)


def test_ladder_tree_loss_walks_to_identity():
    """When the tree builder is down on every rung, the ladder must
    walk all the way to program-order identity (which tolerates a
    missing tree) rather than surface the FM fault."""
    scop = make_mm2(8)
    REGISTRY.arm("fm.bounds", times=-1)
    sched = schedule_with_ladder(scop, tensor_style(), with_tree=True)
    REGISTRY.reset()
    assert provenance(sched)["rung"] == "identity"
    assert sched.fallback_level == 3
    _oracle_check(make_mm2(8), sched)


def test_ladder_expired_deadline_is_identity_and_legal():
    scop = make_mvt(12)
    sched = schedule_with_ladder(scop, tensor_style(), deadline=Deadline(0.0))
    assert sched.degraded and sched.fallback_level == 3
    assert any("Deadline" in r or "deadline" in r
               for r in sched.degrade_reasons)
    _oracle_check(make_mvt(12), sched)


def test_ladder_deterministic_under_identical_faults():
    def run():
        REGISTRY.reset()
        REGISTRY.arm("farkas.project", times=1)
        sched = schedule_with_ladder(make_mm2(8), tensor_style())
        REGISTRY.reset()
        return schedule_fingerprint(sched), sched.fallback_level

    (fp1, l1), (fp2, l2) = run(), run()
    assert fp1 == fp2 and l1 == l2 and l1 > 0


def test_identity_schedule_is_legal_without_solver():
    for mk in (lambda: make_gemm(9), lambda: make_mm2(7)):
        scop = mk()
        sched = identity_schedule(scop)
        assert sched.fallback and sched.stats.get("identity")
        _oracle_check(mk(), sched)


def test_degraded_schedules_never_published(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path / "pool"))
    REGISTRY.arm("ilp.solve", times=-1)
    sched = schedule_with_ladder(make_gemm(10), tensor_style(), cache=cache)
    REGISTRY.reset()
    assert sched.degraded
    assert cache.mem == {}                    # nothing poisoned in memory
    pkls = [f for _, _, fs in os.walk(tmp_path) for f in fs
            if f.endswith(".pkl")]
    assert pkls == []                         # ... or on disk
    # and the next, fault-free call serves the clean schedule
    clean = schedule_with_ladder(make_gemm(10), tensor_style(), cache=cache)
    assert not clean.degraded
    assert schedule_fingerprint(clean) != schedule_fingerprint(sched)


def test_provenance_defaults_for_pre_resilience_objects():
    class Old:                               # simulates a stale pickle
        pass

    assert provenance(Old()) == {"degraded": False, "fallback_level": 0,
                                 "rung": "full", "reasons": []}


# ---------------------------------------------------------------------------
# schedule cache: stats, quarantine, eviction, retry
# ---------------------------------------------------------------------------


def _put_one(cache, scop=None):
    scop = scop or make_gemm(10)
    return cached_schedule_scop(scop, tensor_style(), cache=cache)


def test_cache_stats_roundtrip(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    _put_one(cache)
    assert cache.stats.misses == 1
    _put_one(cache)
    assert cache.stats.hits == 1
    # a fresh instance reads the disk tier
    c2 = ScheduleCache(cache_dir=str(tmp_path))
    _put_one(c2)
    assert c2.stats.disk_hits == 1 and c2.stats["disk_hits"] == 1
    assert set(c2.stats.as_dict()) == {"hits", "misses", "disk_hits",
                                       "corrupt", "evicted", "bytes",
                                       "latency_saved_s", "push_capped"}


def test_cache_corrupt_pickle_quarantined(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    fp = schedule_fingerprint(_put_one(cache))
    pkls = [os.path.join(r, f) for r, _, fs in os.walk(tmp_path)
            for f in fs if f.endswith(".pkl")]
    assert len(pkls) == 1
    with open(pkls[0], "wb") as f:
        f.write(b"\x80\x04 truncated garbage")
    c2 = ScheduleCache(cache_dir=str(tmp_path))
    again = _put_one(c2)                      # quarantine + recompute
    assert schedule_fingerprint(again) == fp
    assert c2.stats.corrupt == 1 and c2.stats.misses == 1
    qdir = tmp_path / "quarantine"
    assert qdir.is_dir() and list(qdir.iterdir())
    # the recompute re-published a *good* entry at the same path
    c3 = ScheduleCache(cache_dir=str(tmp_path))
    _put_one(c3)
    assert c3.stats.disk_hits == 1 and c3.stats.corrupt == 0


def test_cache_injected_read_fault_served_by_retry(tmp_path):
    """A transient read fault is retried and the intact entry served —
    only persistent corruption quarantines."""
    cache = ScheduleCache(cache_dir=str(tmp_path))
    fp = schedule_fingerprint(_put_one(cache))
    c2 = ScheduleCache(cache_dir=str(tmp_path))
    with inject("cache.read", times=1):
        again = _put_one(c2)
    assert schedule_fingerprint(again) == fp
    assert c2.stats.disk_hits == 1 and c2.stats.corrupt == 0


def test_cache_write_fault_degrades_to_uncached(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    with inject("cache.write", times=-1):
        sched = _put_one(cache)
    assert not sched.degraded                 # write trouble ≠ degraded
    pkls = [f for _, _, fs in os.walk(tmp_path) for f in fs
            if f.endswith(".pkl")]
    assert pkls == []                         # nothing on disk ...
    assert cache.mem                          # ... but the mem tier serves


def test_cache_mem_eviction_counted():
    cache = ScheduleCache(disk=False, mem_cap=2)
    for key in ("a", "b", "c", "d"):
        cache.put(key, object())
    assert len(cache.mem) == 2
    assert cache.stats.evicted == 2
    assert list(cache.mem) == ["c", "d"]      # FIFO


def test_global_cache_exposes_stats():
    assert hasattr(global_cache().stats, "corrupt")


# ---------------------------------------------------------------------------
# measurements pool: concurrent appends stay line-atomic
# ---------------------------------------------------------------------------


def _writer(args):
    cache_dir, wid, n = args
    from repro.core.schedcache import ScheduleCache, record_measurements
    cache = ScheduleCache(cache_dir=cache_dir)
    for i in range(n):
        record_measurements(cache, [{"kernel": f"w{wid}", "label": str(i),
                                     "feats": [float(wid)] * 4,
                                     "seconds": 0.001 * i, "v": 999}])
    return wid


def test_measurements_concurrent_writers(tmp_path):
    n_writers, n_rows = 4, 25
    args = [(str(tmp_path), w, n_rows) for w in range(n_writers)]
    with multiprocessing.Pool(n_writers) as pool:
        assert sorted(pool.map(_writer, args)) == list(range(n_writers))
    cache = ScheduleCache(cache_dir=str(tmp_path))
    rows = load_measurements(cache, 999)
    assert len(rows) == n_writers * n_rows    # no torn/interleaved lines
    from repro.core.schedcache import MEASUREMENTS_FILE
    raw = (tmp_path / MEASUREMENTS_FILE).read_text().splitlines()
    for ln in raw:
        json.loads(ln)                        # every line parses


def test_measurements_read_fault_returns_empty(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path))
    record_measurements(cache, [{"v": 7, "kernel": "k", "label": "l",
                                 "feats": [0.0], "seconds": 1.0}])
    with inject("cache.read", times=1):
        assert load_measurements(cache, 7) == []
    assert len(load_measurements(cache, 7)) == 1


# ---------------------------------------------------------------------------
# crunner: typed measurement errors + crash-safe result cache
# ---------------------------------------------------------------------------

TINY_C = """
#include <stdio.h>
#define REPEATS 1
int main(void) {
    double acc = 0.0;
    for (int r = 0; r < REPEATS; ++r)
        for (int i = 0; i < 100; ++i) acc += (double)i;
    printf("TIME_S 0.05 CHECKSUM %.17g\\n", acc);
    return 0;
}
"""


@pytest.fixture()
def cc_cache(tmp_path, monkeypatch):
    import repro.core.crunner as CR
    d = tmp_path / "cc"
    monkeypatch.setattr(CR, "CACHE_DIR", d)
    return d


def test_source_blowup_is_typed(cc_cache):
    from repro.core.crunner import MAX_SOURCE_BYTES, compile_and_run
    with pytest.raises(MeasurementError) as ei:
        compile_and_run("x" * (MAX_SOURCE_BYTES + 1), tag="blow")
    assert ei.value.kind == "source_blowup" and ei.value.phase == "codegen"
    assert ei.value.tag == "blow"


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_compile_failure_is_typed(cc_cache):
    from repro.core.crunner import compile_and_run
    with pytest.raises(MeasurementError) as ei:
        compile_and_run("int main(void) { return syntax error; }", tag="bad")
    assert ei.value.kind == "compile_failed" and ei.value.phase == "compile"


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_run_failure_and_parse_are_typed(cc_cache):
    from repro.core.crunner import compile_and_run
    with pytest.raises(MeasurementError) as ei:
        compile_and_run("int main(void) { return 9; }", tag="rc")
    assert ei.value.kind == "run_failed" and ei.value.phase == "run"
    with pytest.raises(MeasurementError) as ei:
        compile_and_run('#include <stdio.h>\n'
                        'int main(void){ printf("gibberish\\n"); return 0; }',
                        tag="parse")
    assert ei.value.kind == "parse" and ei.value.phase == "parse"


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_run_timeout_is_typed(cc_cache):
    from repro.core.crunner import compile_and_run
    with pytest.raises(MeasurementError) as ei:
        compile_and_run("#include <unistd.h>\n"
                        "int main(void) { sleep(30); return 0; }",
                        tag="hang", timeout=1)
    assert ei.value.kind == "run_timeout" and ei.value.phase == "run"


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_injected_cc_faults_are_typed(cc_cache):
    from repro.core.crunner import measure_source
    for site, phase in (("cc.compile", "compile"), ("cc.run", "run"),
                        ("measure", "measure")):
        with inject(site, times=1):
            with pytest.raises(MeasurementError) as ei:
                measure_source(TINY_C, tag="inj", use_cache=False)
        assert (ei.value.kind, ei.value.phase) == ("injected", phase), site


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_crunner_corrupt_cache_quarantined(cc_cache):
    from repro.core.crunner import compile_and_run
    r1 = compile_and_run(TINY_C, tag="corrupt")
    files = list(cc_cache.glob("*.json"))
    assert files
    files[0].write_text('{"seconds": 0.1, "checksum":')   # torn write
    r2 = compile_and_run(TINY_C, tag="corrupt")           # recompute
    assert r2.checksum == r1.checksum and not r2.cached
    qdir = cc_cache / "quarantine"
    assert qdir.is_dir() and list(qdir.iterdir())
    r3 = compile_and_run(TINY_C, tag="corrupt")           # re-cached
    assert r3.cached


# ---------------------------------------------------------------------------
# autotuner failure policy
# ---------------------------------------------------------------------------


def _tiny_scop():
    s = Scop("resil_mm", params={"N": 20})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("C[i,j] = 0.0")
            with s.loop("k", 0, "N"):
                s.stmt("C[i,j] = C[i,j] + A[i,k] * B[k,j]")
    return s


def test_autotune_deadline_truncates_degraded():
    from repro.core.autotune import autotune
    res = autotune(_tiny_scop(), measure=False, use_cache=False,
                   deadline=Deadline(0.0))
    assert res.degraded and res.reasons
    assert res.config is not None             # still an answer


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_retries_transient_fault_once(cc_cache):
    from repro.core.autotune import autotune
    with inject("cc.compile", times=1):
        res = autotune(_tiny_scop(), measure=True, top_k=2, use_cache=False)
    assert res.source == "measured" and not res.degraded
    assert any(f["kind"] == "injected" for f in res.failures)


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_survives_total_measurement_loss(cc_cache, tmp_path):
    from repro.core.autotune import autotune
    cache = ScheduleCache(cache_dir=str(tmp_path / "pool"))
    with inject("cc.compile", times=-1):
        res = autotune(_tiny_scop(), measure=True, top_k=2, cache=cache,
                       use_cache=True)
    assert res.degraded                       # ref failed: static fallback
    assert res.failures
    # a degraded result is never persisted: the next call re-tunes
    res2 = autotune(_tiny_scop(), measure=False, cache=cache, use_cache=True)
    assert res2.source != "cache"


def test_tuned_result_provenance_roundtrip():
    from repro.core.autotune import TunedConfig, TunedResult
    r = TunedResult(TunedConfig("pluto"), degraded=True,
                    reasons=["deadline"], failures=[{"kind": "parse"}])
    r2 = TunedResult.from_dict(r.to_dict())
    assert (r2.degraded, r2.reasons, r2.failures) == \
        (True, ["deadline"], [{"kind": "parse"}])


# ---------------------------------------------------------------------------
# kernel-plan provenance
# ---------------------------------------------------------------------------


def test_kernel_plan_carries_ladder_provenance(monkeypatch):
    from repro.core import akg
    # the shared schedule cache would (correctly) absorb the fault by
    # serving the warm entry; isolate the plan so the fault reaches the
    # scheduler and the ladder provenance is exercised
    monkeypatch.setattr(akg, "global_cache",
                        lambda: ScheduleCache(disk=False))
    akg.plan_matmul.cache_clear()
    clean = akg.plan_matmul(64, 64, 64)
    assert (clean.degraded, clean.fallback_level, clean.degrade_reasons) == \
        (False, 0, ())
    akg.plan_matmul.cache_clear()
    REGISTRY.arm("ilp.solve", times=-1)
    degraded = akg.plan_matmul(64, 64, 64)
    REGISTRY.reset()
    assert degraded.degraded and degraded.fallback_level > 0
    assert degraded.degrade_reasons
    # degraded plans are not memoized: the fault cleared, so re-planning
    # must return the clean plan again
    replanned = akg.plan_matmul(64, 64, 64)
    assert not replanned.degraded
    assert akg.plan_matmul(64, 64, 64) is replanned
