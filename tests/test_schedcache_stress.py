"""Multi-process schedcache stress: racing writers, readers mid-rename,
measurement-pool compaction under concurrency, and the ranker-threshold
contract compaction must preserve.

Real forked processes (multiprocessing on POSIX), one shared on-disk
pool — the contracts under test are exactly the ones the schedd daemon
and N client processes rely on: atomic publish (temp + rename) means a
reader sees the old entry, the new entry, or a miss — never a torn
pickle; O_APPEND batches and compaction rewrites serialized on a
stable sidecar flock mean the measurement pool never loses or tears a
row.
"""
import json
import multiprocessing as mp
import os

import pytest

from repro.core.config import tensor_style
from repro.core.ranker import FEATURE_NAMES, FEATURE_VERSION, fit_ranker
from repro.core.resilience import schedule_with_ladder
from repro.core.schedcache import (MEASUREMENTS_FILE, ScheduleCache,
                                   compact_measurements, load_measurements,
                                   record_measurements, schedule_fingerprint,
                                   schedule_key)
from repro.core.scop import Scop

pytestmark = pytest.mark.skipif(os.name != "posix",
                                reason="fork + flock are POSIX")


def stress_scop():
    s = Scop("stress", params={"N": 20})
    with s.loop("i", 0, "N"):
        with s.loop("j", 0, "N"):
            s.stmt("A[i,j] = A[i,j] + B[j,i]")
    return s


def _writer_put(pool, key, n_puts):
    cache = ScheduleCache(cache_dir=pool)
    sched = schedule_with_ladder(stress_scop(), tensor_style())
    for _ in range(n_puts):
        cache.put(key, sched)


def _reader_get(pool, key, expect_fp, n_gets, errq):
    cache = ScheduleCache(cache_dir=pool)
    for _ in range(n_gets):
        cache.mem.clear()              # force the disk tier every read
        hit = cache.get(key)
        if hit is not None and schedule_fingerprint(hit) != expect_fp:
            errq.put(f"torn/foreign read: {schedule_fingerprint(hit)[:12]}")
            return
    errq.put(None)


def test_forked_writers_same_key_reader_mid_rename(tmp_path):
    pool = str(tmp_path / "pool")
    scop = stress_scop()
    cfg = tensor_style()
    sched = schedule_with_ladder(scop, cfg)
    expect_fp = schedule_fingerprint(sched)
    key = schedule_key(scop, cfg, "lex")
    assert key is not None

    ctx = mp.get_context("fork")
    errq = ctx.Queue()
    writers = [ctx.Process(target=_writer_put, args=(pool, key, 25))
               for _ in range(4)]
    readers = [ctx.Process(target=_reader_get,
                           args=(pool, key, expect_fp, 200, errq))
               for _ in range(2)]
    for p in writers + readers:
        p.start()
    for p in writers + readers:
        p.join(timeout=120)
        assert p.exitcode == 0
    reader_reports = [errq.get(timeout=10) for _ in readers]
    assert reader_reports == [None, None], reader_reports

    # the settled pool serves the exact schedule, stats tallied cleanly:
    # every atomic-rename publish means zero corrupt entries — at most
    # one could ever be quarantined, and only by an actual tear
    final = ScheduleCache(cache_dir=pool)
    hit = final.get(key)
    assert hit is not None
    assert schedule_fingerprint(hit) == expect_fp
    assert final.stats.hits == 1 and final.stats.disk_hits == 1
    assert final.stats.corrupt <= 1
    assert final.stats.corrupt == 0    # rename is atomic: no tear at all
    qdir = os.path.join(pool, "quarantine")
    assert not os.path.isdir(qdir) or len(os.listdir(qdir)) <= 1


def _writer_measurements(pool, wid, n_batches, max_bytes):
    cache = ScheduleCache(cache_dir=pool)
    for b in range(n_batches):
        rows = [{"kernel": f"k{wid}", "label": f"l{b}_{i}",
                 "feats": [float(i)] * len(FEATURE_NAMES),
                 "seconds": 0.01 + i * 1e-4,
                 "v": 2, "fv": FEATURE_VERSION}
                for i in range(4)]
        record_measurements(cache, rows, max_bytes=max_bytes)


def test_concurrent_append_and_compaction_never_tears(tmp_path):
    pool = str(tmp_path / "pool")
    ctx = mp.get_context("fork")
    # max_bytes small enough that compaction triggers repeatedly while
    # other writers are mid-append — the sidecar pool lock is what
    # keeps their batches out of the orphaned pre-compaction file
    writers = [ctx.Process(target=_writer_measurements,
                           args=(pool, wid, 30, 4096))
               for wid in range(4)]
    for p in writers:
        p.start()
    for p in writers:
        p.join(timeout=120)
        assert p.exitcode == 0

    path = os.path.join(pool, MEASUREMENTS_FILE)
    with open(path) as f:
        lines = f.read().splitlines()
    for ln in lines:                   # no torn/interleaved rows at all
        row = json.loads(ln)
        assert row["kernel"].startswith("k")
    # compaction dedups by fingerprint; every row here is distinct, so
    # ALL 4×30×4 of them must survive — a batch appended into the
    # orphaned pre-compaction inode would be silently lost, and the
    # sidecar pool lock exists precisely to prevent that
    cache = ScheduleCache(cache_dir=pool)
    compact_measurements(cache, force=True)
    rows = load_measurements(cache)
    fps = [(r["kernel"], r["label"]) for r in rows]
    assert len(fps) == len(set(fps))   # one row per fingerprint
    assert len(fps) == 4 * 30 * 4      # and none lost to compaction races


def test_compaction_keeps_newest_and_preserves_order(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path / "pool"))
    for gen in range(3):
        record_measurements(cache, [
            {"kernel": "k", "label": f"l{i}",
             "feats": [1.0] * len(FEATURE_NAMES),
             "seconds": 0.01 * (gen + 1), "v": 2, "fv": FEATURE_VERSION}
            for i in range(6)])
    assert compact_measurements(cache, force=True)
    rows = load_measurements(cache)
    assert len(rows) == 6
    assert all(abs(r["seconds"] - 0.03) < 1e-12 for r in rows)
    # rows whose fingerprint can't be computed survive compaction
    record_measurements(cache, [{"weird": True}])
    assert compact_measurements(cache, force=True)
    rows = load_measurements(cache)
    assert len(rows) == 7
    assert any(r.get("weird") for r in rows)


def test_compaction_preserves_ranker_training_threshold(tmp_path):
    """The ≥32-usable-triples contract: a pool with enough *distinct*
    measurements to train the ranker must still train after compaction
    bounds it — dedup removes superseded repeats, never coverage."""
    cache = ScheduleCache(cache_dir=str(tmp_path / "pool"))
    # 2 kernels × 20 labels = 40 distinct fingerprints, written 3× each
    # (re-measurements) so the raw pool holds 120 rows
    for gen in range(3):
        for kern in ("gemm", "mvt"):
            record_measurements(cache, [
                {"kernel": kern, "label": f"cfg{i}",
                 "feats": [float((i * 7 + j) % 5) + (0.5 if kern == "gemm"
                                                     else 0.0)
                           for j in range(len(FEATURE_NAMES))],
                 "seconds": 0.01 + i * 1e-3 + gen * 1e-5,
                 "v": 2, "fv": FEATURE_VERSION}
                for i in range(20)])
    before = load_measurements(cache)
    assert len(before) == 120
    assert fit_ranker(before) is not None

    assert compact_measurements(cache, force=True)
    after = load_measurements(cache)
    assert len(after) == 40            # newest of each triple kept
    ranker = fit_ranker(after)
    assert ranker is not None          # still ≥32 usable, ≥2 kernels
    # and the kept rows are the newest generation
    assert all(abs((r["seconds"] - 0.01 - 2e-5) % 1e-3) < 1e-9
               or r["seconds"] >= 0.01 for r in after)


def test_record_trigger_bounds_file_size(tmp_path):
    cache = ScheduleCache(cache_dir=str(tmp_path / "pool"))
    path = os.path.join(cache.dir, MEASUREMENTS_FILE)
    # one fingerprint re-measured forever: the pool must stay bounded
    for gen in range(300):
        record_measurements(cache, [
            {"kernel": "k", "label": "only", "feats": [0.0] * 12,
             "seconds": 1e-3 * gen, "v": 2, "fv": FEATURE_VERSION}],
            max_bytes=2048)
    # bounded: the trigger keeps the file near the cap (a few rows of
    # slack accumulate between threshold crossings, never unbounded)
    assert os.path.getsize(path) < 2048 + 1024
    # and a settle-down compaction leaves exactly the newest row
    assert compact_measurements(cache, force=True)
    rows = load_measurements(cache)
    assert len(rows) == 1
    assert abs(rows[0]["seconds"] - 0.299) < 1e-9
