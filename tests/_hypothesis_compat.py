"""Optional-hypothesis shim for the test suite.

The tier-1 environment does not ship ``hypothesis``; hard imports made
three whole test modules fail at *collection*, taking all their plain
(non-property) tests down with them.  Importing ``given``/``settings``/
``st`` from here instead keeps plain tests running everywhere:

* hypothesis installed  -> re-export the real API, property tests run;
* hypothesis missing    -> property tests are individually skipped via
  an inert ``given`` that wraps the test in ``pytest.mark.skip`` (the
  per-test equivalent of ``pytest.importorskip("hypothesis")``), and
  ``st`` becomes a chainable no-op strategy stub so module-level
  strategy definitions still evaluate.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """Inert stand-in for ``hypothesis.strategies``.

        Any attribute access yields a factory returning another stub, so
        arbitrary module-level strategy expressions evaluate fine;
        ``st.composite`` returns the wrapped function's name as a no-op
        callable so ``@st.composite``-decorated builders stay callable.
        """

        def __getattr__(self, name):
            if name == "composite":
                return lambda f: (lambda *a, **k: None)

            def factory(*_a, **_k):
                return _StrategyStub()

            return factory

    st = _StrategyStub()
