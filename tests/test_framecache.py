"""FrameCache: the latency-saved-weighted frame eviction policy.

Pins the two contracts the schedd daemon relies on:

* **accounting** — CacheStats rows (hits/misses/evicted/bytes/
  latency_saved_s) stay exact through put/get/replace/evict/clear;
* **FIFO dominance** — on any replayed admission trace with uniform
  frame sizes and a fixed per-key compute cost, the total compute
  seconds retained is >= what PR 7's FIFO policy would have kept.
  (That is the provable regime: evict-min-score-including-newcomer
  keeps exactly the top-``cap`` scores seen, and FIFO's retained set is
  some other <=cap subset of the same keys.  With unequal frame sizes
  under a byte cap the claim does NOT hold in general — knapsack — so
  both the test and the daemon's gated guarantee stick to entry caps.)

The dominance property runs twice: a seeded 300-trace sweep that always
runs, and a hypothesis version (via the ``_hypothesis_compat`` shim)
that explores adversarial traces when hypothesis is installed (CI).
"""
import random

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.schedcache import CacheStats, FrameCache

SIZE = 64          # uniform frame size: the provable-dominance regime


def frame(byte=b"x"):
    return byte * SIZE


def cost_of(key: int) -> float:
    """Fixed per-key compute cost (distinct across keys)."""
    return 0.013 * (key + 1)


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def test_hit_miss_and_latency_saved_accounting():
    fc = FrameCache(cap_entries=8)
    assert fc.get("a") is None
    assert fc.stats.misses == 1
    assert fc.put("a", frame(), 2.5)
    assert fc.get("a") == frame()
    assert fc.get("a") == frame()
    assert fc.stats.hits == 2
    assert fc.stats.latency_saved_s == pytest.approx(5.0)
    assert "a" in fc and len(fc) == 1


def test_entry_cap_evicts_lowest_score_first():
    fc = FrameCache(cap_entries=2)
    fc.put("cheap", frame(), 0.001)
    fc.put("dear", frame(), 5.0)
    fc.put("mid", frame(), 1.0)          # over cap: "cheap" must go
    assert "cheap" not in fc
    assert "dear" in fc and "mid" in fc
    assert fc.stats.evicted == 1
    assert fc.retained_latency_s() == pytest.approx(6.0)


def test_newcomer_scoring_below_everything_is_rejected():
    fc = FrameCache(cap_entries=2)
    fc.put("a", frame(), 5.0)
    fc.put("b", frame(), 4.0)
    retained = fc.put("c", frame(), 0.001)   # worst score in the cache
    assert not retained
    assert "c" not in fc and "a" in fc and "b" in fc
    assert fc.stats.evicted == 1             # the rejection is counted


def test_byte_cap_enforced_and_bytes_exact():
    fc = FrameCache(cap_entries=100, cap_bytes=3 * SIZE)
    for i in range(5):
        fc.put(i, frame(), cost_of(i))
    assert fc.stats.bytes <= 3 * SIZE
    assert fc.stats.bytes == len(fc) * SIZE
    assert fc.stats.evicted == 2


def test_replace_updates_bytes_and_preserves_hits():
    fc = FrameCache(cap_entries=4)
    fc.put("k", b"a" * 10, 1.0)
    fc.get("k")
    fc.put("k", b"b" * 30, 2.0)          # re-admit: new frame, same key
    assert fc.stats.bytes == 30
    assert fc._entries["k"].hits == 1    # hit history survives replace
    assert fc.get("k") == b"b" * 30
    assert len(fc) == 1 and fc.stats.evicted == 0


def test_clear_resets_occupancy_not_history():
    fc = FrameCache(cap_entries=4)
    fc.put("a", frame(), 1.0)
    fc.get("a")
    fc.clear()
    assert len(fc) == 0 and fc.stats.bytes == 0
    assert fc.stats.hits == 1            # lifetime stats survive clear


def test_snapshot_shape():
    fc = FrameCache(cap_entries=4, cap_bytes=1 << 20)
    fc.put("a", frame(), 2.0)
    fc.put("b", frame(), 0.5)
    snap = fc.snapshot()
    assert snap["entries"] == 2
    assert snap["cap_entries"] == 4 and snap["cap_bytes"] == 1 << 20
    assert snap["bytes"] == 2 * SIZE
    assert snap["retained_latency_s"] == pytest.approx(2.5)
    assert snap["min_score"] == pytest.approx(0.5 / SIZE)
    assert snap["max_score"] == pytest.approx(2.0 / SIZE)
    assert snap["stats"]["evicted"] == 0


def test_shared_stats_object():
    stats = CacheStats()
    fc = FrameCache(cap_entries=2, stats=stats)
    fc.put("a", frame(), 1.0)
    fc.get("a")
    assert stats.hits == 1 and stats.bytes == SIZE


# ---------------------------------------------------------------------------
# FIFO dominance
# ---------------------------------------------------------------------------


def fifo_retained(trace, cap):
    """PR 7's policy replayed: on admission of a new key to a full
    cache, evict the oldest insertion.  Returns retained compute_s."""
    d = {}
    for key in trace:
        if key in d:
            continue                     # warm: PR 7 served the frame,
        if len(d) >= cap:                # no re-admission
            d.pop(next(iter(d)))
        d[key] = cost_of(key)
    return sum(d.values())


def scored_retained(trace, cap):
    fc = FrameCache(cap_entries=cap, cap_bytes=1 << 30)
    for key in trace:
        if fc.get(key) is None:
            fc.put(key, frame(), cost_of(key))
    return fc.retained_latency_s()


def test_retained_latency_dominates_fifo_seeded_sweep():
    rng = random.Random(0xF0F0)
    for _ in range(300):
        cap = rng.randint(1, 8)
        trace = [rng.randrange(12) for _ in range(rng.randint(0, 80))]
        scored = scored_retained(trace, cap)
        fifo = fifo_retained(trace, cap)
        assert scored >= fifo - 1e-12, (trace, cap, scored, fifo)


def test_retained_equals_top_cap_of_seen_keys():
    """Stronger than dominance: with uniform sizes the retained set is
    exactly the top-``cap`` compute costs among distinct keys seen."""
    trace = [3, 0, 7, 1, 7, 2, 5, 0, 4]
    cap = 3
    scored = scored_retained(trace, cap)
    best = sum(sorted((cost_of(k) for k in set(trace)), reverse=True)[:cap])
    assert scored == pytest.approx(best)


@given(st.lists(st.integers(min_value=0, max_value=11), max_size=80),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=200, deadline=None)
def test_retained_latency_dominates_fifo_property(trace, cap):
    assert scored_retained(trace, cap) >= fifo_retained(trace, cap) - 1e-12
