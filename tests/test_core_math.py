"""Affine parser + exact linear algebra properties."""

from _hypothesis_compat import given, settings, st

from repro.core.affine import (affine_eval, parse_affine, parse_constraint)
from repro.core.linalg_q import eye, inverse, mat, matmul, nullspace, orth_complement_basis, orth_complement_rows, rank


def test_parse_basic():
    e = parse_affine("2*i + j - N + 3")
    assert e == {"i": 2, "j": 1, "N": -1, 1: 3}
    assert parse_affine("-(i - 1)") == {"i": -1, 1: 1}
    assert parse_affine("16*l + kv") == {"l": 16, "kv": 1}
    assert parse_affine("0") == {1: 0}


def test_parse_constraint_normalization():
    e, k = parse_constraint("i <= N - 1")
    assert k == ">=0" and e == {"i": -1, "N": 1, 1: -1}
    e, k = parse_constraint("x < 1")       # strict → integerized
    assert e == {"x": -1, 1: 0} and k == ">=0"
    e, k = parse_constraint("a == b")
    assert k == "==0" and e == {"a": 1, "b": -1}


@settings(max_examples=30, deadline=None)
@given(st.integers(-9, 9), st.integers(-9, 9), st.integers(-9, 9))
def test_parse_eval_roundtrip(a, b, c):
    e = parse_affine(f"{a}*i + {b}*j + {c}")
    assert affine_eval(e, {"i": 2, "j": -3}) == 2 * a - 3 * b + c


def test_rank_inverse():
    m = mat([[1, 2], [3, 5]])
    assert rank(m) == 2
    inv = inverse(m)
    assert matmul(m, inv) == eye(2)


def test_nullspace_orthogonal():
    m = mat([[1, 1, 0]])
    ns = nullspace(m)
    assert len(ns) == 2
    for v in ns:
        assert sum(a * b for a, b in zip(m[0], v)) == 0


def test_orth_complement_paper_eq3():
    # after finding (1, 1), the complement of its row space
    rows = orth_complement_rows(mat([[1, 1]]), 2)
    # projector rows sum to zero — the degenerate case the basis avoids
    s = [sum(col) for col in zip(*rows)]
    assert all(x == 0 for x in s)
    basis = orth_complement_basis(mat([[1, 1]]), 2)
    assert len(basis) == 1 and basis[0][0] == -basis[0][1]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(-3, 3), min_size=3, max_size=3),
                min_size=1, max_size=2))
def test_orth_basis_is_orthogonal_property(rows_in):
    m = mat(rows_in)
    r = rank(m)
    basis = orth_complement_basis(m, 3)
    assert len(basis) == 3 - r
    for b in basis:
        for row in m:
            assert sum(x * y for x, y in zip(row, b)) == 0
