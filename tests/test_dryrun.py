"""Dry-run smoke: one small cell through lower+compile+roofline in a
subprocess (the 512-device XLA flag must be set before jax init, so it
cannot run inside the main pytest process)."""
import json
import os
import subprocess
import sys
from pathlib import Path


ROOT = Path(__file__).resolve().parent.parent


def _run(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT)


def test_dryrun_smallest_cell_single_pod():
    cp = _run(["--arch", "qwen3_0_6b", "--shape", "decode_32k",
               "--single-pod-only"])
    assert "OK" in cp.stdout, cp.stdout + cp.stderr[-2000:]
    art = ROOT / "artifacts" / "dryrun" / \
        "qwen3_0_6b__decode_32k__pod16x16__baseline.json"
    d = json.loads(art.read_text())
    assert d["ok"] and d["devices"] == 256
    rf = d["roofline"]
    assert rf["compute_s"] > 0 and rf["memory_s"] > 0
    assert rf["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < rf["model_flops_frac"] <= 1.5


def test_dryrun_multi_pod_axis():
    cp = _run(["--arch", "qwen3_0_6b", "--shape", "decode_32k",
               "--multi-pod-only"])
    assert "OK" in cp.stdout, cp.stdout + cp.stderr[-2000:]
    art = ROOT / "artifacts" / "dryrun" / \
        "qwen3_0_6b__decode_32k__pod2x16x16__baseline.json"
    d = json.loads(art.read_text())
    assert d["ok"] and d["devices"] == 512


def test_roofline_hlo_parser():
    from repro.launch.roofline import collective_bytes_from_hlo
    hlo = """
      %ar = bf16[16,128]{1,0} all-reduce(%x), replica_groups={{0,1}}
      %ag.1 = f32[256]{0} all-gather(%y), dimensions={0}
      %cp = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute-start(%z)
    """
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 16 * 128 * 2
    assert out["all-gather"]["bytes"] == 256 * 4
    assert out["collective-permute"]["count"] == 1
    assert out["weighted_bytes"] > 0
