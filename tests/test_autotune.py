"""Cache-model tile sizing + kernel-specific autotuner.

Covers the PR-2 performance work: tiling legality (tiled/wavefronted
variants must reproduce the untransformed oracle's checksum bit-for-bit
on small instances of the PolyBench fast set), cache-model behaviour
(budget monotonicity, determinism), autotuner determinism, and the
schedule-cache persistence of tuned configs (second compile = lookup).
"""
import shutil

import pytest

from repro.core import config as CFG
from repro.core.autotune import (TunedConfig, autotune, build_source,
                                 candidate_space, static_cost)
from repro.core.cachemodel import (CacheSpec, auto_tile_sizes,
                                   band_access_groups, select_tile_sizes,
                                   stmt_access_groups, working_set_bytes)
from repro.core.codegen import scan_from_schedule
from repro.core.postproc import find_tilable_bands, tile_schedule
from repro.core.schedcache import ScheduleCache
from repro.core.scheduler import PolyTOPSScheduler, schedule_scop
from repro.core.scops_polybench import (make_gemm, make_gesummv,
                                        make_jacobi1d, make_jacobi2d,
                                        make_mvt, make_trmm)

HAVE_GCC = shutil.which("gcc") is not None

# the PolyBench fast set at test-friendly sizes
SMALL_FAST_SET = {
    "gemm": lambda: make_gemm(40),
    "mvt": lambda: make_mvt(48),
    "jacobi1d": lambda: make_jacobi1d((6, 44)),
    "jacobi2d": lambda: make_jacobi2d((5, 22)),
    "trmm": lambda: make_trmm(36),
    "gesummv": lambda: make_gesummv(40),
}
SCALARS = {"alpha": 1.5, "beta": 0.7, "zero": 0.0, "one": 1.0}


def _c_checksum(scop, tc=None):
    from repro.core.cbackend import CCodeGenerator
    from repro.core.crunner import compile_and_run

    scalars = {k: v for k, v in SCALARS.items() if k in scop.scalars}
    if tc is None:     # untransformed program order: the oracle
        sched = PolyTOPSScheduler(scop, CFG.SchedulerConfig())._fallback_original()
        src = CCodeGenerator(sched, scalars=scalars).generate()
    else:
        sched = schedule_scop(scop, tc.scheduler_config())
        src = build_source(scop, tc, sched, scalars)
    return compile_and_run(src, tag=f"at_{scop.name}_{tc.label if tc else 'orig'}",
                           use_cache=False).checksum


# ---------------------------------------------------------------------------
# tiling legality: every tiled/wavefronted config == untiled oracle
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
@pytest.mark.parametrize("name", sorted(SMALL_FAST_SET))
def test_tiled_variants_match_oracle(name):
    scop = SMALL_FAST_SET[name]()
    ref = _c_checksum(scop)
    configs = [
        TunedConfig("pluto", tile=8),
        TunedConfig("pluto", tile="l1"),
        TunedConfig("tensor", tile="l2"),
        TunedConfig("pluto", tile=8, wavefront=True),
    ]
    for tc in configs:
        got = _c_checksum(SMALL_FAST_SET[name](), tc)
        assert abs(got - ref) <= 1e-6 * max(1.0, abs(ref)), \
            f"{name} {tc.label}: {got!r} != oracle {ref!r}"


# ---------------------------------------------------------------------------
# cache model
# ---------------------------------------------------------------------------


def test_working_set_and_budget_monotonicity():
    scop = make_gemm(256)
    sched = schedule_scop(scop, CFG.pluto_style())
    bands = find_tilable_bands(sched)
    assert bands, "gemm must have a tilable band"
    b = bands[0]
    scan = scan_from_schedule(sched)
    groups = band_access_groups(scan, b.start, b.length)
    # gemm: C[i,j], A[i,k], B[k,j] → three access groups
    assert len(groups) == 3
    small = working_set_bytes(groups, [8] * b.length)
    big = working_set_bytes(groups, [64] * b.length)
    assert small < big
    # larger budget → componentwise >= tile sizes, and both fit budget
    spec = CacheSpec()
    t1 = select_tile_sizes(sched, b.start, b.length, spec.l1_bytes, spec)
    t2 = select_tile_sizes(sched, b.start, b.length, spec.l2_bytes, spec)
    assert all(a <= c for a, c in zip(t1, t2))
    assert working_set_bytes(groups, t1) <= spec.l1_bytes
    assert working_set_bytes(groups, t2) <= spec.l2_bytes


def test_auto_tile_sizes_deterministic():
    scop = make_gemm(420)
    s1 = auto_tile_sizes(schedule_scop(scop, CFG.pluto_style()))
    s2 = auto_tile_sizes(schedule_scop(make_gemm(420), CFG.pluto_style()))
    assert s1 == s2 and s1      # non-empty, repeatable


def test_stmt_access_groups_shared_primitive():
    scop = make_gemm(64)
    stmt = scop.statements[1]          # C[i,j] += A[i,k]*B[k,j]
    groups = stmt_access_groups(stmt, stmt.iters)
    assert {g.array for g in groups} == {"A", "B", "C"}
    # C read+write collapse into one group
    assert len(groups) == 3


def test_stencil_spread_counted_once():
    """jacobi1d's A[t,i-1], A[t,i], A[t,i+1] are one access group with a
    constant spread, not three groups."""
    scop = make_jacobi1d((6, 40))
    sched = schedule_scop(scop, CFG.pluto_style())
    bands = find_tilable_bands(sched)
    assert bands
    scan = scan_from_schedule(sched)
    groups = band_access_groups(scan, bands[0].start, bands[0].length)
    arrays = sorted(g.array for g in groups)
    assert len(arrays) <= 4    # 2 arrays × (read group + write group) max
    assert any(any(s > 0 for s in g.spread) for g in groups)


# ---------------------------------------------------------------------------
# autotuner: determinism + cache-hit persistence
# ---------------------------------------------------------------------------


def test_static_ranking_deterministic():
    scop = make_gemm(64)
    cache = ScheduleCache(disk=False)
    r1 = autotune(scop, measure=False, cache=cache, use_cache=False)
    r2 = autotune(make_gemm(64), measure=False,
                  cache=ScheduleCache(disk=False), use_cache=False)
    assert r1.config == r2.config
    assert r1.ranked == r2.ranked
    assert r1.source == "static"


def test_candidate_space_structure():
    scop = make_gemm(64)
    cache = ScheduleCache(disk=False)
    from repro.core.autotune import _schedules_for_space
    scheds = _schedules_for_space(scop, cache)
    cands = candidate_space(scop, scheds)
    labels = [c.label for c in cands]
    assert len(labels) == len(set(labels))            # no duplicates
    assert "pluto" in labels and "tensor" in labels   # untiled bases present
    assert any("tilel1" in l for l in labels)
    assert any("tilel2" in l for l in labels)
    # static costs are finite and positive
    for tc in cands:
        c = static_cost(scop, scheds[(tc.strategy, tc.autovec)], tc)
        assert c > 0


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_measured_served_from_cache(tmp_path):
    """Second compile of the same kernel shape must get the tuned config
    from the schedule cache — in-memory, then across processes via disk."""
    scop = make_gemm(40)
    cache = ScheduleCache(cache_dir=str(tmp_path))
    r1 = autotune(scop, scalars=SCALARS, measure=True, top_k=3, cache=cache)
    assert r1.source == "measured"
    assert r1.seconds is not None and r1.checksum is not None
    r2 = autotune(make_gemm(40), scalars=SCALARS, measure=True, top_k=3,
                  cache=cache)
    assert r2.source == "cache"
    assert r2.config == r1.config
    # a fresh cache over the same directory: disk hit, same config
    cache2 = ScheduleCache(cache_dir=str(tmp_path))
    r3 = autotune(make_gemm(40), scalars=SCALARS, measure=True, top_k=3,
                  cache=cache2)
    assert r3.source == "cache"
    assert r3.config == r1.config
    assert cache2.stats["disk_hits"] >= 1


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_winner_is_legal(tmp_path):
    """The tuned config's measured checksum equals the oracle's."""
    scop = make_trmm(36)
    cache = ScheduleCache(cache_dir=str(tmp_path))
    r = autotune(scop, scalars=SCALARS, measure=True, top_k=3, cache=cache)
    if r.source == "measured":
        ref = _c_checksum(make_trmm(36))
        assert abs(r.checksum - ref) <= 1e-6 * max(1.0, abs(ref))


# ---------------------------------------------------------------------------
# crunner cache keying
# ---------------------------------------------------------------------------


def test_crunner_key_includes_cflags_and_gcc():
    from repro.core import crunner

    k1 = crunner._result_key("int main(){}")
    old = list(crunner.CFLAGS)
    try:
        crunner.CFLAGS.append("-O0")
        k2 = crunner._result_key("int main(){}")
    finally:
        crunner.CFLAGS[:] = old
    assert k1 != k2                       # flag change → new key
    assert crunner._result_key("int main(){}") == k1   # restored → stable
    assert crunner.compiler_version()     # fingerprint available
