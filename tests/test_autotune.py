"""Cache-model tile sizing + kernel-specific autotuner.

Covers the PR-2 performance work: tiling legality (tiled/wavefronted
variants must reproduce the untransformed oracle's checksum bit-for-bit
on small instances of the PolyBench fast set), cache-model behaviour
(budget monotonicity, determinism), autotuner determinism, and the
schedule-cache persistence of tuned configs (second compile = lookup).
"""
import shutil

import pytest

from repro.core import config as CFG
from repro.core.autotune import (TunedConfig, autotune, build_source,
                                 candidate_space, rank_pallas_plans,
                                 static_cost)
from repro.core.cachemodel import (CacheSpec, auto_tile_sizes,
                                   band_access_groups, select_tile_sizes,
                                   stmt_access_groups, working_set_bytes)
from repro.core.schedtree import scan_from_schedule
from repro.core.postproc import find_tilable_bands
from repro.core.schedcache import ScheduleCache
from repro.core.scheduler import PolyTOPSScheduler, schedule_scop
from repro.core.scops_polybench import (make_gemm, make_gesummv,
                                        make_jacobi1d, make_jacobi2d,
                                        make_mvt, make_trmm)

HAVE_GCC = shutil.which("gcc") is not None

# the PolyBench fast set at test-friendly sizes
SMALL_FAST_SET = {
    "gemm": lambda: make_gemm(40),
    "mvt": lambda: make_mvt(48),
    "jacobi1d": lambda: make_jacobi1d((6, 44)),
    "jacobi2d": lambda: make_jacobi2d((5, 22)),
    "trmm": lambda: make_trmm(36),
    "gesummv": lambda: make_gesummv(40),
}
SCALARS = {"alpha": 1.5, "beta": 0.7, "zero": 0.0, "one": 1.0}


def _c_checksum(scop, tc=None):
    from repro.core.cbackend import CCodeGenerator
    from repro.core.crunner import compile_and_run

    scalars = {k: v for k, v in SCALARS.items() if k in scop.scalars}
    if tc is None:     # untransformed program order: the oracle
        sched = PolyTOPSScheduler(scop, CFG.SchedulerConfig())._fallback_original()
        src = CCodeGenerator(sched, scalars=scalars).generate()
    else:
        sched = schedule_scop(scop, tc.scheduler_config())
        src = build_source(scop, tc, sched, scalars)
    return compile_and_run(src, tag=f"at_{scop.name}_{tc.label if tc else 'orig'}",
                           use_cache=False).checksum


# ---------------------------------------------------------------------------
# tiling legality: every tiled/wavefronted config == untiled oracle
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
@pytest.mark.parametrize("name", sorted(SMALL_FAST_SET))
def test_tiled_variants_match_oracle(name):
    scop = SMALL_FAST_SET[name]()
    ref = _c_checksum(scop)
    configs = [
        TunedConfig("pluto", tile=8),
        TunedConfig("pluto", tile="l1"),
        TunedConfig("tensor", tile="l2"),
        TunedConfig("pluto", tile=8, wavefront=True),
    ]
    for tc in configs:
        got = _c_checksum(SMALL_FAST_SET[name](), tc)
        assert abs(got - ref) <= 1e-6 * max(1.0, abs(ref)), \
            f"{name} {tc.label}: {got!r} != oracle {ref!r}"


# ---------------------------------------------------------------------------
# cache model
# ---------------------------------------------------------------------------


def test_working_set_and_budget_monotonicity():
    scop = make_gemm(256)
    sched = schedule_scop(scop, CFG.pluto_style())
    bands = find_tilable_bands(sched)
    assert bands, "gemm must have a tilable band"
    b = bands[0]
    scan = scan_from_schedule(sched)
    groups = band_access_groups(scan, b.start, b.length)
    # gemm: C[i,j], A[i,k], B[k,j] → three access groups
    assert len(groups) == 3
    small = working_set_bytes(groups, [8] * b.length)
    big = working_set_bytes(groups, [64] * b.length)
    assert small < big
    # larger budget → componentwise >= tile sizes, and both fit budget
    spec = CacheSpec()
    t1 = select_tile_sizes(sched, b.start, b.length, spec.l1_bytes, spec)
    t2 = select_tile_sizes(sched, b.start, b.length, spec.l2_bytes, spec)
    assert all(a <= c for a, c in zip(t1, t2))
    assert working_set_bytes(groups, t1) <= spec.l1_bytes
    assert working_set_bytes(groups, t2) <= spec.l2_bytes


def test_auto_tile_sizes_deterministic():
    scop = make_gemm(420)
    s1 = auto_tile_sizes(schedule_scop(scop, CFG.pluto_style()))
    s2 = auto_tile_sizes(schedule_scop(make_gemm(420), CFG.pluto_style()))
    assert s1 == s2 and s1      # non-empty, repeatable


def test_stmt_access_groups_shared_primitive():
    scop = make_gemm(64)
    stmt = scop.statements[1]          # C[i,j] += A[i,k]*B[k,j]
    groups = stmt_access_groups(stmt, stmt.iters)
    assert {g.array for g in groups} == {"A", "B", "C"}
    # C read+write collapse into one group
    assert len(groups) == 3


def test_stencil_spread_counted_once():
    """jacobi1d's A[t,i-1], A[t,i], A[t,i+1] are one access group with a
    constant spread, not three groups."""
    scop = make_jacobi1d((6, 40))
    sched = schedule_scop(scop, CFG.pluto_style())
    bands = find_tilable_bands(sched)
    assert bands
    scan = scan_from_schedule(sched)
    groups = band_access_groups(scan, bands[0].start, bands[0].length)
    arrays = sorted(g.array for g in groups)
    assert len(arrays) <= 4    # 2 arrays × (read group + write group) max
    assert any(any(s > 0 for s in g.spread) for g in groups)


# ---------------------------------------------------------------------------
# autotuner: determinism + cache-hit persistence
# ---------------------------------------------------------------------------


def test_static_ranking_deterministic():
    scop = make_gemm(64)
    cache = ScheduleCache(disk=False)
    r1 = autotune(scop, measure=False, cache=cache, use_cache=False)
    r2 = autotune(make_gemm(64), measure=False,
                  cache=ScheduleCache(disk=False), use_cache=False)
    assert r1.config == r2.config
    assert r1.ranked == r2.ranked
    assert r1.source == "static"


def test_candidate_space_structure():
    scop = make_gemm(64)
    cache = ScheduleCache(disk=False)
    from repro.core.autotune import _schedules_for_space
    scheds = _schedules_for_space(scop, cache)
    cands = candidate_space(scop, scheds)
    labels = [c.label for c in cands]
    assert len(labels) == len(set(labels))            # no duplicates
    assert "pluto" in labels and "tensor" in labels   # untiled bases present
    assert any("tilel1" in l for l in labels)
    assert any("tilel2" in l for l in labels)
    # static costs are finite and positive
    for tc in cands:
        c = static_cost(scop, scheds[tc.base], tc)
        assert c > 0


# ---------------------------------------------------------------------------
# the §III-E axes: fusion modes, explicit groups, cost mixes
# ---------------------------------------------------------------------------


def test_space_covers_fusion_and_mix_axes():
    """Multi-statement kernels must enumerate fusion variants whose
    schedules are structurally distinct, and the dedup must collapse the
    ones that aren't."""
    from repro.core.autotune import _schedules_for_space, base_configs
    from repro.core.schedcache import schedule_fingerprint

    scop = make_mvt(48)
    bases = base_configs(scop)
    assert any(b.fusion == "max" for b in bases)
    assert any(b.fusion == "no" for b in bases)
    assert any(b.mix is not None for b in bases)
    scheds = _schedules_for_space(scop, ScheduleCache(disk=False), bases)
    cands = candidate_space(scop, scheds)
    # mvt: smart fusion fuses the two independent statements, so 'no'
    # must survive dedup as a genuinely different schedule
    assert any(c.fusion == "no" for c in cands)
    # dedup invariant: every candidate base has a unique fingerprint
    fps = [schedule_fingerprint(scheds[c.base]) for c in cands
           if c.tile is None and not c.wavefront]
    assert len(fps) == len(set(fps))


def test_scc_group_variants_legal_and_bounded():
    from repro.core.autotune import MAX_GROUP_VARIANTS, scc_group_variants
    from repro.core.scops_polybench import make_mm2

    scop = make_mm2(16)
    variants = scc_group_variants(scop)
    assert 0 < len(variants) <= MAX_GROUP_VARIANTS
    n = len(scop.statements)
    for groups in variants:
        flat = sorted(i for g in groups for i in g)
        assert flat == list(range(n))       # a partition of all statements
        # each explicit-group config must schedule without a legality
        # error (groups follow the SCC topological order)
        tc = TunedConfig("pluto", fusion="groups", fusion_groups=groups)
        sched = schedule_scop(scop, tc.scheduler_config())
        assert not sched.fallback


def test_mix_configs_thread_into_ilp_construction():
    from repro.core.costs import COST_MIXES

    for mix, recipe in COST_MIXES.items():
        tc = TunedConfig("pluto", mix=mix)
        cfg = tc.scheduler_config()
        for dim, (cfs, rp) in recipe.items():
            assert cfg.ilp[dim].cost_functions == list(cfs)
            assert cfg.ilp[dim].require_parallel == rp
        # every mix must actually schedule gemm (no unknown cost names)
        sched = schedule_scop(make_gemm(24), cfg)
        assert not sched.fallback


def test_label_encodes_every_axis():
    tc = TunedConfig("pluto", tile="l2", wavefront=True, fusion="groups",
                     fusion_groups=((0, 1), (2,)), mix="c01")
    assert tc.label == "pluto+mixc01+fg01-2+tilel2+wave"
    tc2 = TunedConfig("tensor", fusion="max", autovec=True)
    assert tc2.label == "tensor+fmax+autovec"
    assert tc.uses_new_axes and tc2.uses_new_axes
    assert not TunedConfig("pluto", tile=32).uses_new_axes


def test_tuned_result_roundtrip_with_new_axes():
    from repro.core.autotune import TunedResult

    tc = TunedConfig("pluto", tile=32, fusion="groups",
                     fusion_groups=((0,), (1, 2)), mix="pc")
    r = TunedResult(tc, 1.5, 0.01, 42.0, "measured", ["a", "b"], "learned")
    r2 = TunedResult.from_dict(r.to_dict())
    assert r2.config == tc
    assert r2.source == "cache" and r2.ranker == "learned"
    assert r2.config.fusion_groups == ((0,), (1, 2))


# ---------------------------------------------------------------------------
# learned ranker + measurement pool
# ---------------------------------------------------------------------------


def test_ranker_below_min_samples_falls_back():
    from repro.core import ranker as RK

    assert RK.fit_ranker([]) is None
    rows = [{"kernel": "k", "feats": [0.0] * len(RK.FEATURE_NAMES),
             "seconds": 0.1, "v": 2, "fv": RK.FEATURE_VERSION}] * 5
    assert RK.fit_ranker(rows) is None


def test_ranker_learns_within_kernel_ordering():
    """Synthetic pool where log(time) = 2·feat0: the fitted model must
    rank a smaller feat0 as faster, deterministically."""
    import math

    from repro.core import ranker as RK

    nf = len(RK.FEATURE_NAMES)
    rows = []
    for k in range(4):
        for j in range(12):
            feats = [0.0] * nf
            feats[0] = float(j) / 3.0 + k      # log_static_cost varies
            feats[2] = 3.0 + k                 # kernel-constant: cancels
            rows.append({"kernel": f"k{k}", "feats": feats,
                         "seconds": math.exp(2.0 * feats[0]),
                         "v": 2, "fv": RK.FEATURE_VERSION})
    m1 = RK.fit_ranker(rows)
    m2 = RK.fit_ranker(list(rows))
    assert m1 is not None and m1.weights == m2.weights   # deterministic
    lo = [0.0] * nf
    hi = [0.0] * nf
    hi[0] = 2.0
    assert m1.predict(lo) < m1.predict(hi)
    # rows with a stale feature version never train a model
    stale = [dict(r, fv=RK.FEATURE_VERSION + 1) for r in rows]
    assert RK.fit_ranker(stale) is None


def test_measurement_pool_roundtrip(tmp_path):
    from repro.core.schedcache import load_measurements, record_measurements

    cache = ScheduleCache(cache_dir=str(tmp_path))
    rows = [{"kernel": "gemm", "label": "pluto", "feats": [1.0], "seconds": 0.5,
             "v": 2, "fv": 1},
            {"kernel": "gemm", "label": "tensor", "feats": [2.0], "seconds": 0.25,
             "v": 1, "fv": 1}]
    record_measurements(cache, rows)
    record_measurements(cache, [])            # no-op
    got = load_measurements(cache)
    assert got == rows
    assert load_measurements(cache, space_version=2) == rows[:1]
    # disk-less caches neither write nor read
    mem = ScheduleCache(disk=False)
    record_measurements(mem, rows)
    assert load_measurements(mem) == []
    # torn tail line is skipped silently
    with open(tmp_path / "measurements.jsonl", "a") as f:
        f.write('{"kernel": "trunc')
    assert load_measurements(cache) == rows


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_measured_served_from_cache(tmp_path):
    """Second compile of the same kernel shape must get the tuned config
    from the schedule cache — in-memory, then across processes via disk."""
    scop = make_gemm(40)
    cache = ScheduleCache(cache_dir=str(tmp_path))
    r1 = autotune(scop, scalars=SCALARS, measure=True, top_k=3, cache=cache)
    assert r1.source == "measured"
    assert r1.seconds is not None and r1.checksum is not None
    r2 = autotune(make_gemm(40), scalars=SCALARS, measure=True, top_k=3,
                  cache=cache)
    assert r2.source == "cache"
    assert r2.config == r1.config
    # a fresh cache over the same directory: disk hit, same config
    cache2 = ScheduleCache(cache_dir=str(tmp_path))
    r3 = autotune(make_gemm(40), scalars=SCALARS, measure=True, top_k=3,
                  cache=cache2)
    assert r3.source == "cache"
    assert r3.config == r1.config
    assert cache2.stats["disk_hits"] >= 1


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_second_compile_is_pure_cache_hit(tmp_path, monkeypatch):
    """Winner replay: the second autotune of the same kernel shape must
    not enumerate, schedule, rank or measure anything — guarded by
    poisoning every enumeration entry point after the first call."""
    from repro.core import autotune as AT

    scop = make_gesummv(40)
    cache = ScheduleCache(cache_dir=str(tmp_path))
    r1 = autotune(scop, scalars=SCALARS, measure=True, top_k=3, cache=cache)
    assert r1.source == "measured"

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-enumerate")

    monkeypatch.setattr(AT, "base_configs", boom)
    monkeypatch.setattr(AT, "_schedules_for_space", boom)
    monkeypatch.setattr(AT, "candidate_space", boom)
    monkeypatch.setattr(AT, "build_source", boom)
    r2 = autotune(make_gesummv(40), scalars=SCALARS, measure=True, top_k=3,
                  cache=cache)
    assert r2.source == "cache"
    assert r2.config == r1.config and r2.ranked == r1.ranked


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_measured_autotune_records_training_triples(tmp_path):
    from repro.core import ranker as RK
    from repro.core.autotune import SPACE_VERSION
    from repro.core.schedcache import load_measurements

    cache = ScheduleCache(cache_dir=str(tmp_path))
    autotune(make_gemm(40), scalars=SCALARS, measure=True, top_k=3,
             cache=cache)
    rows = load_measurements(cache, SPACE_VERSION)
    assert rows, "measured candidates must persist as training triples"
    for r in rows:
        assert r["kernel"] == "gemm"
        assert len(r["feats"]) == len(RK.FEATURE_NAMES)
        assert r["seconds"] > 0 and r["fv"] == RK.FEATURE_VERSION


@pytest.mark.skipif(not HAVE_GCC, reason="no C compiler")
def test_autotune_winner_is_legal(tmp_path):
    """The tuned config's measured checksum equals the oracle's."""
    scop = make_trmm(36)
    cache = ScheduleCache(cache_dir=str(tmp_path))
    r = autotune(scop, scalars=SCALARS, measure=True, top_k=3, cache=cache)
    if r.source == "measured":
        ref = _c_checksum(make_trmm(36))
        assert abs(r.checksum - ref) <= 1e-6 * max(1.0, abs(ref))


# ---------------------------------------------------------------------------
# crunner cache keying
# ---------------------------------------------------------------------------


def test_crunner_key_includes_cflags_and_gcc():
    from repro.core import crunner

    k1 = crunner._result_key("int main(){}")
    old = list(crunner.CFLAGS)
    try:
        crunner.CFLAGS.append("-O0")
        k2 = crunner._result_key("int main(){}")
    finally:
        crunner.CFLAGS[:] = old
    assert k1 != k2                       # flag change → new key
    assert crunner._result_key("int main(){}") == k1   # restored → stable
    assert crunner.compiler_version()     # fingerprint available


# ---------------------------------------------------------------------------
# backend-aware candidate lowering: Pallas kernel plans
# ---------------------------------------------------------------------------


def test_rank_pallas_plans_matmul():
    """The enumerated configuration space lowers to ranked KernelPlans
    through the schedule tree — deterministic, lane-sane, best-first."""
    from repro.core.akg import LANE, _matmul_scop

    scop = _matmul_scop(256, 256, 256)
    cands = rank_pallas_plans(scop, use_cache=False,
                              cache=ScheduleCache(disk=False))
    assert cands, "no lowerable candidates"
    costs = [c.static_cost for c in cands]
    assert costs == sorted(costs)
    best = cands[0]
    # tensor-style contiguity should rank first and put lanes on j
    assert best.plan.vector_iter == "j"
    assert best.plan.tile["j"] % LANE == 0
    # deterministic: same input → identical ranking and plans
    again = rank_pallas_plans(scop, use_cache=False,
                              cache=ScheduleCache(disk=False))
    assert [(c.config.label, c.plan) for c in again] == \
           [(c.config.label, c.plan) for c in cands]


def test_rank_pallas_plans_excludes_cpu_tiling_axis():
    """Tile/wavefront variants are the VMEM fitter's job, not a Pallas
    search axis."""
    from repro.core.akg import _matmul_scop

    cands = rank_pallas_plans(_matmul_scop(128, 128, 128), use_cache=False,
                              cache=ScheduleCache(disk=False))
    assert all(c.config.tile is None and not c.config.wavefront
               for c in cands)


def test_rank_pallas_plans_scalar_init_statement():
    """A SCoP whose first statement is zero-dimensional (scalar init)
    must lower the deepest statement's nest, not crash on stmt 0."""
    from repro.core.scop import Scop

    s = Scop("init_then_loop", params={"N": 64})
    s.stmt("acc[0] = zero * 1.0")
    with s.loop("i", 0, "N"):
        s.stmt("acc[0] = acc[0] + x[i]")
    cands = rank_pallas_plans(s, use_cache=False,
                              cache=ScheduleCache(disk=False))
    assert cands
    assert all(c.plan.loop_order == ("i",) for c in cands)
