"""Scheduler behaviour: paper examples, strategies, config surface."""
import pytest

from repro.core import config as CFG
from repro.core.deps import tighten_equalities
from repro.core.scheduler import SchedulingError, schedule_scop
from repro.core.scop import Scop


def listing1():
    k = Scop("listing1", params={})
    with k.loop("i", 0, 100):
        with k.loop("j", 0, 10):
            k.stmt("c[j,i] = a[j,i] * b")
            k.stmt("d[i,j] = e[i,j] * x")
    return k


def gemm(n=24):
    k = Scop("gemm", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("C[i,j] = C[i,j] * beta")
            with k.loop("kk", 0, "N"):
                k.stmt("C[i,j] = C[i,j] + alpha*A[i,kk]*B[kk,j]")
    return k


def test_paper_listing1_interchange():
    """The paper's flagship example: tensor-style must interchange S0 to
    (j, i) while keeping S1 at (i, j) — exactly Listing 1 (right)."""
    sched = schedule_scop(listing1(), CFG.tensor_style())
    s0 = sched.it_matrix(sched.scop.statements[0])
    s1 = sched.it_matrix(sched.scop.statements[1])
    assert s0[0] == [0, 1] and s0[1] == [1, 0]     # j outer, i inner
    assert s1[0] == [1, 0] and s1[1] == [0, 1]     # i outer, j inner


def test_gemm_tensor_style_ikj():
    sched = schedule_scop(gemm(), CFG.tensor_style())
    s1 = sched.scop.statements[1]
    m = sched.it_matrix(s1)
    assert m[0] == [1, 0, 0]          # i
    assert m[1] == [0, 0, 1]          # k
    assert m[2] == [0, 1, 0]          # j innermost (stride-1)


def test_gemm_pluto_parallel_outer():
    sched = schedule_scop(gemm(), CFG.pluto_style())
    # dims 1 and 2 (i, j for the fused band) are parallel
    assert sched.parallel[1] and sched.parallel[2]


def test_jacobi_pluto_skewing():
    j1 = Scop("jacobi1d", params={"T": 6, "N": 20})
    with j1.loop("t", 0, "T"):
        with j1.loop("i", 1, "N-1"):
            j1.stmt("B[i] = (A[i-1]+A[i]+A[i+1])/3")
        with j1.loop("i2", 1, "N-1"):
            j1.stmt("A[i2] = B[i2]")
    sched = schedule_scop(j1, CFG.pluto_style())
    m = sched.it_matrix(sched.scop.statements[0])
    assert m[0] == [1, 0]             # t
    assert m[1] == [2, 1]             # 2t + i: the classic skew
    assert not sched.fallback


def test_every_dep_satisfied():
    for cfg in (CFG.pluto_style(), CFG.tensor_style(), CFG.isl_style()):
        sched = schedule_scop(gemm(), cfg)
        assert all(d.satisfied_at is not None for d in sched.deps)


def test_fusion_config_explicit():
    cfg = CFG.SchedulerConfig.from_json({
        "scheduling_strategy": {
            "ILP_construction": [
                {"scheduling_dimension": "default",
                 "cost_functions": ["proximity"]}],
            "fusion": [{"scheduling_dimension": 0,
                        "stmts_fusion": [["1"], ["0"]]}],
        }})
    with pytest.raises(SchedulingError):
        # S1 before S0 violates the flow dependence S0 → S1
        schedule_scop(gemm(), cfg)


def test_custom_constraint_no_skewing():
    j1 = Scop("j", params={"T": 5, "N": 16})
    with j1.loop("t", 0, "T"):
        with j1.loop("i", 1, "N-1"):
            j1.stmt("A[i] = A[i-1] + A[i+1]")
    cfg = CFG.pluto_style()
    cfg.ilp["default"].constraints = ["no-skewing"]
    sched = schedule_scop(j1, cfg)
    for row in sched.it_matrix(sched.scop.statements[0]):
        assert sum(row) <= 1


def test_vectorize_directive():
    from repro.core.config import Directive
    cfg = CFG.tensor_style()
    cfg.directives = [Directive("vectorize", [1], 1)]   # j innermost for S1
    sched = schedule_scop(gemm(), cfg)
    m = sched.it_matrix(sched.scop.statements[1])
    assert m[-1] == [0, 1, 0]
    assert not sched.dropped_directives


def test_illegal_directive_dropped():
    from repro.core.config import Directive
    # seidel-like: no legal schedule keeps j fully innermost-parallel;
    # a directive to vectorize the sequential t loop must be dropped
    s = Scop("s", params={"T": 4, "N": 10})
    with s.loop("t", 0, "T"):
        with s.loop("i", 1, "N-1"):
            s.stmt("A[i] = A[i-1] + A[i]")
    cfg = CFG.pluto_style()
    cfg.directives = [Directive("vectorize", [0], 0)]
    sched = schedule_scop(s, cfg)    # must not crash; directive dropped
    assert all(d.satisfied_at is not None for d in sched.deps)


def test_equality_tightening():
    from fractions import Fraction
    cons = [
        ({"l1": Fraction(16), "kv1": Fraction(1),
          "l2": Fraction(-16), "kv2": Fraction(-1)}, "==0"),
        ({"kv1": Fraction(1)}, ">=0"),
        ({"kv1": Fraction(-1), 1: Fraction(15)}, ">=0"),
        ({"kv2": Fraction(1)}, ">=0"),
        ({"kv2": Fraction(-1), 1: Fraction(15)}, ">=0"),
    ]
    out = tighten_equalities(cons)
    eqs = [e for e, k in out if k == "==0"]
    assert ({"l1": Fraction(16), "l2": Fraction(-16)} in eqs
            or {"l1": Fraction(1), "l2": Fraction(-1)} in eqs)
    assert {"kv1": Fraction(1), "kv2": Fraction(-1)} in eqs


def test_json_roundtrip():
    cfg = CFG.tensor_style()
    cfg.auto_vectorize = True
    d = cfg.to_json()
    cfg2 = CFG.SchedulerConfig.from_json(d)
    assert cfg2.auto_vectorize
    assert cfg2.ilp["default"].cost_functions == ["contiguity", "proximity"]


def test_strategy_callback_interface():
    """The Python analogue of the paper's C++ interface (Listing 3)."""
    seen = []

    def strategy(state):
        seen.append((state.dim, state.band_start, state.parallel_failed))
        return CFG.DimConfig(cost_functions=["proximity"])

    cfg = CFG.SchedulerConfig(strategy=strategy)
    schedule_scop(gemm(), cfg)
    # gemm's smart-fuse distributes at dim 0 (scalar dim), so the first
    # ILP dimension the strategy sees is dim 1, at a band start
    assert seen and seen[0] == (1, True, False)


def test_parametric_shift_flag():
    """Paper §IV-C: parametric shifting is opt-in; with it enabled the
    scheduler may use nonzero parameter coefficients in φ."""
    s = Scop("shift", params={"N": 8})
    with s.loop("i", 0, "N"):
        s.stmt("A[i+8] = B[i]")
    with s.loop("i2", 0, "N"):
        s.stmt("C[i2] = A[i2+8] * 2.0")
    cfg = CFG.pluto_style()
    sched = schedule_scop(s, cfg)          # default: no parametric coeffs
    for st in sched.scop.statements:
        for row in sched.rows[st.index]:
            assert not any(k[0] == "par" for k in row.coeffs)
    cfg2 = CFG.pluto_style()
    cfg2.parametric_shift = True
    sched2 = schedule_scop(s, cfg2)        # must still be legal
    assert all(d.satisfied_at is not None for d in sched2.deps)


def test_sequential_directive_marks_dim():
    from repro.core.config import Directive
    cfg = CFG.pluto_style()
    cfg.directives = [Directive("sequential", [1], 0)]
    sched = schedule_scop(gemm(), cfg)
    assert any(si == 1 for (si, _) in sched.seq_marked)
