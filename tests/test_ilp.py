"""ILP layer: HiGHS engine vs the exact rational engine (cross-oracle)."""

from _hypothesis_compat import given, settings, st

from repro.core.ilp import ILPProblem


def _mk(engine):
    p = ILPProblem(engine)
    p.var("x", ub=10)
    p.var("y", ub=10)
    p.add({"x": 2, "y": 1, 1: -5})      # 2x + y >= 5
    p.add({"x": 1, "y": 3, 1: -6})      # x + 3y >= 6
    return p


def test_min_matches_engines():
    vh, _ = _mk("highs").solve_min({"x": 1, "y": 1})
    ve, _ = _mk("exact").solve_min({"x": 1, "y": 1})
    assert vh == ve == 4


def test_lexmin_stages():
    for eng in ("highs", "exact"):
        p = ILPProblem(eng)
        p.var("u")
        p.var("w")
        p.var("t", ub=4)
        p.add({"u": 1, "w": 1, "t": 1, 1: -3})
        p.add({"t": 1, 1: -2})
        sol = p.lexmin([{"u": 1}, {"w": 1}, {"t": 1}])
        assert (sol["u"], sol["w"], sol["t"]) == (0, 0, 3)


def test_infeasible_returns_none():
    p = ILPProblem()
    p.var("x", ub=1)
    p.add({"x": 1, 1: -2})
    assert p.solve_min({"x": 1}) is None
    assert not p.feasible()


def test_branch_and_bound_integrality():
    for eng in ("highs", "exact"):
        p = ILPProblem(eng)
        p.var("y")
        p.add({"y": 2, 1: -3})           # y >= 1.5 → integer y >= 2
        v, sol = p.solve_min({"y": 1})
        assert v == 2 and sol["y"] == 2


def test_equality_constraints():
    p = ILPProblem()
    p.var("a", ub=10)
    p.var("b", ub=10)
    p.add({"a": 1, "b": 1, 1: -7}, "==0")
    v, sol = p.solve_min({"a": 1})
    assert v == 0 and sol["b"] == 7


@settings(max_examples=30, deadline=None)
@given(
    c=st.lists(st.integers(-3, 3), min_size=2, max_size=2),
    rows=st.lists(
        st.tuples(st.integers(-3, 3), st.integers(-3, 3), st.integers(-6, 6)),
        min_size=1, max_size=4),
)
def test_engines_agree_property(c, rows):
    """Random small bounded ILPs: both engines find the same optimum."""
    def build(eng):
        p = ILPProblem(eng)
        p.var("x", ub=7)
        p.var("y", ub=7)
        for (a, b, d) in rows:
            p.add({"x": a, "y": b, 1: d})
        return p

    obj = {"x": c[0], "y": c[1]}
    rh = build("highs").solve_min(obj)
    re_ = build("exact").solve_min(obj)
    if rh is None or re_ is None:
        assert rh is None and re_ is None
    else:
        assert rh[0] == re_[0]
