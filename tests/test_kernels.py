"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.akg import plan_attention, plan_matmul
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 128),
                                   (64, 256, 512), (32, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_allclose(m, n, k, dtype):
    r = jax.random.PRNGKey(0)
    a = jax.random.normal(r, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(r, 1), (k, n), dtype)
    got = np.asarray(ops.matmul(a, b), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * k ** 0.5)


def test_matmul_plan_is_polytops_derived():
    plan = plan_matmul(256, 256, 256)
    assert plan.loop_order[0] == "i"
    assert plan.loop_order[-1] == "j"        # lanes innermost (contiguity)
    assert plan.vector_iter == "j"
    assert plan.tile["j"] % 128 == 0 or plan.tile["j"] == 256


@pytest.mark.parametrize("b,s,h,hkv,d", [(2, 256, 4, 2, 64), (1, 128, 2, 2, 32),
                                         (2, 64, 4, 4, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_allclose(b, s, h, hkv, d, causal):
    r = jax.random.PRNGKey(1)
    q = jax.random.normal(r, (b, s, h, d), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(r, 2), (b, s, hkv, d), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(r, 3), (b, s, hkv, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal)
    rep = h // hkv
    kr, vr = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        kr.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        vr.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        causal=causal).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,di,st", [(2, 64, 256, 16), (1, 128, 128, 8)])
def test_selective_scan_allclose(b, s, di, st):
    r = jax.random.PRNGKey(2)
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (b, s, di, st))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 4), (b, s, di, st)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 5), (b, s, st))
    got = ops.selective_scan(a_bar, b_bar, c)
    want = ref.selective_scan_ref(a_bar, b_bar, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_attention_plan_lanes():
    plan = plan_attention(512, 512, 128)
    assert plan.vector_iter == "d"           # head_dim on lanes
    assert plan.tile["q"] <= 128 and plan.tile["kk"] <= 128
