"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.akg import plan_attention, plan_matmul
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 128),
                                   (64, 256, 512), (32, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_allclose(m, n, k, dtype):
    r = jax.random.PRNGKey(0)
    a = jax.random.normal(r, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(r, 1), (k, n), dtype)
    got = np.asarray(ops.matmul(a, b), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * k ** 0.5)


def test_matmul_plan_is_polytops_derived():
    plan = plan_matmul(256, 256, 256)
    assert plan.loop_order[0] == "i"
    assert plan.loop_order[-1] == "j"        # lanes innermost (contiguity)
    assert plan.vector_iter == "j"
    assert plan.tile["j"] % 128 == 0 or plan.tile["j"] == 256


@pytest.mark.parametrize("b,s,h,hkv,d", [(2, 256, 4, 2, 64), (1, 128, 2, 2, 32),
                                         (2, 64, 4, 4, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_allclose(b, s, h, hkv, d, causal):
    r = jax.random.PRNGKey(1)
    q = jax.random.normal(r, (b, s, h, d), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(r, 2), (b, s, hkv, d), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(r, 3), (b, s, hkv, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal)
    rep = h // hkv
    kr, vr = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        kr.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        vr.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        causal=causal).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,di,st", [(2, 64, 256, 16), (1, 128, 128, 8)])
def test_selective_scan_allclose(b, s, di, st):
    r = jax.random.PRNGKey(2)
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (b, s, di, st))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 4), (b, s, di, st)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 5), (b, s, st))
    got = ops.selective_scan(a_bar, b_bar, c)
    want = ref.selective_scan_ref(a_bar, b_bar, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_attention_plan_lanes():
    plan = plan_attention(512, 512, 128)
    assert plan.vector_iter == "d"           # head_dim on lanes
    assert plan.tile["q"] <= 128 and plan.tile["kk"] <= 128


# ---------------------------------------------------------------------------
# KernelPlan lowering properties: every kernel's scheduler-produced tree
# lowers to a TPU-legal plan — lane-aligned vector dim, sublane-aligned
# next-inner dim, VMEM-fitting tiles.
# ---------------------------------------------------------------------------

from repro.core.akg import (LANE, SUBLANE, VMEM_BYTES,  # noqa: E402
                            lower_to_kernel_plan, plan_mamba_scan)
from repro.core.cachemodel import (stmt_access_groups,  # noqa: E402
                                   working_set_bytes)


def _assert_tpu_legal(plan, scop, stmt_idx, dims, bytes_per_elem, n_buffers):
    stmt = scop.statements[stmt_idx]
    # grid order covers every iterator exactly once
    assert sorted(plan.loop_order) == sorted(stmt.iters)
    vec = plan.vector_iter
    assert vec in plan.loop_order
    # lane alignment on the vector dim (or the whole dim when small)
    tv = plan.tile[vec]
    assert tv % LANE == 0 or tv == dims[vec], (plan, dims)
    # sublane alignment on the next-inner non-vector dim
    inner = [it for it in plan.loop_order if it != vec]
    if inner:
        ti = plan.tile[inner[-1]]
        assert ti % SUBLANE == 0 or ti == dims[inner[-1]], (plan, dims)
    # the tile working set (real access groups, buffered) fits VMEM
    groups = stmt_access_groups(stmt, list(plan.loop_order))
    sizes = [plan.tile[it] for it in plan.loop_order]
    ws = n_buffers * working_set_bytes(groups, sizes, bytes_per_elem)
    assert ws <= VMEM_BYTES, (plan, ws)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 128),
                                   (512, 512, 512), (64, 256, 512),
                                   (1024, 1024, 512), (2048, 2048, 2048)])
def test_matmul_plan_tpu_legal(m, n, k):
    from repro.core.akg import _matmul_scop
    plan = plan_matmul(m, n, k)
    _assert_tpu_legal(plan, _matmul_scop(m, n, k), 0,
                      {"i": m, "j": n, "kk": k}, 2, 3)


@pytest.mark.parametrize("sq,sk,d", [(128, 128, 64), (512, 512, 128),
                                     (256, 1024, 128), (1024, 1024, 64)])
def test_attention_plan_tpu_legal(sq, sk, d):
    plan = plan_attention(sq, sk, d)
    dims = {"q": sq, "kk": sk, "d": d}
    assert plan.vector_iter == "d"
    assert plan.tile["d"] % LANE == 0 or plan.tile["d"] == d
    assert plan.tile["q"] <= 128 and plan.tile["kk"] <= 128
    assert all(plan.tile[it] % SUBLANE == 0 or plan.tile[it] == dims[it]
               for it in plan.loop_order)


@pytest.mark.parametrize("seq,di,st", [(64, 128, 8), (128, 256, 16),
                                       (256, 512, 32), (512, 1024, 16),
                                       (256, 256, 255), (128, 2048, 256)])
def test_mamba_plan_tpu_legal(seq, di, st):
    plan = plan_mamba_scan(seq, di, st)
    # t is the recurrence dim: sequential, outermost in the grid order
    assert plan.loop_order[0] == "t"
    assert plan.tile["n"] == st            # hidden state untiled (VMEM)
    assert plan.tile["d"] % SUBLANE == 0 or plan.tile["d"] == di
    assert plan.tile["t"] <= seq
    # the pinned state dim counts against the budget: buffered working
    # set must fit VMEM even for non-lane-multiple states
    groups = stmt_access_groups(
        _mamba_stmt(seq, di, st), list(plan.loop_order))
    sizes = [plan.tile[it] for it in plan.loop_order]
    assert 2 * working_set_bytes(groups, sizes, 4) <= VMEM_BYTES, plan


def _mamba_stmt(seq, di, st):
    from repro.core.scop import Scop
    s = Scop("mamba_scan", params={"T": seq, "D": di, "S": st})
    with s.loop("t", 0, "T"):
        with s.loop("d", 0, "D"):
            with s.loop("n", 0, "S"):
                s.stmt("H[d,n] = A[t,d,n] * H[d,n] + B[t,d,n]")
    return s.statements[0]


def test_mamba_kernel_consumes_scheduler_plan():
    """selective_scan's default block geometry comes from the schedule
    tree (no hand-coded order/tiles) and still matches the oracle."""
    import repro.kernels.mamba_scan as ms
    plan = plan_mamba_scan(64, 128, 8)
    r = jax.random.PRNGKey(7)
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (1, 64, 128, 8))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 1), (1, 64, 128, 8)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 2), (1, 64, 8))
    got = ms.selective_scan(a_bar, b_bar, c)         # plan-driven defaults
    explicit = ms.selective_scan(a_bar, b_bar, c,
                                 d_block=plan.tile["d"],
                                 chunk=plan.tile["t"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(explicit),
                               rtol=0, atol=0)
    want = ref.selective_scan_ref(a_bar, b_bar, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_plan_wrappers_are_thin_over_general_lowering():
    """plan_matmul is the general tree lowering, nothing more."""
    from repro.core.akg import _matmul_scop
    from repro.core.config import tensor_style
    from repro.core.schedcache import cached_schedule_scop
    from repro.core.schedtree import schedule_tree

    scop = _matmul_scop(256, 256, 256)
    cfg = tensor_style()
    cfg.auto_vectorize = True
    sched = cached_schedule_scop(scop, cfg)
    assert lower_to_kernel_plan(schedule_tree(sched)) == plan_matmul(256, 256, 256)
