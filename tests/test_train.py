"""Training runtime: loss decreases, checkpoint round-trip, fault
tolerance, data determinism, gradient compression."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw, grad_compress
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as CKPT
from repro.train import fault as FAULT
from repro.train.loop import Trainer, TrainConfig


@pytest.fixture(scope="module")
def tiny_cfg():
    arch = get_arch("granite_3_2b").smoke()
    return TrainConfig(arch=arch, total_steps=25, global_batch=4, seq_len=64,
                       ckpt_every=10, log_every=100,
                       opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=25))


def test_loss_decreases(tiny_cfg):
    tr = Trainer(tiny_cfg)
    tr.fit()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] - 0.05, losses


def test_checkpoint_roundtrip(tiny_cfg):
    with tempfile.TemporaryDirectory() as td:
        key = jax.random.PRNGKey(0)
        from repro.model import transformer as T
        params = T.init_params(key, tiny_cfg.arch)
        opt = adamw.init(params)
        CKPT.save(td, 7, params, opt)
        assert CKPT.latest_step(td) == 7
        p2, o2, meta = CKPT.restore(td)
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        # keep-N garbage collection
        for s in (8, 9, 10, 11):
            CKPT.save(td, s, params, opt, keep=2)
        steps = sorted(int(p.name.split("_")[1]) for p in Path(td).iterdir())
        assert steps == [10, 11]


def test_preemption_restore(tiny_cfg):
    with tempfile.TemporaryDirectory() as td:
        cfg = TrainConfig(**{**tiny_cfg.__dict__, "ckpt_dir": td,
                             "total_steps": 22, "ckpt_every": 5})
        tr = Trainer(cfg)
        orig = tr.run_step
        fired = {}

        def flaky(step):
            if step == 12 and "f" not in fired:
                fired["f"] = True
                raise FAULT.Preemption("simulated")
            return orig(step)

        tr.run_step = flaky
        out = tr.fit()
        assert out["restarts"] == 1
        assert out["final_step"] == 22


def test_straggler_monitor():
    mon = FAULT.StragglerMonitor(threshold=2.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 5.0)
    assert mon.flagged == [2]


def test_restart_storm_exhausts_budget():
    """A persistent fault must exhaust max_restarts and surface as a
    RuntimeError chained from the Preemption — not loop forever."""
    calls = {"n": 0}

    def doomed(step):
        calls["n"] += 1
        raise FAULT.Preemption(f"storm {calls['n']}")

    policy = FAULT.FaultPolicy(max_restarts=3)
    with pytest.raises(RuntimeError, match="exceeded max_restarts=3") as ei:
        FAULT.run_resilient(doomed, 0, 10, restore_fn=lambda: 0,
                            save_fn=lambda s: None, policy=policy,
                            log_fn=lambda m: None)
    assert isinstance(ei.value.__cause__, FAULT.Preemption)
    # max_restarts restores + the final fatal attempt
    assert calls["n"] == policy.max_restarts + 1


def test_checkpoint_cadence_and_rewind():
    """Checkpoints land at every multiple of checkpoint_every; a
    preemption rewinds to the latest one and replays the gap."""
    saved, executed = [], []

    def step_fn(step):
        executed.append(step)
        if step == 7 and executed.count(7) == 1:
            raise FAULT.Preemption("simulated")
        return {"step": step}

    policy = FAULT.FaultPolicy(max_restarts=2, checkpoint_every=3)
    out = FAULT.run_resilient(step_fn, 0, 10,
                              restore_fn=lambda: saved[-1],
                              save_fn=saved.append, policy=policy,
                              log_fn=lambda m: None)
    # save_fn(step+1) fires when (step+1) % every == 0
    assert saved == [3, 6, 9]
    # steps 6..7 re-executed after restoring the step-6 checkpoint
    assert executed == [0, 1, 2, 3, 4, 5, 6, 7, 6, 7, 8, 9]
    assert out["restarts"] == 1 and out["final_step"] == 10
    assert out["last_metrics"] == {"step": 9}


def test_straggler_ewma_math():
    """The EWMA recurrence itself: seed on first sample, then
    (1-a)*ewma + a*dt, with the flag judged against the PRE-update mean."""
    mon = FAULT.StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not mon.observe(0, 1.0)        # seeds, can never flag
    assert mon.ewma == 1.0
    assert not mon.observe(1, 2.0)        # 2.0 == 2.0*1.0, not strictly >
    assert mon.ewma == pytest.approx(1.5)
    assert mon.observe(2, 3.1)            # 3.1 > 2.0*1.5
    assert mon.ewma == pytest.approx(2.3)
    # the slow sample raised the mean, so the same reading passes now
    assert not mon.observe(3, 3.1)
    assert mon.flagged == [2]


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts produce disjoint halves of the same global batch
    h0 = SyntheticLM(DataConfig(vocab=100, seq_len=32, global_batch=4,
                                seed=7, host_id=0, n_hosts=2)).batch(3)
    h1 = SyntheticLM(DataConfig(vocab=100, seq_len=32, global_batch=4,
                                seed=7, host_id=1, n_hosts=2)).batch(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"])
    # labels are shifted tokens
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_grad_compress_error_feedback():
    """bf16 compression with feedback is unbiased over repeated steps."""
    g = jnp.full((64,), 0.1001, jnp.float32)   # not bf16-representable
    res = grad_compress.init_residual({"w": g})["w"] * 0
    total = jnp.zeros_like(g)
    r = res
    for _ in range(64):
        q, r = grad_compress.compress_with_feedback({"w": g}, {"w": r})
        q, r = q["w"], r["w"]
        total = total + q.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(total / 64), np.asarray(g),
                               rtol=1e-3)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}       # d/dw (w²)
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_serve_engine_smoke():
    import jax
    from repro.launch.serve import Request, ServeEngine
    from repro.model import transformer as T
    cfg = get_arch("granite_3_2b").smoke()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    eng = ServeEngine(cfg, params, batch=2, max_len=24)
    for i in range(2):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (1, 8), 2, cfg.vocab)
        eng.admit(Request(i, prompt), slot=i)
    for _ in range(4):
        eng.step()
    for req in eng.slots:
        assert len(req.generated) == 5
        assert all(0 <= t < cfg.vocab for t in req.generated)
