"""The exact lexicographic simplex core and its determinism guarantees.

Four layers:

* tableau/unit — pivots, feasibility, unboundedness, integrality,
  free variables, and exactness past the int64 range (object-dtype
  promotion must be transparent);
* property — random feasible/infeasible ILPs solved by both the exact
  core and the HiGHS cross-check oracle must agree on feasibility and
  on every optimal value (hypothesis when installed, plus a seeded
  random sweep that always runs);
* projection — the multiplier-free Farkas rows must define exactly the
  same schedule-coefficient optima as the replayed multiplier form;
* end-to-end — *every* kernel×strategy combination schedules to
  bit-identical signatures via the seed pipeline, the incremental
  pipeline, and a repeat run: the HiGHS-era alternate-optima residual
  (~4/56 combos) is now structurally zero.
"""
import random
from fractions import Fraction

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import config as CFG
from repro.core import costs as C
from repro.core.deps import compute_dependences
from repro.core.farkas import farkas_expansion, project_farkas, replay_farkas
from repro.core.ilp import ILPProblem, Unbounded
from repro.core.scheduler import PolyTOPSScheduler
from repro.core.scops_npu import make_lu16, make_trsml, make_trsmu
from repro.core.scops_polybench import REGISTRY

ALL_KERNELS = dict(REGISTRY)
ALL_KERNELS.update({"npu_trsml": make_trsml, "npu_trsmu": make_trsmu,
                    "npu_lu16": make_lu16})
ALL_COMBOS = [(k, s) for k in sorted(ALL_KERNELS) for s in ("pluto", "tensor")]
assert len(ALL_COMBOS) == 56


def _sig(s):
    return (
        {i: [(r.kind, tuple(sorted(r.coeffs.items()))) for r in rr]
         for i, rr in s.rows.items()},
        tuple(s.bands), tuple(s.parallel), s.fallback,
    )


# ---------------------------------------------------------------------------
# tableau / unit
# ---------------------------------------------------------------------------

def test_lex_is_default_engine():
    assert ILPProblem().engine == "lex"
    assert ILPProblem("exact").engine == "lex"     # legacy alias


def test_solve_min_exact_vertex():
    p = ILPProblem()
    p.var("x", ub=10)
    p.var("y", ub=10)
    p.add({"x": 2, "y": 1, 1: -5})
    p.add({"x": 1, "y": 3, 1: -6})
    v, sol = p.solve_min({"x": 1, "y": 1})
    assert v == 4 and sol["x"] + sol["y"] == 4


def test_integrality_branch_and_bound():
    p = ILPProblem()
    p.var("y")
    p.add({"y": 2, 1: -3})               # y >= 1.5 → integer y >= 2
    v, sol = p.solve_min({"y": 1})
    assert v == 2 and sol["y"] == Fraction(2)
    assert sol["y"].denominator == 1


def test_continuous_vertex_is_fractional():
    p = ILPProblem()
    p.var("x", lb=0, ub=None, integer=False)
    p.var("y", lb=0, ub=None, integer=False)
    p.add({"x": 2, "y": 1, 1: -5})
    p.add({"x": 1, "y": 3, 1: -6})
    v, sol = p.solve_min({"x": 1, "y": 1})
    assert v == Fraction(16, 5)          # exact rational vertex (9/5, 7/5)
    assert sol["x"] == Fraction(9, 5) and sol["y"] == Fraction(7, 5)


def test_free_variable_and_unbounded():
    p = ILPProblem()
    p.var("f", lb=None, integer=False)
    p.add({"f": 1, 1: 5})                # f >= -5
    v, sol = p.solve_min({"f": 1})
    assert v == -5 and sol["f"] == -5
    with pytest.raises(Unbounded):
        p.solve_min({"f": -1})


def test_free_variable_upper_bound_enforced():
    """A free (lb=None) variable's ub must become a tableau row on the
    split representation — maximizing must stop at the declared ub, not
    at a looser constraint row."""
    p = ILPProblem()
    p.var("x", lb=None, ub=5, integer=False)
    p.add({"x": -1, 1: 10})              # x <= 10 (looser than the ub)
    v, sol = p.solve_min({"x": -1})
    assert sol["x"] == 5 and v == -5


def test_infeasible_and_empty():
    p = ILPProblem()
    p.var("x", ub=1)
    p.add({"x": 1, 1: -2})
    assert p.solve_min({"x": 1}) is None
    assert not p.feasible()
    assert p.lexmin([{"x": 1}]) is None


def test_equality_rows():
    p = ILPProblem()
    p.var("a", ub=10)
    p.var("b", ub=10)
    p.add({"a": 1, "b": 1, 1: -7}, "==0")
    v, sol = p.solve_min({"a": 1})
    assert v == 0 and sol["b"] == 7


def test_exactness_beyond_int64():
    """Coefficients near 2^62 force the object-dtype promotion; results
    must stay exact (floats would be off by thousands here)."""
    big = (1 << 62) + 3
    p = ILPProblem()
    p.var("x", ub=None)
    p.var("y", ub=None)
    p.add({"x": big, "y": -1, 1: -1})            # big·x - y >= 1
    p.add({"y": 1, "x": -1, 1: 0}, ">=0")        # y >= x
    v, sol = p.solve_min({"x": big, "y": 1})
    assert sol["x"] == 1 and sol["y"] == 1       # x=1 forces y∈[1, big-1]
    assert v == big + 1


def test_lexmin_stage_order_matters():
    for order in (["u", "w"], ["w", "u"]):
        p = ILPProblem()
        p.var("u", ub=5)
        p.var("w", ub=5)
        p.add({"u": 1, "w": 1, 1: -3})
        sol = p.lexmin([{order[0]: 1}, {order[1]: 1}])
        # the first-minimized variable hits 0, the second absorbs the 3
        assert (sol[order[0]], sol[order[1]]) == (0, 3)


def test_lexmin_canonicalization_unique_point():
    """Alternate optima on the objective must collapse to the canonical
    (lexicographically smallest) point in declaration order."""
    p = ILPProblem()
    p.var("a", ub=4)
    p.var("b", ub=4)
    p.add({"a": 1, "b": 1, 1: -4})               # a + b >= 4
    sol = p.lexmin([{"a": 1, "b": 1}])           # any a+b=4 is optimal
    assert (sol["a"], sol["b"]) == (0, 4)        # canon: minimize a first
    sol2 = p.lexmin([{"a": 1, "b": 1}], canon=["b", "a"])
    assert (sol2["a"], sol2["b"]) == (4, 0)


def test_lexmin_does_not_mutate_problem():
    p = ILPProblem()
    p.var("x", ub=9)
    p.var("y", ub=9)
    p.add({"x": 1, "y": 1, 1: -4})
    ncons, nvars = len(p.cons), len(p.vars)
    p.lexmin([{"x": 1}, {"y": 1}])
    assert len(p.cons) == ncons and len(p.vars) == nvars
    v, _ = p.solve_min({"x": 1, "y": 1})
    assert v == 4


# ---------------------------------------------------------------------------
# property tests vs the HiGHS oracle
# ---------------------------------------------------------------------------

def _pair(rows, ubs):
    """Build the same ILP for both engines."""
    out = []
    for eng in ("lex", "highs"):
        p = ILPProblem(eng)
        p.var("x", ub=ubs[0])
        p.var("y", ub=ubs[1])
        p.var("z", ub=ubs[2])
        for (a, b, c, d, kind) in rows:
            p.add({"x": a, "y": b, "z": c, 1: d},
                  "==0" if kind else ">=0")
        out.append(p)
    return out


def _check_agree(rows, ubs, objs):
    pl, ph = _pair(rows, ubs)
    try:
        sl = pl.lexmin(objs)
    except Unbounded:
        sl = "unbounded"
    try:
        sh = ph.lexmin(objs)
    except (Unbounded, RuntimeError):
        sh = "unbounded"
    if sl == "unbounded" or sh == "unbounded":
        assert sl == sh
        return
    if sl is None or sh is None:
        assert sl is None and sh is None
        return
    for i, obj in enumerate(objs):
        vl = sum((Fraction(c) * sl[k] for k, c in obj.items() if k != 1),
                 Fraction(obj.get(1, 0)))
        vh = sum((Fraction(c) * sh[k] for k, c in obj.items() if k != 1),
                 Fraction(obj.get(1, 0)))
        assert vl == vh, f"stage {i}: lex {vl} != highs {vh}"


@settings(max_examples=60, deadline=None)
@given(
    rows=st.lists(
        st.tuples(st.integers(-3, 3), st.integers(-3, 3), st.integers(-3, 3),
                  st.integers(-8, 8), st.booleans()),
        min_size=1, max_size=6),
    objs=st.lists(
        st.fixed_dictionaries(
            {"x": st.integers(-2, 2), "y": st.integers(-2, 2),
             "z": st.integers(-2, 2)}),
        min_size=1, max_size=3),
)
def test_property_lexmin_agrees_with_highs(rows, objs):
    """Random feasible/infeasible bounded ILPs: the exact core and the
    HiGHS oracle agree on feasibility and on every lexicographic stage
    value."""
    _check_agree(rows, (7, 7, 5), objs)


def test_random_sweep_agrees_with_highs():
    """Seeded random sweep of the same property — runs even without
    hypothesis installed."""
    rng = random.Random(20260730)
    for _ in range(80):
        rows = [
            (rng.randint(-3, 3), rng.randint(-3, 3), rng.randint(-3, 3),
             rng.randint(-8, 8), rng.random() < 0.2)
            for _ in range(rng.randint(1, 6))
        ]
        objs = [
            {"x": rng.randint(-2, 2), "y": rng.randint(-2, 2),
             "z": rng.randint(-2, 2)}
            for _ in range(rng.randint(1, 3))
        ]
        _check_agree(rows, (7, 7, 5), objs)


# ---------------------------------------------------------------------------
# Farkas projection equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["gemm", "jacobi1d", "trisolv", "fdtd2d"])
def test_projection_matches_multiplier_form(kernel):
    """For every dependence: the lexmin of the schedule-coefficient
    variables over the *projected* legality rows must equal the lexmin
    over the replayed multiplier expansion — i.e. the exact elimination
    (substitution + Imbert-accelerated FM) changed nothing about the
    feasible T-space."""
    scop = ALL_KERNELS[kernel]()
    params = scop.param_names()
    deps = compute_dependences(scop)
    for dep in deps[:6]:
        coef, const = C.phi_coef_map(dep, params)
        tvars = sorted({v for e in coef.values() for v in e}
                       | {v for v in const if v != 1})

        def build(with_multipliers):
            p = ILPProblem("lex")
            for v in tvars:
                p.var(v, lb=0, ub=3, integer=True)
            if with_multipliers:
                replay_farkas(p, farkas_expansion(dep.cons, coef, const, "t"))
            else:
                for e, k in project_farkas(dep.cons, coef, const):
                    p.add(dict(e), k)
            return p

        objs = [{v: Fraction(1) for v in tvars},
                {v: Fraction(k + 1) for k, v in enumerate(tvars)}]
        a = build(False).lexmin(objs, canon=tvars)
        b = build(True).lexmin(objs, canon=tvars)
        if a is None or b is None:
            assert a is None and b is None
            continue
        assert {v: a[v] for v in tvars} == {v: b[v] for v in tvars}


def test_projection_has_no_multipliers():
    scop = ALL_KERNELS["gemm"]()
    params = scop.param_names()
    dep = compute_dependences(scop)[0]
    coef, const = C.phi_coef_map(dep, params)
    rows = project_farkas(dep.cons, coef, const)
    allowed = {v for e in coef.values() for v in e}
    allowed |= {v for v in const if v != 1}
    for e, _ in rows:
        assert set(e) - {1} <= allowed


# ---------------------------------------------------------------------------
# differential: config-varied objectives, exact core vs HiGHS oracle.
# The autotuner's enumerated configurations (fusion modes, explicit
# statement groups, per-dim cost mixes) construct per-dimension ILPs
# whose objective *stages* differ from the plain strategy sweep; on each
# of them the exact core and the HiGHS oracle must agree on every stage
# value (two engines may pick different alternate optima, but the stage
# values of a lexicographic optimum are unique).
# ---------------------------------------------------------------------------

DIFF_KERNELS = ("gemm", "mvt", "mm2")


def _small_scop(kernel):
    from repro.core.scops_polybench import make_gemm, make_mm2, make_mvt
    return {"gemm": lambda: make_gemm(10),
            "mvt": lambda: make_mvt(10),
            "mm2": lambda: make_mm2(8)}[kernel]()


@pytest.mark.parametrize("kernel", DIFF_KERNELS)
def test_config_varied_objectives_agree_with_highs(kernel):
    from repro.core.autotune import base_configs

    for base in base_configs(_small_scop(kernel)):
        cfgs = {}
        scheds = {}
        for eng in ("lex", "highs"):
            scop = _small_scop(kernel)
            try:
                sch = PolyTOPSScheduler(scop, base.scheduler_config(),
                                        engine=eng, decompose=False,
                                        record_stage_values=True)
                scheds[eng] = sch.schedule()
                cfgs[eng] = sch.stats.get("stage_values", [])
            except Exception as e:
                cfgs[eng] = ("raised", type(e).__name__)
        if isinstance(cfgs["lex"], tuple) or isinstance(cfgs["highs"], tuple):
            # a config that fails must fail identically on both engines
            assert cfgs["lex"] == cfgs["highs"], base.label
            continue
        sv_lex, sv_highs = cfgs["lex"], cfgs["highs"]
        if _sig(scheds["lex"]) == _sig(scheds["highs"]):
            # identical trajectories: the full stage-value streams match
            assert sv_lex == sv_highs, base.label
        else:
            # alternate optima may diverge the *trajectory* after some
            # dim, but the first solved dimension is the same problem on
            # both engines: its stage values must agree exactly
            assert sv_lex and sv_highs, base.label
            assert sv_lex[0] == sv_highs[0], base.label


def test_stage_values_recorded_for_custom_mix():
    """A per-dim cost mix reaches ILP objective construction: the
    contiguity-first dims carry an extra leading stage vs plain pluto."""
    from repro.core.autotune import TunedConfig

    scop = _small_scop("gemm")
    sch_pluto = PolyTOPSScheduler(_small_scop("gemm"), CFG.pluto_style(),
                                  decompose=False, record_stage_values=True)
    sch_pluto.schedule()
    sch_mix = PolyTOPSScheduler(
        scop, TunedConfig("pluto", mix="cp").scheduler_config(),
        decompose=False, record_stage_values=True)
    sch_mix.schedule()
    sv_p = sch_pluto.stats["stage_values"]
    sv_m = sch_mix.stats["stage_values"]
    assert sv_p and sv_m
    # proximity contributes 2 stages (u, w); contiguity prepends one
    # more on dims where incomplete statements remain
    assert any(len(vm[1]) == len(vp[1]) + 1
               for vm, vp in zip(sv_m, sv_p) if vm[0] == vp[0])


# ---------------------------------------------------------------------------
# the 56-combo exact-equality invariant (the former residual list → zero)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,style", ALL_COMBOS,
                         ids=[f"{k}-{s}" for k, s in ALL_COMBOS])
def test_seed_equals_incremental_all_combos(kernel, style):
    """Every kernel×strategy combo: the seed pipeline, the incremental
    pipeline and a repeat run produce bit-identical schedules."""
    mk = ALL_KERNELS[kernel]
    cfg = CFG.STRATEGIES[style]
    seed = PolyTOPSScheduler(mk(), cfg(), incremental=False).schedule()
    inc = PolyTOPSScheduler(mk(), cfg()).schedule()
    rep = PolyTOPSScheduler(mk(), cfg()).schedule()
    assert _sig(seed) == _sig(inc) == _sig(rep)
