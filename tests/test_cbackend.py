"""C backend: compile+run, checksum equivalence across schedule variants."""
import shutil

import pytest

from repro.core import config as CFG
from repro.core.cbackend import CCodeGenerator
from repro.core.crunner import compile_and_run
from repro.core.postproc import tile_schedule
from repro.core.scheduler import schedule_scop
from repro.core.scops_polybench import make_gemm, make_jacobi1d

pytestmark = pytest.mark.skipif(shutil.which("gcc") is None,
                                reason="no C compiler")


def _checksum(scop, cfg, tile=None, wavefront=False):
    sched = schedule_scop(scop, cfg)
    scan = tile_schedule(sched, tile, wavefront=wavefront) if tile else None
    src = CCodeGenerator(sched, scan=scan,
                         scalars={"alpha": 1.5, "beta": 0.7}).generate()
    r = compile_and_run(src, tag=f"t_{scop.name}_{cfg.name}_{tile}_{wavefront}",
                        use_cache=False)
    return r.checksum


def test_gemm_variants_agree():
    scop = make_gemm(48)
    cks = [
        _checksum(scop, CFG.pluto_style()),
        _checksum(scop, CFG.tensor_style()),
        _checksum(scop, CFG.pluto_style(), tile=16),
    ]
    assert max(cks) - min(cks) < 1e-6 * max(1.0, abs(cks[0]))


def test_jacobi_wavefront_agrees():
    scop = make_jacobi1d((6, 40))
    base = _checksum(scop, CFG.pluto_style())
    wf = _checksum(scop, CFG.pluto_style(), tile=8, wavefront=True)
    assert abs(base - wf) < 1e-6 * max(1.0, abs(base))
