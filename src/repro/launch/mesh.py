"""Mesh construction + sharding policy for the production topology.

Single pod:  (data=16, model=16)          — 256 chips (TPU v5e pod slice)
Multi pod:   (pod=2, data=16, model=16)   — 512 chips

DP runs over ('pod','data'); TP/EP/vocab over 'model'. Parameters of
large archs additionally shard over 'data' (FSDP/ZeRO-3); optimizer
states inherit parameter specs (ZeRO-1 falls out for free).

Everything here is a FUNCTION of the mesh — importing this module never
touches jax device state.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ArchConfig


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def logical_rules(cfg: ArchConfig, mesh: Mesh, *, batch: int, seq_shard: bool = False
                  ) -> Dict[str, Any]:
    """Logical activation axis -> physical mesh axes for this arch."""
    model_n = mesh.shape["model"]
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    rules: Dict[str, Any] = {
        "batch": dp if batch % dp_n == 0 else
                 ("data" if batch % mesh.shape["data"] == 0 else None),
        "seq": "model" if seq_shard else None,
        "heads": "model" if cfg.n_heads % model_n == 0 else None,
        "kv_heads": "model" if cfg.n_kv_heads % model_n == 0 else None,
        "ffn": "model" if (cfg.d_ff and cfg.d_ff % model_n == 0)
               or (cfg.family in ("ssm", "hybrid") and cfg.d_inner % model_n == 0)
               else None,
        "experts": "model" if cfg.n_experts and cfg.n_experts % model_n == 0 else None,
        "vocab": "model" if cfg.vocab % model_n == 0 else None,
    }
    return rules


# ---------------------------------------------------------------------------
# parameter shardings (by pytree path name conventions)
# ---------------------------------------------------------------------------

def _param_spec(path: str, leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    model_n = mesh.shape["model"]
    # FSDP shards over the full DP domain (pod×data in multi-pod): more
    # shards AND consistent device order with the batch sharding (avoids
    # GSPMD "involuntary full rematerialization" reshards)
    data_ax = dp_axes(mesh) if cfg.fsdp else None
    heads_ok = cfg.n_heads % model_n == 0
    ff_ok = cfg.d_ff % model_n == 0 if cfg.d_ff else False
    di_ok = cfg.d_inner % model_n == 0
    exp_ok = cfg.n_experts % model_n == 0 if cfg.n_experts else False
    vocab_ok = cfg.vocab % model_n == 0

    def maybe(ax_ok, ax="model"):
        return ax if ax_ok else None

    name = path.split("/")[-1]
    ndim = leaf.ndim
    spec: Tuple = (None,) * ndim
    if name in ("embed", "lm_head"):
        spec = (maybe(vocab_ok), data_ax)
    elif name == "frontend_proj":
        spec = (data_ax, None)
    elif name == "wq":
        spec = (data_ax, maybe(heads_ok))
    elif name in ("wk", "wv"):
        kv_ok = cfg.n_kv_heads % model_n == 0
        spec = (data_ax, maybe(kv_ok))
    elif name == "wo":
        spec = (maybe(heads_ok), data_ax)
    elif name in ("w_gate", "w_up"):
        if "ffn" in path and cfg.n_experts and ndim == 3:   # MoE experts
            spec = (maybe(exp_ok), data_ax, None)
        else:
            spec = (data_ax, maybe(ff_ok))
    elif name == "w_down":
        if "ffn" in path and cfg.n_experts and ndim == 3:
            spec = (maybe(exp_ok), None, data_ax)
        else:
            spec = (maybe(ff_ok), data_ax)
    elif name == "router":
        spec = (None, maybe(exp_ok))
    elif name == "in_proj":
        spec = (data_ax, maybe(di_ok))
    elif name == "out_proj":
        spec = (maybe(di_ok), data_ax)
    elif name == "x_proj":
        spec = (maybe(di_ok), None)
    elif name == "dt_proj":
        spec = (None, maybe(di_ok))
    elif name in ("conv_w",):
        spec = (None, maybe(di_ok))
    elif name in ("a_log", "d_skip", "conv_b", "dt_bias"):
        spec = (maybe(di_ok),) + (None,) * (ndim - 1)
    else:   # norms & misc: replicated
        spec = (None,) * ndim
    spec = spec[:ndim] + (None,) * (ndim - len(spec))
    return P(*spec)


def _is_stacked(path_keys) -> bool:
    """Params under decoder/encoder 'slots' carry a leading layer axis."""
    return "slots" in path_keys


def param_pspecs(params, cfg: ArchConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching `params`."""

    def spec_for(path, leaf):
        keys = [_key_str(k) for k in path]
        name = "/".join(keys)
        stacked = _is_stacked(keys)
        base = _param_spec(name, _LeafView(leaf, stacked), cfg, mesh)
        if stacked:
            return P(*((None,) + tuple(base)))
        return base

    return jax.tree_util.tree_map_with_path(spec_for, params)


class _LeafView:
    """Leaf with the stacked layer axis hidden."""

    def __init__(self, leaf, stacked: bool):
        self.ndim = leaf.ndim - (1 if stacked else 0)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def shardings_for(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def make_param_handlers(cfg: ArchConfig, mesh: Mesh):
    """(gather_fn, grad_fn) for FSDP: see model.sharding.set_param_handlers.

    gather_fn re-constrains a *sliced per-layer* param tree to TP-only
    specs (data axis dropped) — the path names still match because only
    the leading 'slots' stacking is gone. grad_fn pins a full gradient
    tree to the FSDP param specs."""
    if not cfg.fsdp:
        return None, None
    tp_cfg = cfg.scaled(fsdp=False)

    def gather_fn(tree):
        def constrain(path, leaf):
            keys = [_key_str(k) for k in path]
            spec = _param_spec("/".join(keys), leaf, tp_cfg, mesh)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))
        return jax.tree_util.tree_map_with_path(constrain, tree)

    def grad_fn(tree):
        specs = param_pspecs(tree, cfg, mesh)
        return jax.tree.map(
            lambda leaf, s: jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, s)),
            tree, specs)

    return gather_fn, grad_fn


# ---------------------------------------------------------------------------
# cache shardings (decode)
# ---------------------------------------------------------------------------

def cache_pspecs(cache, cfg: ArchConfig, mesh: Mesh, batch: int):
    """KV caches: batch over DP when divisible; otherwise shard the
    sequence axis over 'model' (long-context decode, flash-decoding
    style distributed softmax). Mamba states: d_inner over 'model'."""
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    model_n = mesh.shape["model"]
    batch_ax = dp if batch % dp_n == 0 else None
    kv_ok = cfg.n_kv_heads % model_n == 0
    di_ok = cfg.d_inner % model_n == 0

    def spec_for(path, leaf):
        keys = [_key_str(k) for k in path]
        stacked = "slots" in keys
        lead = (None,) if stacked else ()
        name = keys[-1]
        nd = leaf.ndim
        if name in ("k", "v"):
            # (b, S, hkv, hd): prefer head sharding; else shard S on model
            if kv_ok:
                spec = lead + (batch_ax, None, "model", None)
            else:
                spec = lead + (batch_ax, "model", None, None)
        elif name == "conv":
            spec = lead + (batch_ax, None, "model" if di_ok else None)
        elif name == "ssm":
            spec = lead + (batch_ax, "model" if di_ok else None, None)
        else:
            spec = (None,) * nd
        spec = tuple(spec)[:nd] + (None,) * (nd - len(spec))
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def batch_pspec(mesh: Mesh, batch: int) -> P:
    dp = dp_axes(mesh)
    if batch % axis_size(mesh, dp) == 0:
        return P(dp)
    if batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)
