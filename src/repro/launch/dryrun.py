import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including jax):
# jax locks the device count at first initialization.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * compiled.memory_analysis()  — bytes per device (fits-on-chip proof)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective byte counts      — parsed from the optimized HLO text
and writes artifacts/dryrun/<arch>__<shape>__<mesh>.json consumed by
benchmarks/bench_roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                                get_arch, runnable_cells)
from ..model import transformer as T
from ..model.sharding import (clear_logical_rules, clear_param_handlers,
                              set_logical_rules, set_moe_groups,
                              set_param_handlers)
from ..optim import adamw
from ..train import steps as STEPS
from . import mesh as M
from .roofline import collective_bytes_from_hlo, roofline_terms

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# §Perf variants: module-level model knobs applied around lowering.
# 'baseline' is the paper-faithful configuration recorded first.
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "chunked_attn": {"attn_chunk": 512},
    "chunked_attn_256": {"attn_chunk": 256},
    "remat_dots": {"remat": "dots"},
    "chunked_remat_dots": {"attn_chunk": 512, "remat": "dots"},
    "no_remat": {"remat": "none"},
    # serving: drop tensor-parallel sharding (params replicated, DP only)
    # — removes the per-layer all-reduce chain for tiny per-token compute
    "tp_off": {"tp_off": True},
    "tp_off_chunked": {"tp_off": True, "attn_chunk": 512},
    # decode: one-hot embed = local shard matmul + tiny AR instead of
    # all-gathering the whole vocab-sharded table per step
    "onehot_embed": {"embed_mode": "onehot"},
    # decode: keep TP but drop FSDP — weights stay resident (sharded
    # 1/16 on 'model'), no per-layer data-axis all-gather per token step
    "no_fsdp": {"fsdp_off": True},
    "no_fsdp_onehot": {"fsdp_off": True, "embed_mode": "onehot"},
    # train: fewer microbatches → fewer FSDP param re-gathers
    "micro_half": {"n_micro_div": 2},
    "micro_quarter": {"n_micro_div": 4},
}


class _variant_ctx:
    def __init__(self, name: str):
        self.knobs = VARIANTS[name]

    def __enter__(self):
        from ..model import attention as A
        from ..model import layers as L
        from ..model import transformer as TMOD
        self.prev = (A.ATTN_CHUNK, TMOD.REMAT, L.EMBED_MODE)
        A.ATTN_CHUNK = self.knobs.get("attn_chunk", 0)
        TMOD.REMAT = self.knobs.get("remat", "full")
        L.EMBED_MODE = self.knobs.get("embed_mode", "take")
        return self

    def __exit__(self, *exc):
        from ..model import attention as A
        from ..model import layers as L
        from ..model import transformer as TMOD
        A.ATTN_CHUNK, TMOD.REMAT, L.EMBED_MODE = self.prev
        return False


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    gb, seq = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        text_len = seq - (cfg.frontend_len if cfg.family == "vlm" else 0)
        batch = {
            "tokens": sds((gb, text_len), jnp.int32),
            "labels": sds((gb, text_len), jnp.int32),
        }
        if cfg.family == "vlm":
            batch["frontend"] = sds((gb, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        if cfg.enc_layers:
            batch["enc_frontend"] = sds((gb, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        text_len = seq - (cfg.frontend_len if cfg.family == "vlm" else 0)
        batch = {"tokens": sds((gb, text_len), jnp.int32)}
        if cfg.family == "vlm":
            batch["frontend"] = sds((gb, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        if cfg.enc_layers:
            batch["enc_frontend"] = sds((gb, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one token + a full-length cache
    cache = jax.eval_shape(lambda: T.init_cache(cfg, gb, seq))
    batch = {
        "token": sds((gb, 1), jnp.int32),
        "cache": cache,
        "cache_len": sds((), jnp.int32),
    }
    if cfg.enc_layers:
        batch["memory"] = sds((gb, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    return batch


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig, batch, mesh):
    bspec = M.batch_pspec(mesh, shape.global_batch)

    def spec_for(path, leaf):
        keys = [M._key_str(k) for k in path]
        name = keys[0] if keys else ""
        if name in ("tokens", "labels", "token"):
            return P(*bspec) if not isinstance(bspec, P) else bspec
        if name in ("frontend", "enc_frontend", "memory"):
            return P(bspec[0] if len(bspec) else None, None, None)
        if name == "cache":
            return None  # handled separately
        return P()

    specs = jax.tree_util.tree_map_with_path(spec_for, batch)
    if "cache" in batch:
        specs = dict(specs)
        specs["cache"] = M.cache_pspecs(batch["cache"], cfg, mesh,
                                        shape.global_batch)
        specs["cache_len"] = P()
    return specs


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               n_micro: Optional[int] = None, variant: str = "baseline",
               donate: bool = True, cfg: Optional[ArchConfig] = None):
    cfg = cfg or get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    knobs = VARIANTS.get(variant, {})
    tp_off = knobs.get("tp_off", False)
    rules = M.logical_rules(cfg, mesh, batch=shape.global_batch)
    if tp_off:
        rules = {k: (v if k == "batch" else None) for k, v in rules.items()}
        cfg = cfg.scaled(fsdp=False)
    elif knobs.get("fsdp_off"):
        cfg = cfg.scaled(fsdp=False)
    set_logical_rules(mesh, rules)
    gather_fn, grad_fn = M.make_param_handlers(cfg, mesh)
    set_param_handlers(gather_fn, grad_fn)
    dp_n = M.axis_size(mesh, M.dp_axes(mesh))
    set_moe_groups(dp_n)
    vctx = _variant_ctx(variant)
    vctx.__enter__()
    try:
        params_shape = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        pspecs = M.param_pspecs(params_shape, cfg, mesh)
        if tp_off:
            pspecs = jax.tree.map(lambda s: P(), pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
        pshard = M.shardings_for(pspecs, mesh)
        batch = input_specs(cfg, shape, mesh)
        bspecs = batch_pspecs(cfg, shape, batch, mesh)
        if tp_off:
            dp_axes_set = {"data", "pod"}

            def keep_dp(s):
                return P(*[ax if (ax in dp_axes_set
                                  or (isinstance(ax, tuple)
                                      and set(ax) <= dp_axes_set)) else None
                           for ax in s])
            bspecs = jax.tree.map(keep_dp, bspecs,
                                  is_leaf=lambda x: isinstance(x, P))
        bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                              is_leaf=lambda x: isinstance(x, P))
        if shape.kind == "train":
            dp_n = M.axis_size(mesh, M.dp_axes(mesh))
            nm = n_micro or max(shape.global_batch // dp_n, 1)
            nm = max(nm // VARIANTS.get(variant, {}).get("n_micro_div", 1), 1)
            opt_cfg = adamw.AdamWConfig()
            step = STEPS.make_train_step(cfg, opt_cfg, nm)
            opt_shape = jax.eval_shape(adamw.init, params_shape)
            opt_specs = adamw.AdamWState(
                step=P(),
                m=pspecs, v=pspecs)
            opt_shard = M.shardings_for(opt_specs, mesh)
            fn = jax.jit(
                step,
                in_shardings=(pshard, opt_shard, bshard),
                out_shardings=(pshard, opt_shard,
                               jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                            {"grad_norm": 0, "lr": 0, "loss": 0})),
                donate_argnums=(0, 1) if donate else (),
            )
            with mesh:
                lowered = fn.lower(params_shape, opt_shape, batch)
        elif shape.kind == "prefill":
            step = STEPS.make_prefill_step(cfg)
            fn = jax.jit(step, in_shardings=(pshard, bshard))
            with mesh:
                lowered = fn.lower(params_shape, batch)
        else:
            step = STEPS.make_serve_step(cfg)
            fn = jax.jit(
                step,
                in_shardings=(pshard, bshard),
                donate_argnums=(1,) if donate else (),
            )
            with mesh:
                lowered = fn.lower(params_shape, batch)
        return mesh, lowered
    finally:
        vctx.__exit__()
        clear_logical_rules()
        clear_param_handlers()


def _compile_stats(arch_id, shape_name, multi_pod, cfg, variant):
    mesh, lowered = lower_cell(arch_id, shape_name, multi_pod,
                               variant=variant, cfg=cfg)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    # jax < 0.4.30 returned [per-computation dict]; newer returns the
    # dict directly — normalize to a dict either way
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return mesh, compiled, cost, coll


def _probe_cfg(cfg: ArchConfig, mult: int) -> ArchConfig:
    from ..model.transformer import pattern_period
    period = pattern_period(cfg, "decoder")
    return cfg.scaled(
        n_layers=mult * period,
        enc_layers=mult if cfg.enc_layers else 0,
    )


def extrapolated_costs(arch_id, shape_name, multi_pod, cfg, shape, variant,
                       n_micro: int):
    """Scan bodies are counted ONCE by cost_analysis; recover true totals
    by compiling depth=P and depth=2P probes and extrapolating linearly:
      F(R) = F_fixed + M·(F_mb + R·F_unit)   (train; M = micro steps)
      F(R) = F_fixed + R·F_unit              (prefill / decode)
    """
    from ..model import transformer as TMOD
    from ..model.transformer import pattern_period
    period = pattern_period(cfg, "decoder")
    TMOD.UNROLL = True   # probes must unroll (while bodies count once)
    try:
        _, _, cost_a, coll_a = _compile_stats(arch_id, shape_name, multi_pod,
                                              _probe_cfg(cfg, 1), variant)
        _, _, cost_b, coll_b = _compile_stats(arch_id, shape_name, multi_pod,
                                              _probe_cfg(cfg, 2), variant)
    finally:
        TMOD.UNROLL = False

    repeats = cfg.n_layers // period
    tail = cfg.n_layers - repeats * period
    r_eff = repeats + tail / period
    m = n_micro if shape.kind == "train" else 1
    # optimizer flops outside the micro scan (analytic, ~12 flop/param)
    n_params = active_params_total(cfg)
    f_opt = 12.0 * n_params if shape.kind == "train" else 0.0

    def scale(key, a, b, is_flops=False):
        unit = max(b - a, 0.0)
        base = a - (f_opt if is_flops else 0.0)
        return (f_opt if is_flops else 0.0) + m * (base + (r_eff - 1) * unit)

    flops = scale("flops", float(cost_a.get("flops", 0)),
                  float(cost_b.get("flops", 0)), is_flops=True)
    bytes_acc = scale("bytes", float(cost_a.get("bytes accessed", 0)),
                      float(cost_b.get("bytes accessed", 0)))
    coll = {}
    for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute"):
        coll[k] = {
            "count": coll_a[k]["count"],
            "bytes": scale(k, float(coll_a[k]["bytes"]),
                           float(coll_b[k]["bytes"])),
        }
    from .roofline import _FACTORS
    coll["weighted_bytes"] = sum(
        coll[k]["bytes"] * f for k, f in _FACTORS.items())
    return ({"flops": flops, "bytes accessed": bytes_acc}, coll)


def active_params_total(cfg: ArchConfig) -> float:
    """All parameters (not just active) — for optimizer flop estimates."""
    from .roofline import active_params
    total = active_params(cfg)
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
        total += per_expert * (cfg.n_experts - cfg.top_k) * n_moe_layers
    return total


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             save: bool = True, variant: str = "baseline") -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    out: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "ok": False,
    }
    try:
        cfg = get_arch(arch_id)
        shape = SHAPES[shape_name]
        mesh, lowered = lower_cell(arch_id, shape_name, multi_pod,
                                   variant=variant)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        n_dev = mesh.size
        dp_n = M.axis_size(mesh, M.dp_axes(mesh))
        nm = max(shape.global_batch // dp_n, 1) if shape.kind == "train" else 1
        nm = max(nm // VARIANTS.get(variant, {}).get("n_micro_div", 1), 1)
        cost, coll = extrapolated_costs(arch_id, shape_name, multi_pod, cfg,
                                        shape, variant, nm)
        rf = roofline_terms(cfg, shape, cost, coll, n_dev)
        out.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            devices=n_dev,
            memory={
                "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
                "temp": getattr(mem, "temp_size_in_bytes", 0),
                "arguments": getattr(mem, "argument_size_in_bytes", 0),
                "output": getattr(mem, "output_size_in_bytes", 0),
                "aliased": getattr(mem, "alias_size_in_bytes", 0),
            },
            cost={
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
            },
            collectives=coll,
            roofline=rf,
        )
    except Exception as e:
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
    if save:
        ART.mkdir(parents=True, exist_ok=True)
        (ART / f"{arch_id}__{shape_name}__{mesh_name}__{variant}.json").write_text(
            json.dumps(out, indent=1, default=str))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        pairs = runnable_cells()
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        pairs = [(a, s) for a in archs for s in shapes
                 if (a, s) in runnable_cells()]
    for a, s in pairs:
        meshes = [False, True]
        if args.multi_pod or args.multi_pod_only:
            meshes = [True]
        elif args.single_pod_only:
            meshes = [False]
        for mp in meshes:
            cells.append((a, s, mp))

    for a, s, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        art = ART / f"{a}__{s}__{mesh_name}__{args.variant}.json"
        if args.skip_existing and art.exists():
            prev = json.loads(art.read_text())
            if prev.get("ok"):
                print(f"[dryrun] {a} × {s} × {mesh_name}: SKIP (exists)", flush=True)
                continue
        r = run_cell(a, s, mp, variant=args.variant)
        status = "OK" if r["ok"] else f"FAIL ({r.get('error', '?')[:120]})"
        extra = ""
        if r["ok"]:
            gb = r["memory"]["bytes_per_device"] / 2**30
            bt = r["roofline"]["bottleneck"]
            extra = f" mem/dev={gb:.2f}GiB bottleneck={bt} compile={r['compile_s']}s"
        print(f"[dryrun] {a} × {s} × {'2x16x16' if mp else '16x16'}: {status}{extra}",
              flush=True)


if __name__ == "__main__":
    main()
