"""Batched serving launcher: continuous-batching-style loop.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b \
        --batch 4 --prompt-len 32 --gen 16 [--smoke]

Maintains a request queue; each engine iteration either prefills a
waiting batch slot or decodes one token for all active slots (the
simple alternating policy — a production engine would interleave at
finer granularity; the step functions are the same ones the dry-run
lowers at scale).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import get_arch
from ..model import transformer as T


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (1, plen)
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch decode engine with greedy sampling."""

    def __init__(self, cfg, params, batch: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.cache = T.init_cache(cfg, batch, max_len)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.lengths = [0] * batch
        self.slots: List[Optional[Request]] = [None] * batch
        self._decode = jax.jit(
            lambda p, t, c, n: T.decode_step(p, cfg, t, c, n))
        self._prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t))

    def admit(self, req: Request, slot: int):
        logits, pre = self._prefill(self.params, req.prompt)
        # copy the prefilled cache rows into the batch cache at `slot`
        plen = req.prompt.shape[1]

        def merge(dst, src):
            if dst.ndim != src.ndim:
                return dst
            # dst: (..., batch, S, ...); src: (..., 1, plen, ...)
            bdim = next((i for i in range(dst.ndim)
                         if dst.shape[i] == self.batch
                         and src.shape[i] == 1), None)
            if bdim is None:
                return dst
            idx = [slice(None)] * dst.ndim
            idx[bdim] = slice(slot, slot + 1)
            sdim = bdim + 1
            idx[sdim] = slice(0, src.shape[sdim])
            return dst.at[tuple(idx)].set(src.astype(dst.dtype))

        self.cache = jax.tree.map(merge, self.cache, pre)
        self.slots[slot] = req
        self.lengths[slot] = plen
        nxt = int(jnp.argmax(logits[0]))
        req.generated.append(nxt)
        self.tokens = self.tokens.at[slot, 0].set(nxt)

    def step(self):
        n = max(self.lengths)
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache, jnp.int32(n))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        for i, req in enumerate(self.slots):
            if req is not None and not req.done:
                req.generated.append(int(nxt[i]))
                self.lengths[i] += 1


def warm_kernel_plans(cfg, max_len: int) -> None:
    """Plan the serving kernels up front, through a schedd daemon when
    ``$POLYTOPS_SCHEDD_SOCK`` names one (so N serving processes
    amortize one scheduler) and in-process otherwise — ``akg``'s remote
    hook makes the same call total either way."""
    from ..core import akg
    from ..core.schedclient import maybe_client

    client = maybe_client()
    plans = [akg.plan_matmul(cfg.d_model, cfg.d_ff, cfg.d_model),
             akg.plan_attention(max_len, max_len, cfg.hd)]
    degraded = sum(1 for p in plans if p.degraded)
    if client is not None:
        st = client.stats.as_dict()
        via = (f"via schedd ({client.sock_path}, "
               f"remote_ok={st['remote_ok']} fallbacks={st['fallbacks']})")
    else:
        via = "in-process"
    print(f"serve: {len(plans)} kernel plans warmed {via}"
          + (f", {degraded} degraded" if degraded else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    warm_kernel_plans(cfg, args.prompt_len + args.gen + 1)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    eng = ServeEngine(cfg, params, args.batch,
                      args.prompt_len + args.gen + 1)
    for i in range(args.batch):
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (1, args.prompt_len), 2, cfg.vocab)
        eng.admit(Request(i, prompt), slot=i)
    t0 = time.time()
    for _ in range(args.gen):
        eng.step()
    dt = time.time() - t0
    print(f"{args.batch} seqs × {args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/max(dt,1e-9):.1f} tok/s, CPU smoke)")
    for req in eng.slots:
        print(f"req{req.rid}: {req.generated[:10]}")


if __name__ == "__main__":
    main()
