"""Batched serving launcher: continuous batching over PolyTOPS-planned
kernels.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b \
        --batch 4 --prompt-len 32 --gen 16 [--engine continuous] \
        [--pallas] [--smoke]

Two engines share the model's step functions:

* :class:`ServeEngine` — the legacy alternating loop: whole-prompt
  prefill into a slot, then lock-step decode of every active slot with a
  shared ``max(lengths)`` cache length.  Kept as the baseline the bench
  compares against (and because the dry-run lowers its step functions).
* :class:`ContinuousEngine` — finer-grained continuous batching:
  per-request admission into free slots, prompt prefill in fixed-size
  chunks interleaved with decode ticks (a long prompt never stalls
  in-flight decodes), ragged per-slot cache lengths, and paged KV — the
  decode tick reads only the page-aligned used prefix of the cache, page
  size from ``plan_attention``'s k tile.  One host sync per tick.  With
  ``use_pallas=True`` the model layers route through the Pallas kernels
  (flash attention with the SMEM q-offset for prefill chunks, the fused
  scan+gate kernel for Mamba archs) — see :mod:`repro.model.pallas_mode`.
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import get_arch
from ..model import pallas_mode
from ..model import transformer as T


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (1, plen)
    generated: List[int] = field(default_factory=list)
    done: bool = False
    max_new: int = 0               # 0 = engine default
    t_submit: float = 0.0
    t_first: float = 0.0           # first generated token (prefill done)
    token_times: List[float] = field(default_factory=list)


def _merge_slot(cache: Dict, pre: Dict, slot) -> Dict:
    """Write a b=1 prefill cache into batch slot ``slot`` structurally:
    "slots" entries carry batch on axis 1, "tail" entries on axis 0 (a
    fact of init_cache's layout — not a shape heuristic; matching on
    sizes silently skipped mismatched leaves and left stale rows)."""
    def wr(axis):
        def go(dst, src):
            starts = [0] * dst.ndim
            starts[axis] = slot
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                starts)
        return go
    return {"slots": [jax.tree.map(wr(1), c, sc)
                      for c, sc in zip(cache["slots"], pre["slots"])],
            "tail": [jax.tree.map(wr(0), c, sc)
                     for c, sc in zip(cache["tail"], pre["tail"])]}


class ServeEngine:
    """Fixed-batch decode engine with greedy sampling (alternating
    prefill/decode baseline)."""

    def __init__(self, cfg, params, batch: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.cache = T.init_cache(cfg, batch, max_len)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.lengths = [0] * batch
        self.slots: List[Optional[Request]] = [None] * batch
        self._decode = jax.jit(
            lambda p, t, c, n: T.decode_step(p, cfg, t, c, n))
        self._prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t))
        self._merge = jax.jit(
            lambda c, pre, s: _merge_slot(T.zero_cache_slot(c, s), pre, s))

    def admit(self, req: Request, slot: int):
        logits, pre = self._prefill(self.params, req.prompt)
        # zero the slot's rows first (reused-slot hygiene: a shorter new
        # prompt must not expose the previous occupant's KV rows through
        # the shared max(lengths) decode mask), then merge structurally.
        self.cache = self._merge(self.cache, pre, jnp.int32(slot))
        self.slots[slot] = req
        self.lengths[slot] = req.prompt.shape[1]
        nxt = int(jnp.argmax(logits[0]))
        req.generated.append(nxt)
        self.tokens = self.tokens.at[slot, 0].set(nxt)

    def reset(self):
        """Back to the post-init state, keeping compiled step functions."""
        self.cache = jax.tree.map(jnp.zeros_like, self.cache)
        self.tokens = jnp.zeros((self.batch, 1), jnp.int32)
        self.lengths = [0] * self.batch
        self.slots = [None] * self.batch

    def step(self):
        n = max(self.lengths)
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache, jnp.int32(n))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        for i, req in enumerate(self.slots):
            if req is not None and not req.done:
                req.generated.append(int(nxt[i]))
                self.lengths[i] += 1


FREE, PREFILL, DECODE = 0, 1, 2


class ContinuousEngine:
    """Continuous-batching engine: per-request admission, chunked
    prefill interleaved with decode ticks, ragged paged KV.

    All decode-loop state (last token, per-slot lengths, generated-token
    buffer) lives on device and is updated functionally inside the jit'd
    ticks, so the steady-state loop dispatches work without a single
    host sync — tokens are fetched in one blocking read per *request*
    (at retirement), not per token.  The host keeps an exact mirror of
    lengths/counters (greedy decoding with a token budget is
    deterministic bookkeeping), so admission and retirement decisions
    never have to read the device.  ``eos``-triggered stopping and
    ``sync=True`` (per-token latency measurement) opt back into one
    fetch per tick."""

    def __init__(self, cfg, params, batch: int, max_len: int, *,
                 chunk: int = 16, page: Optional[int] = None,
                 use_pallas: bool = False, max_new: int = 16,
                 eos: Optional[int] = None, sync: bool = False,
                 pallas_opts: Optional[Dict] = None):
        from ..core import akg

        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.chunk, self.max_new, self.eos = chunk, max_new, eos
        self.sync = sync or eos is not None
        # pallas_opts: extra PallasMode fields (threshold overrides for
        # small-shape parity tests; see model/pallas_mode.py)
        self._mode_kw = dict(enabled=use_pallas, **(pallas_opts or {}))
        # paged-KV geometry from the scheduler: the attention plan's k
        # tile is the unit the flash kernel streams, so pages align with
        # kernel blocks and the page bound costs no masking slop
        plan = akg.plan_attention(max(chunk, 8), max_len, cfg.hd)
        self.page = page or max(min(plan.tile.get("kk", 128), max_len), 8)

        self.cache = T.init_cache(cfg, batch, max_len)
        # device-resident decode state: (tokens (b,1), lengths (b,),
        # out_buf (b, max_new), out_pos (b,))
        self.dev = (jnp.zeros((batch, 1), jnp.int32),
                    jnp.zeros((batch,), jnp.int32),
                    jnp.zeros((batch, max_new), jnp.int32),
                    jnp.zeros((batch,), jnp.int32))
        self.lengths = [0] * batch          # host mirror of dev[1]
        self.gen_count = [0] * batch        # host mirror of dev[3]
        self.state = [FREE] * batch
        self.slots: List[Optional[Request]] = [None] * batch
        self.prefill_pos = [0] * batch
        self.queue: Deque[Request] = deque()
        self._active = jnp.zeros((batch,), bool)
        # tick accounting for the prefill/decode overlap ratio
        self.ticks = self.ticks_decode = self.ticks_prefill = 0
        self.ticks_overlap = 0

        def _decode_tick(p, c, dev, act, kv):
            toks, lens, buf, pos = dev
            logits, c = T.serve_decode_step(p, cfg, toks, c, lens, act, kv)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)        # (b,)
            toks = jnp.where(act[:, None], nxt[:, None], toks)
            lens = lens + act
            upd = jax.vmap(lambda b, t, i:
                           jax.lax.dynamic_update_slice(b, t[None], (i,)))
            buf = jnp.where(act[:, None], upd(buf, nxt, pos), buf)
            pos = pos + act
            return c, (toks, lens, buf, pos), nxt

        def _chunk_tick(p, toks, c, dev, off, slot, last, kv):
            sub = T.cache_slot_view(c, slot)
            logits, sub = T.chunk_step(p, cfg, toks, sub, off, kv)
            c = T.cache_slot_write(c, sub, slot)
            t, lens, buf, pos = dev
            sl = jnp.arange(t.shape[0]) == slot
            end = off + toks.shape[1]
            lens = jnp.where(sl, end, lens)
            # final chunk: its last-position logits seed decoding
            ctok = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            fin = sl & last
            t = jnp.where(fin[:, None], ctok, t)
            buf = jnp.where(fin[:, None]
                            & (jnp.arange(buf.shape[1]) == 0)[None, :],
                            ctok, buf)
            pos = jnp.where(fin, 1, pos)
            return c, (t, lens, buf, pos)

        def _mixed_tick(p, toks, c, dev, act, off, slot, last, kv_d, kv_p):
            # overlap tick: decode every active slot AND land one prefill
            # chunk in a single dispatch.  Decode runs first: its garbage
            # write into the prefilling slot (row = that slot's current
            # length) is overwritten by the chunk that follows.
            c, dev, nxt = _decode_tick(p, c, dev, act, kv_d)
            c, dev = _chunk_tick(p, toks, c, dev, off, slot, last, kv_p)
            return c, dev, nxt

        def _decode_k(p, c, dev, act, kv, k):
            # k decode steps fused into one dispatch (steady state: no
            # prefill pending, so nothing competes for the tick)
            def body(carry, _):
                c, dev = carry
                c, dev, _ = _decode_tick(p, c, dev, act, kv)
                return (c, dev), None
            (c, dev), _ = jax.lax.scan(body, (c, dev), None, length=k)
            return c, dev

        self._decode = jax.jit(_decode_tick, static_argnames=("kv",),
                               donate_argnums=(1, 2))
        self._decode_k = jax.jit(_decode_k, static_argnames=("kv", "k"),
                                 donate_argnums=(1, 2))
        self._chunk = jax.jit(_chunk_tick, static_argnames=("kv",),
                              donate_argnums=(2, 3))
        self._mixed = jax.jit(_mixed_tick,
                              static_argnames=("kv_d", "kv_p"),
                              donate_argnums=(2, 3))

        def _admit(c, dev, s):
            t, lens, buf, pos = dev
            sl = jnp.arange(t.shape[0]) == s
            return (T.zero_cache_slot(c, s),
                    (t, jnp.where(sl, 0, lens), buf, jnp.where(sl, 0, pos)))

        self._admit = jax.jit(_admit, donate_argnums=(0, 1))

    # -- admission -------------------------------------------------------
    def submit(self, req: Request):
        plen = req.prompt.shape[1]
        if plen + (req.max_new or self.max_new) > self.max_len:
            raise ValueError(f"request {req.rid} exceeds max_len")
        if (req.max_new or self.max_new) > self.dev[2].shape[1]:
            raise ValueError(f"request {req.rid} exceeds token buffer")
        req.t_submit = req.t_submit or time.time()
        self.queue.append(req)

    def _set_state(self, i: int, st: int):
        self.state[i] = st
        self._active = jnp.asarray([s == DECODE for s in self.state])

    def _admit_free_slots(self):
        for i in range(self.batch):
            if not self.queue:
                return
            if self.state[i] == FREE:
                req = self.queue.popleft()
                # reused-slot hygiene: drop every cache row the previous
                # occupant wrote before the new request's chunks land
                self.cache, self.dev = self._admit(self.cache, self.dev,
                                                   jnp.int32(i))
                self.slots[i] = req
                self._set_state(i, PREFILL)
                self.prefill_pos[i] = 0
                self.lengths[i] = 0
                self.gen_count[i] = 0

    def _bucket(self, need: int) -> int:
        return min(-(-need // self.page) * self.page, self.max_len)

    # -- one engine tick -------------------------------------------------
    def tick(self) -> bool:
        """Run one engine iteration; returns True if any work was done."""
        pallas_mode.configure(**self._mode_kw)
        self._admit_free_slots()
        decoding = [i for i in range(self.batch) if self.state[i] == DECODE]
        prefilling = [i for i in range(self.batch)
                      if self.state[i] == PREFILL]
        if not decoding and not prefilling:
            return False
        self.ticks += 1
        nxt_dev = None

        if decoding and not prefilling and not self.queue and not self.sync:
            # steady state: every slot is decoding and nothing is waiting,
            # so fuse up to 16 greedy steps into one dispatch.  Safe
            # because retirement is count-based host bookkeeping: the
            # earliest any slot can retire is min remaining-budget steps
            # away, and a roomier kv bucket only adds exact-zero masked
            # rows (bit-identical logits).
            rem = min((self.slots[i].max_new or self.max_new)
                      - self.gen_count[i] for i in decoding)
            k = min(rem, 16)
            k = 1 << (k.bit_length() - 1)           # quantize: few traces
            if k > 1:
                kv = self._bucket(max(self.lengths[i]
                                      for i in decoding) + k)
                self.cache, self.dev = self._decode_k(
                    self.params, self.cache, self.dev, self._active, kv, k)
                self.ticks += k - 1
                self.ticks_decode += k
                for i in decoding:
                    self.lengths[i] += k
                    self.gen_count[i] += k
                    self._maybe_retire(i)
                return True

        kv_d = (self._bucket(max(self.lengths[i] for i in decoding) + 1)
                if decoding else 0)
        ci = prefilling[0] if prefilling else None
        if ci is not None:
            req = self.slots[ci]
            off = self.prefill_pos[ci]
            c = min(self.chunk, req.prompt.shape[1] - off)
            toks = req.prompt[:, off:off + c]
            kv_p = self._bucket(off + c)
            last = off + c == req.prompt.shape[1]

        if decoding and ci is not None:
            self.cache, self.dev, nxt_dev = self._mixed(
                self.params, toks, self.cache, self.dev, self._active,
                jnp.int32(off), jnp.int32(ci), jnp.asarray(last),
                kv_d, kv_p)
            self.ticks_decode += 1
            self.ticks_prefill += 1
            self.ticks_overlap += 1
        elif decoding:
            self.cache, self.dev, nxt_dev = self._decode(
                self.params, self.cache, self.dev, self._active, kv_d)
            self.ticks_decode += 1
        else:
            self.cache, self.dev = self._chunk(
                self.params, toks, self.cache, self.dev, jnp.int32(off),
                jnp.int32(ci), jnp.asarray(last), kv_p)
            self.ticks_prefill += 1

        if decoding:
            for i in decoding:
                self.lengths[i] += 1
                self.gen_count[i] += 1
        if ci is not None:
            self.prefill_pos[ci] = off + c
            self.lengths[ci] = off + c
            if last:
                self._set_state(ci, DECODE)
                self.gen_count[ci] = 1

        if self.sync:
            # per-token observation: one fetch per tick (EOS stopping /
            # latency measurement); otherwise the loop stays async
            nxt = jax.device_get(nxt_dev) if nxt_dev is not None else None
            now = time.time()
            for i in decoding:
                req = self.slots[i]
                req.generated.append(int(nxt[i]))
                req.token_times.append(now)
            if ci is not None and self.state[ci] == DECODE \
                    and self.gen_count[ci] == 1:
                req = self.slots[ci]
                req.t_first = now
                tok0 = int(jax.device_get(self.dev[0][ci, 0]))
                req.generated.append(tok0)
                req.token_times.append(now)

        for i in range(self.batch):
            if self.state[i] == DECODE:
                self._maybe_retire(i)
        return True

    def _maybe_retire(self, i: int):
        req = self.slots[i]
        limit = req.max_new or self.max_new
        if self.gen_count[i] >= limit or \
                (self.eos is not None and req.generated
                 and req.generated[-1] == self.eos):
            if not self.sync:
                # one blocking read per request: its finished token row
                n = self.gen_count[i]
                req.generated = [int(x) for x in
                                 jax.device_get(self.dev[2][i, :n])]
            req.done = True
            self._set_state(i, FREE)
            self.lengths[i] = 0

    def run(self) -> int:
        """Tick until the queue and all slots drain; returns tick count."""
        n = 0
        while self.tick():
            n += 1
        return n

    def reset(self):
        """Back to the post-init state, keeping compiled tick functions."""
        b = self.batch
        self.cache = jax.tree.map(jnp.zeros_like, self.cache)
        self.dev = (jnp.zeros((b, 1), jnp.int32),
                    jnp.zeros((b,), jnp.int32),
                    jnp.zeros_like(self.dev[2]),
                    jnp.zeros((b,), jnp.int32))
        self.lengths = [0] * b
        self.gen_count = [0] * b
        self.state = [FREE] * b
        self.slots = [None] * b
        self.prefill_pos = [0] * b
        self.queue.clear()
        self._active = jnp.zeros((b,), bool)
        self.ticks = self.ticks_decode = self.ticks_prefill = 0
        self.ticks_overlap = 0

    def overlap_ratio(self) -> float:
        busy = max(self.ticks_decode + self.ticks_prefill
                   - self.ticks_overlap, 1)
        return self.ticks_overlap / busy


def warm_kernel_plans(cfg, max_len: int, chunk: int = 16) -> None:
    """Plan the serving kernels up front, through a schedd daemon when
    ``$POLYTOPS_SCHEDD_SOCK`` names one (so N serving processes
    amortize one scheduler) and in-process otherwise — ``akg``'s remote
    hook makes the same call total either way."""
    from ..core import akg
    from ..core.schedclient import maybe_client

    client = maybe_client()
    plans = [akg.plan_matmul(cfg.d_model, cfg.d_ff, cfg.d_model),
             akg.plan_attention(max_len, max_len, cfg.hd),
             akg.plan_attention(max(chunk, 8), max_len, cfg.hd)]
    if cfg.d_inner and cfg.ssm_state:
        plans.append(akg.plan_scan_gate(max(chunk, 8), cfg.d_inner,
                                        cfg.ssm_state))
    degraded = sum(1 for p in plans if p.degraded)
    if client is not None:
        st = client.stats.as_dict()
        via = (f"via schedd ({client.sock_path}, "
               f"remote_ok={st['remote_ok']} fallbacks={st['fallbacks']})")
    else:
        via = "in-process"
    print(f"serve: {len(plans)} kernel plans warmed {via}"
          + (f", {degraded} degraded" if degraded else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--engine", choices=("alternating", "continuous"),
                    default="continuous")
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    max_len = args.prompt_len + args.gen + 1
    warm_kernel_plans(cfg, max_len, args.chunk)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (1, args.prompt_len), 2, cfg.vocab)
               for i in range(args.batch)]
    t0 = time.time()
    if args.engine == "alternating":
        eng = ServeEngine(cfg, params, args.batch, max_len)
        for i, prompt in enumerate(prompts):
            eng.admit(Request(i, prompt), slot=i)
        for _ in range(args.gen - 1):
            eng.step()
        reqs = [r for r in eng.slots if r is not None]
    else:
        ceng = ContinuousEngine(cfg, params, args.batch, max_len,
                                chunk=args.chunk, use_pallas=args.pallas,
                                max_new=args.gen)
        reqs = [Request(i, p) for i, p in enumerate(prompts)]
        for r in reqs:
            ceng.submit(r)
        ceng.run()
        print(f"overlap ratio: {ceng.overlap_ratio():.2f}, "
              f"page={ceng.page}")
    dt = time.time() - t0
    ntok = sum(len(r.generated) for r in reqs)
    print(f"{len(reqs)} seqs, {ntok} tokens in {dt:.2f}s "
          f"({ntok/max(dt,1e-9):.1f} tok/s, CPU smoke, {args.engine})")
    for req in reqs:
        print(f"req{req.rid}: {req.generated[:10]}")


if __name__ == "__main__":
    main()
