"""schedd: the fault-tolerant Unix-socket scheduling daemon.

    PYTHONPATH=src python -m repro.launch.schedd \
        --sock /run/user/$UID/schedd.sock [--cache-dir DIR] [--chaos]

The paper puts PolyTOPS *inside* a production compiler, where compiles
arrive concurrently from many clients and must be amortized, not
repeated.  ``schedd`` is that shape: a long-lived process owning one
:class:`~repro.core.schedcache.ScheduleCache` pool, serving
``schedule`` / ``autotune`` / ``plan`` requests over the wire protocol
in :mod:`repro.core.schedclient`.  Guarantees:

* **Request coalescing** — concurrent identical requests (same
  ``schedule_key`` / autotune-space digest / plan signature) share ONE
  in-flight computation: the first arrival computes, the rest block on
  its flight and receive the identical encoded response.  Warm
  non-degraded responses are additionally kept as pre-encoded frames,
  so a warm hit is one ``sendall`` of cached bytes — no re-pickling.

* **Deadline propagation** — a request's ``deadline_s`` (the client's
  remaining budget) resumes as a server-side
  :class:`~repro.core.resilience.Deadline` threaded into the ladder /
  autotuner, so the end-to-end budget covers the wire hop too.

* **Load shedding** — when ``max_inflight`` distinct computations are
  already running, new *keyed work* is refused with a typed
  ``overloaded`` response (the client's cue to fall back in-process);
  coalescible requests, frame-cache hits, ping and stats are always
  served — shedding protects the solver, not the socket.

* **Version handshake** — every connection opens with the four-version
  hello (:func:`repro.core.schedclient.wire_versions`); a skewed peer
  is rejected with ``version_skew`` before any pickle of a Schedule is
  exchanged.

* **Crash recovery** — accepted autotune work is journalled
  (begin/done rows, flock'd O_APPEND like the measurement pool) so a
  ``kill -9`` mid-request loses at most the in-flight measurement:
  every persistent store the daemon touches (schedule pickles, the
  winner store, ``measurements.jsonl``) already publishes atomically
  (PR 6), and on restart the journal's begin-without-done rows are
  counted as ``journal_recovered`` and cleared.  Degraded results are
  never persisted and never frame-cached — a transient fault cannot
  poison future clients.

* **Hostile-socket robustness** — per-connection recv timeouts drop
  slow-loris peers; bad magic, truncated frames, oversized lengths and
  unpicklable bodies get a best-effort typed ``bad_frame`` reply and a
  closed connection; no client behaviour can crash the daemon.

``--chaos`` enables the test-only ``test_delay_s`` request field (the
chaos sweep and bench use it to hold a computation open long enough to
race a second client or a ``kill -9`` against it).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import signal
import socket
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core import schedclient as wire
from ..core.resilience import Deadline, provenance, schedule_with_ladder
from ..core.schedcache import ScheduleCache, schedule_key, scop_fingerprint

try:
    import fcntl
except ImportError:            # non-POSIX: O_APPEND keeps lines atomic
    fcntl = None

JOURNAL_FILE = "schedd_journal.jsonl"


# ---------------------------------------------------------------------------
# autotune journal
# ---------------------------------------------------------------------------


class AutotuneJournal:
    """Append-only begin/done journal for accepted autotune work.

    The journal exists for *observability after a crash*, not for
    replay: every store autotune writes (winner pickles, the
    measurement pool) publishes atomically, so a ``kill -9``
    mid-request can only lose the in-flight measurement — the journal's
    begin-without-done rows say exactly which work that was.  Appends
    reuse the measurement pool's discipline (one ``write`` on an
    O_APPEND handle under an advisory flock); torn tail lines from a
    dying writer are tolerated on read.  Disk trouble degrades to
    "not journalled" — it never fails the request."""

    def __init__(self, path: str):
        self.path = path

    def _append(self, row: Dict[str, Any]) -> None:
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            with open(self.path, "a") as f:
                if fcntl is not None:
                    try:
                        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                    except OSError:
                        pass
                f.write(json.dumps(row, sort_keys=True) + "\n")
                f.flush()
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            pass

    def begin(self, key: str) -> None:
        self._append({"ev": "begin", "key": key, "pid": os.getpid(),
                      "t": time.time()})

    def done(self, key: str) -> None:
        self._append({"ev": "done", "key": key})

    def recover(self) -> List[str]:
        """Keys begun but never finished by a previous daemon (the work
        a crash interrupted).  Clears the journal atomically; returns []
        on any disk trouble."""
        orphans: List[str] = []
        try:
            with open(self.path) as f:
                begun: Dict[str, int] = {}
                for ln in f:
                    ln = ln.strip()
                    if not ln:
                        continue
                    try:
                        row = json.loads(ln)
                    except json.JSONDecodeError:
                        continue          # torn tail line from a kill -9
                    key = str(row.get("key"))
                    if row.get("ev") == "begin":
                        begun[key] = begun.get(key, 0) + 1
                    elif row.get("ev") == "done" and begun.get(key):
                        begun[key] -= 1
                orphans = sorted(k for k, n in begun.items() if n > 0)
            import tempfile
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path),
                                       suffix=".tmp")
            os.close(fd)
            os.replace(tmp, self.path)    # atomically truncate
        except FileNotFoundError:
            pass
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            return []
        return orphans


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------


class _Flight:
    """One in-flight keyed computation; waiters block on the event and
    read the identical encoded response frame."""

    __slots__ = ("event", "frame")

    def __init__(self):
        self.event = threading.Event()
        self.frame: Optional[bytes] = None


class _Shutdown(Exception):
    pass


class SchedDaemon:
    """See the module docstring.  Thread-per-connection; all shared
    state (the flight table, the frame cache, counters) is mutated
    under ``_lock``; the ScheduleCache itself relies on the GIL plus
    atomic on-disk publishes, same as the multi-process case."""

    def __init__(self, sock_path: str, cache_dir: Optional[str] = None, *,
                 max_inflight: int = 8, conn_timeout: float = 10.0,
                 frame_cache_cap: int = 256, chaos: bool = False):
        self.sock_path = sock_path
        self.cache = ScheduleCache(cache_dir=cache_dir)
        self.max_inflight = max_inflight
        self.conn_timeout = conn_timeout
        self.frame_cache_cap = frame_cache_cap
        self.chaos = chaos
        self.journal = (AutotuneJournal(os.path.join(self.cache.dir,
                                                     JOURNAL_FILE))
                        if self.cache.disk else None)
        self.recovered: List[str] = (self.journal.recover()
                                     if self.journal else [])
        self._lock = threading.Lock()
        self._flights: Dict[Any, _Flight] = {}
        self._frames: Dict[Any, bytes] = {}
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.counters: Dict[str, int] = {
            "requests": 0, "computed": 0, "coalesced": 0, "frame_hits": 0,
            "shed": 0, "bad_frames": 0, "version_skew": 0, "slow_loris": 0,
            "degraded": 0, "errors": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        d = os.path.dirname(self.sock_path)
        if d:
            os.makedirs(d, exist_ok=True)
        try:
            os.unlink(self.sock_path)     # stale socket from a kill -9
        except FileNotFoundError:
            pass
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.sock_path)
        os.chmod(self.sock_path, 0o600)   # same-user peers only
        self._listener.listen(64)
        self._listener.settimeout(0.2)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="schedd-accept", daemon=True)
        self._accept_thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        try:
            os.unlink(self.sock_path)
        except OSError:
            pass

    def wait(self) -> None:
        while not self._stop.wait(timeout=0.5):
            pass

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    # -- connection handling ----------------------------------------------

    def _handle_conn(self, conn: socket.socket) -> None:
        conn.settimeout(self.conn_timeout)
        try:
            hello = wire.recv_frame(conn, eof_ok=True)
            if hello is None:
                return
            if not isinstance(hello, dict) or hello.get("op") != "hello":
                self._count("bad_frames")
                wire.send_frame(conn, {"ok": False, "error": "bad_frame",
                                       "detail": "expected hello"})
                return
            skew = wire.version_skew(hello)
            if skew:
                self._count("version_skew")
                wire.send_frame(conn, {"ok": False, "error": "version_skew",
                                       "detail": skew})
                return
            wire.send_frame(conn, {"ok": True, "op": "hello",
                                   "pid": os.getpid(),
                                   **wire.wire_versions()})
            while True:
                req = wire.recv_frame(conn, eof_ok=True)
                if req is None:
                    return
                self._count("requests")
                if not isinstance(req, dict):
                    self._count("bad_frames")
                    wire.send_frame(conn, {
                        "ok": False, "error": "bad_frame",
                        "detail": f"request is {type(req).__name__}, "
                                  f"not a dict"})
                    continue
                # local_only: the handlers call into akg, whose remote
                # hook must never route the daemon's own work back to a
                # daemon (ourselves, for the in-process test harness)
                with wire.local_only():
                    frame = self._dispatch(req)
                conn.sendall(frame)
        except _Shutdown as e:
            try:
                conn.sendall(e.args[0])    # the "bye" frame
            except OSError:
                pass
            self._stop.set()
        except wire.ProtocolError as e:
            self._count("bad_frames")
            try:          # best effort: the peer may already be gone
                wire.send_frame(conn, {"ok": False, "error": "bad_frame",
                                       "detail": str(e)})
            except OSError:
                pass
        except socket.timeout:
            self._count("slow_loris")     # stalled peer: drop it
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    # -- dispatch ----------------------------------------------------------

    def _dispatch(self, req: Dict[str, Any]) -> bytes:
        op = req.get("op")
        if op == "ping":
            return wire.encode_frame({"ok": True, "op": "pong",
                                      "pid": os.getpid()})
        if op == "stats":
            return wire.encode_frame({"ok": True, "result": self.stats()})
        if op == "shutdown":
            frame = wire.encode_frame({"ok": True, "op": "bye"})
            raise _Shutdown(frame)        # _handle_conn sets the stop flag
        handlers = {"schedule": self._handle_schedule,
                    "autotune": self._handle_autotune,
                    "plan": self._handle_plan}
        if op not in handlers:
            return wire.encode_frame({"ok": False, "error": "bad_request",
                                      "detail": f"unknown op {op!r}"})
        try:
            return handlers[op](req)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:     # a handler bug must not kill the daemon
            self._count("errors")
            return wire.encode_frame({
                "ok": False, "error": "internal",
                "detail": f"{type(e).__name__}: {e}"})

    def _deadline(self, req: Dict[str, Any]) -> Optional[Deadline]:
        budget = req.get("deadline_s")
        return Deadline(float(budget)) if budget is not None else None

    def _test_delay(self, req: Dict[str, Any]) -> None:
        """Chaos/bench-only hold: lets a harness keep a computation
        in-flight long enough to race a second client or a kill -9."""
        if self.chaos and req.get("test_delay_s"):
            time.sleep(float(req["test_delay_s"]))

    # -- coalescing core ---------------------------------------------------

    def _serve_keyed(self, key: Optional[Any], compute,
                     deadline: Optional[Deadline]) -> bytes:
        """Coalesce + shed + frame-cache around one keyed computation.

        ``compute()`` returns ``(response_dict, cacheable)``; the
        encoded frame is shared with every coalesced waiter and, when
        cacheable (non-degraded success), kept for warm hits."""
        owner_flight: Optional[_Flight] = None
        if key is not None:
            with self._lock:
                cached = self._frames.get(key)
                if cached is not None:
                    self.counters["frame_hits"] += 1
                    return cached
                existing = self._flights.get(key)
                if existing is not None:
                    self.counters["coalesced"] += 1
                else:
                    if len(self._flights) >= self.max_inflight:
                        self.counters["shed"] += 1
                        return wire.encode_frame({
                            "ok": False, "error": "overloaded",
                            "detail": f"{len(self._flights)} computations "
                                      f"in flight (cap {self.max_inflight})"})
                    owner_flight = _Flight()
                    self._flights[key] = owner_flight
            if owner_flight is None:
                budget = None
                if deadline is not None and deadline.budget_s is not None:
                    budget = max(deadline.remaining(), 0.0)
                if not existing.event.wait(
                        timeout=budget if budget is not None else 600.0):
                    return wire.encode_frame({
                        "ok": False, "error": "deadline",
                        "detail": "coalesced wait exceeded the budget"})
                assert existing.frame is not None
                return existing.frame
        else:
            with self._lock:
                if len(self._flights) >= self.max_inflight:
                    self.counters["shed"] += 1
                    return wire.encode_frame({
                        "ok": False, "error": "overloaded",
                        "detail": f"{len(self._flights)} computations "
                                  f"in flight (cap {self.max_inflight})"})

        self._count("computed")
        try:
            resp, cacheable = compute()
            # encode inside the try: an unencodable result must not
            # leave coalesced waiters blocked on a never-set flight
            frame = wire.encode_frame(resp)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            self._count("errors")
            resp, cacheable = ({"ok": False, "error": "internal",
                                "detail": f"{type(e).__name__}: {e}"}, False)
            frame = wire.encode_frame(resp)
        if owner_flight is not None:
            with self._lock:
                self._flights.pop(key, None)
                if cacheable and resp.get("ok"):
                    if len(self._frames) >= self.frame_cache_cap:
                        self._frames.pop(next(iter(self._frames)))
                    self._frames[key] = frame
            owner_flight.frame = frame
            owner_flight.event.set()
        return frame

    # -- handlers ----------------------------------------------------------

    def _handle_schedule(self, req: Dict[str, Any]) -> bytes:
        from ..core.config import SchedulerConfig

        scop = req["scop"]
        config = req.get("config") or SchedulerConfig()
        engine = req.get("engine", "lex")
        with_tree = bool(req.get("with_tree", False))
        extra = dict(req.get("extra") or {})
        deadline = self._deadline(req)
        try:
            skey = schedule_key(scop, config, engine, extra=extra)
        except Exception:
            skey = None
        key = ("schedule", skey, with_tree) if skey is not None else None

        def compute() -> Tuple[Dict[str, Any], bool]:
            self._test_delay(req)
            sched = schedule_with_ladder(
                scop, config, engine=engine, deadline=deadline,
                cache=self.cache, with_tree=with_tree, **extra)
            prov = provenance(sched)
            if prov["degraded"]:
                self._count("degraded")
            meta = {"degraded": prov["degraded"], "rung": prov["rung"],
                    "pid": os.getpid()}
            # degraded schedules are served (every rung is legal) but
            # never frame-cached: the next request re-plans clean
            return ({"ok": True, "result": sched, "meta": meta},
                    not prov["degraded"])

        return self._serve_keyed(key, compute, deadline)

    def _handle_autotune(self, req: Dict[str, Any]) -> bytes:
        from ..core.autotune import autotune

        scop = req["scop"]
        kwargs = dict(req.get("kwargs") or {})
        deadline = self._deadline(req)
        try:
            digest = hashlib.sha256(json.dumps(
                {"scop": scop_fingerprint(scop),
                 "kwargs": {k: kwargs[k] for k in sorted(kwargs)}},
                sort_keys=True, separators=(",", ":"),
                default=str).encode()).hexdigest()
            key: Optional[Any] = ("autotune", digest)
        except Exception:
            digest, key = None, None

        def compute() -> Tuple[Dict[str, Any], bool]:
            # journal BEFORE the chaos hold: the work is accepted the
            # moment we own the flight, so a kill -9 during the hold is
            # exactly the "crash mid-request" the journal must witness
            if self.journal is not None and digest is not None:
                self.journal.begin(digest)
            self._test_delay(req)
            try:
                result = autotune(scop, deadline=deadline,
                                  cache=self.cache, **kwargs)
            finally:
                # done even on failure: the work is over either way —
                # only a crash leaves a begin-without-done orphan
                if self.journal is not None and digest is not None:
                    self.journal.done(digest)
            if result.degraded:
                self._count("degraded")
            meta = {"degraded": result.degraded, "source": result.source,
                    "pid": os.getpid()}
            return ({"ok": True, "result": result, "meta": meta},
                    not result.degraded)

        return self._serve_keyed(key, compute, deadline)

    def _handle_plan(self, req: Dict[str, Any]) -> bytes:
        from ..core import akg

        kind = req.get("kind")
        args = tuple(req.get("args") or ())
        kwargs = dict(req.get("kwargs") or {})
        planners = {"matmul": akg.plan_matmul,
                    "attention": akg.plan_attention,
                    "mamba_scan": akg.plan_mamba_scan}
        if kind not in planners:
            return wire.encode_frame({
                "ok": False, "error": "bad_request",
                "detail": f"unknown plan kind {kind!r}"})
        try:
            key: Optional[Any] = ("plan", kind, args,
                                  tuple(sorted(kwargs.items())))
        except TypeError:
            key = None
        deadline = self._deadline(req)

        def compute() -> Tuple[Dict[str, Any], bool]:
            self._test_delay(req)
            plan = planners[kind](*args, **kwargs)
            if plan.degraded:
                self._count("degraded")
            meta = {"degraded": plan.degraded, "pid": os.getpid()}
            return ({"ok": True, "result": plan, "meta": meta},
                    not plan.degraded)

        return self._serve_keyed(key, compute, deadline)

    # -- introspection -----------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self.counters)
            inflight = len(self._flights)
            frames = len(self._frames)
        return {
            "pid": os.getpid(),
            "sock": self.sock_path,
            "cache_dir": self.cache.dir,
            "counters": counters,
            "inflight": inflight,
            "frame_cache": frames,
            "cache": self.cache.stats.as_dict(),
            "journal_recovered": len(self.recovered),
            "journal_recovered_keys": list(self.recovered),
            "versions": wire.wire_versions(),
            "chaos": self.chaos,
        }


def default_socket_path() -> str:
    env = os.environ.get(wire.SOCKET_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "polytops",
                        "schedd.sock")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sock", default=default_socket_path(),
                    help="Unix socket path (default $POLYTOPS_SCHEDD_SOCK "
                         "or ~/.cache/polytops/schedd.sock)")
    ap.add_argument("--cache-dir", default=None,
                    help="schedule-cache pool (default schedcache's)")
    ap.add_argument("--max-inflight", type=int, default=8)
    ap.add_argument("--conn-timeout", type=float, default=10.0,
                    help="per-connection recv timeout (slow-loris guard)")
    ap.add_argument("--chaos", action="store_true",
                    help="enable the test-only test_delay_s request field")
    args = ap.parse_args(argv)

    # the daemon's own scheduling work must never route back through a
    # client pointed at ourselves
    wire.mark_server_process()

    daemon = SchedDaemon(args.sock, cache_dir=args.cache_dir,
                         max_inflight=args.max_inflight,
                         conn_timeout=args.conn_timeout, chaos=args.chaos)
    daemon.start()
    print(f"schedd: pid {os.getpid()} listening on {args.sock} "
          f"(cache {daemon.cache.dir}, "
          f"journal recovered {len(daemon.recovered)})", flush=True)

    def _term(signum, frame):
        daemon._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    try:
        daemon.wait()
    finally:
        daemon.stop()
    print("schedd: stopped", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
