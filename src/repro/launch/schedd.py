"""schedd: the fault-tolerant scheduling daemon (Unix socket + TCP).

    PYTHONPATH=src python -m repro.launch.schedd \
        --sock /run/user/$UID/schedd.sock [--workers N] [--cache-dir DIR] \
        [--listen host:port --keyfile FILE] [--peers host:port,...] \
        [--chaos]

The paper puts PolyTOPS *inside* a production compiler, where compiles
arrive concurrently from many clients and must be amortized, not
repeated.  ``schedd`` is that shape: a long-lived process owning one
:class:`~repro.core.schedcache.ScheduleCache` pool, serving
``schedule`` / ``autotune`` / ``plan`` requests over the wire protocol
in :mod:`repro.core.wire`.  Guarantees:

* **Request coalescing** — concurrent identical requests (same
  ``schedule_key`` / autotune-space digest / plan signature) share ONE
  in-flight computation: the first arrival computes, the rest block on
  its flight and receive the identical encoded response.  Warm
  non-degraded responses are additionally kept as pre-encoded frames,
  so a warm hit is one ``sendall`` of cached bytes — no re-pickling.

* **Worker pool** — with ``--workers N`` the accept loop stays a thin
  coalescing/shedding front and every non-coalesced keyed computation
  is dispatched to one of N *forked* worker processes (each inheriting
  the already-imported scheduling stack, so the fork is warm).  Distinct
  keys genuinely schedule in parallel across cores instead of
  serializing on one GIL.  The request's remaining deadline budget is
  re-measured at dispatch and propagated into the worker; worker
  failures come back as the same typed error dicts the inline path
  produces.  A worker that dies mid-job (``kill -9``, OOM) is detected
  through its pipe, counted, journalled as a witnessed crash, replaced,
  and the job is retried once on a fresh worker — a poison request
  burns exactly two workers and yields a typed ``worker_crashed``
  response (the client's cue to fall back in-process).  ``--workers 0``
  (the default) computes inline in the connection thread, the
  single-process behaviour this daemon always had.

* **Latency-saved frame cache** — warm frames are retained by a
  :class:`~repro.core.schedcache.FrameCache` scored on *measured
  compute seconds saved per byte* (each flight's wall time is recorded
  when its frame is admitted), evicting the lowest score first — a
  multi-second autotune frame is never displaced by a swarm of
  millisecond plan frames.

* **Winner-store push** — an autotune computation also returns its
  winning configuration's *schedule* (already computed during the
  search); the daemon pushes that frame into the frame cache **before**
  waking coalesced followers, so a follow-up ``schedule`` request for
  the tuned config is a warm one-``sendall`` hit even on its first
  arrival.

* **Deadline propagation** — a request's ``deadline_s`` (the client's
  remaining budget) resumes as a server-side
  :class:`~repro.core.resilience.Deadline` threaded into the ladder /
  autotuner (re-measured at worker dispatch), so the end-to-end budget
  covers the wire hop and the pool queue too.

* **Load shedding** — when ``max_inflight`` distinct computations are
  already running, new *keyed work* is refused with a typed
  ``overloaded`` response (the client's cue to fall back in-process);
  coalescible requests, frame-cache hits, ping and stats are always
  served — shedding protects the solver, not the socket.

* **Version handshake** — every connection opens with a JSON
  four-version hello (:func:`repro.core.wire.wire_versions`); a skewed
  peer is rejected with ``version_skew`` before any pickle of a
  Schedule is exchanged.

* **Authenticated TCP transport** — ``--listen host:port`` serves the
  same protocol to remote hosts, gated by an HMAC-SHA256
  challenge–response woven into the hello (shared key from
  ``--keyfile`` / ``$POLYTOPS_SCHEDD_KEY``; the daemon *refuses to
  listen* without one).  Handshake frames are JSON and capped at
  ``PRE_AUTH_MAX_FRAME_BYTES``, so an unauthenticated peer can neither
  reach ``pickle.loads`` nor make the daemon buffer a 64 MiB frame;
  after auth every frame carries a sequence-numbered MAC verified
  before its body is unpickled.  Bad credentials get a typed
  ``auth_failed`` reply and a closed connection — never a crash.

* **Peer winner push** — ``--peers host:port,...`` names sibling
  daemons; an autotune winner's pre-encoded schedule frame is pushed
  to every peer (async, best-effort, authenticated like any client) so
  a fleet shares tuned schedules without re-searching.  Reception
  reuses the local winner-push admission path: never displacing a
  hotter frame, never admitted over an in-flight computation, and
  pushed frames are never re-forwarded (no push loops).

* **Crash recovery** — accepted autotune work is journalled
  (begin/done rows, flock'd O_APPEND like the measurement pool) so a
  ``kill -9`` mid-request loses at most the in-flight measurement:
  every persistent store the daemon touches (schedule pickles, the
  winner store, ``measurements.jsonl``) already publishes atomically
  (PR 6), and on restart the journal's begin-without-done rows are
  counted as ``journal_recovered`` and cleared.  A worker killed
  mid-autotune is *witnessed*: the daemon appends a ``crashed`` row
  (which completes the begin, so a witnessed crash is never
  double-counted as an orphan on restart) and retries the job.
  Degraded results are never persisted and never frame-cached — a
  transient fault cannot poison future clients.

* **Hostile-socket robustness** — per-connection recv timeouts drop
  slow-loris peers; bad magic, truncated frames, oversized lengths and
  unpicklable bodies get a best-effort typed ``bad_frame`` reply and a
  closed connection; no client behaviour can crash the daemon.

``--chaos`` enables the test-only ``test_delay_s`` request field (the
chaos sweep and benches use it to hold a computation open long enough
to race a second client or a ``kill -9`` against it) and
``test_kill_worker`` (a pool worker SIGKILLs itself mid-job — the
worker-crash recovery drill).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import multiprocessing
import os
import queue
import signal
import socket
import sys
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from ..core import schedclient, wire
from ..core.resilience import Deadline, fault_point, provenance, \
    schedule_with_ladder
from ..core.schedcache import FrameCache, ScheduleCache, schedule_key, \
    scop_fingerprint

try:
    import fcntl
except ImportError:            # non-POSIX: O_APPEND keeps lines atomic
    fcntl = None

JOURNAL_FILE = "schedd_journal.jsonl"

#: a frame pushed from an autotune winner is valued at this fraction of
#: the autotune flight's wall time: a follower hitting it saves a
#: schedule computation, not the whole search — but the push should
#: still outrank millisecond plan frames under eviction pressure
PUSH_COST_FRACTION = 0.1

#: peer winner-push storm cap: at most MAX pushes *admitted* per sliding
#: WINDOW seconds.  A large fleet autotuning in lock-step pushes its
#: winners everywhere at once; unbounded admission would churn a
#: daemon's own hot frames through the latency-saved eviction fight.
#: Excess pushes are refused (not errors — the sender treats pushes as
#: best-effort) and tallied as ``push_capped`` on the frame cache's
#: CacheStats.  Overridable per daemon via --push-storm-max/-window or
#: $POLYTOPS_PUSH_STORM_MAX / $POLYTOPS_PUSH_STORM_WINDOW.
PUSH_STORM_MAX = 32
PUSH_STORM_WINDOW_S = 10.0

#: set in pool workers only — guards the chaos-only self-kill field so
#: an inline daemon can never SIGKILL itself
_IN_POOL_WORKER = False


# ---------------------------------------------------------------------------
# autotune journal
# ---------------------------------------------------------------------------


class AutotuneJournal:
    """Append-only begin/done/crashed journal for accepted autotune work.

    The journal exists for *observability after a crash*, not for
    replay: every store autotune writes (winner pickles, the
    measurement pool) publishes atomically, so a ``kill -9``
    mid-request can only lose the in-flight measurement — the journal's
    begin-without-done rows say exactly which work that was.  A pool
    worker's death is different: the daemon survives to witness it, so
    it appends a ``crashed`` row — which completes the begin (the loss
    is already accounted) instead of leaving a false orphan for the
    next restart.  Appends reuse the measurement pool's discipline (one
    ``write`` on an O_APPEND handle under an advisory flock); torn tail
    lines from a dying writer are tolerated on read.  Disk trouble
    degrades to "not journalled" — it never fails the request."""

    def __init__(self, path: str):
        self.path = path

    def _append(self, row: Dict[str, Any]) -> None:
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            with open(self.path, "a") as f:
                if fcntl is not None:
                    try:
                        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                    except OSError:
                        pass
                f.write(json.dumps(row, sort_keys=True) + "\n")
                f.flush()
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            pass

    def begin(self, key: str) -> None:
        self._append({"ev": "begin", "key": key, "pid": os.getpid(),
                      "t": time.time()})

    def done(self, key: str) -> None:
        self._append({"ev": "done", "key": key})

    def crashed(self, key: str, detail: str = "") -> None:
        """A worker died computing ``key`` and the daemon witnessed it —
        completes the begin so restart-time recovery doesn't re-count a
        loss that was already observed and (once) retried."""
        self._append({"ev": "crashed", "key": key, "detail": detail})

    def recover(self) -> List[str]:
        """Keys begun but never finished by a previous daemon (the work
        a crash interrupted).  ``done`` and witnessed ``crashed`` rows
        both complete a begin.  Clears the journal atomically; returns
        [] on any disk trouble."""
        orphans: List[str] = []
        try:
            with open(self.path) as f:
                begun: Dict[str, int] = {}
                for ln in f:
                    ln = ln.strip()
                    if not ln:
                        continue
                    try:
                        row = json.loads(ln)
                    except json.JSONDecodeError:
                        continue          # torn tail line from a kill -9
                    key = str(row.get("key"))
                    if row.get("ev") == "begin":
                        begun[key] = begun.get(key, 0) + 1
                    elif (row.get("ev") in ("done", "crashed")
                          and begun.get(key)):
                        begun[key] -= 1
                orphans = sorted(k for k, n in begun.items() if n > 0)
            import tempfile
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path),
                                       suffix=".tmp")
            os.close(fd)
            os.replace(tmp, self.path)    # atomically truncate
        except FileNotFoundError:
            pass
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            return []
        return orphans


# ---------------------------------------------------------------------------
# the computation itself — shared by the inline path and pool workers
# ---------------------------------------------------------------------------


def compute_request(op: str, req: Dict[str, Any], cache: ScheduleCache, *,
                    chaos: bool = False,
                    deadline: Optional[Deadline] = None
                    ) -> Tuple[Dict[str, Any], bool, List[Tuple[Any, Dict[str, Any]]]]:
    """Run one keyed computation exactly as the daemon serves it.

    Returns ``(response_dict, cacheable, pushes)``: the wire response,
    whether its frame may be retained warm (non-degraded success), and
    any *push* entries — ``(frame_key, response_dict)`` pairs for
    sibling keys this computation warmed as a by-product (today: an
    autotune winner's schedule).  Runs identically inline (``--workers
    0``) and inside a forked pool worker; typed failures come back as
    error dicts, anything else raises for the caller to marshal.
    """
    if chaos and req.get("test_kill_worker") and _IN_POOL_WORKER:
        os.kill(os.getpid(), signal.SIGKILL)      # the kill -9 drill
    if deadline is None:
        budget = req.get("deadline_s")
        deadline = Deadline(float(budget)) if budget is not None else None
    if chaos and req.get("test_delay_s"):
        time.sleep(float(req["test_delay_s"]))

    if op == "schedule":
        return _compute_schedule(req, cache, deadline)
    if op == "autotune":
        return _compute_autotune(req, cache, deadline)
    if op == "plan":
        return _compute_plan(req, cache, deadline)
    return ({"ok": False, "error": "bad_request",
             "detail": f"unknown op {op!r}"}, False, [])


def _compute_schedule(req, cache, deadline):
    from ..core.config import SchedulerConfig

    scop = req["scop"]
    config = req.get("config") or SchedulerConfig()
    engine = req.get("engine", "lex")
    with_tree = bool(req.get("with_tree", False))
    extra = dict(req.get("extra") or {})
    sched = schedule_with_ladder(
        scop, config, engine=engine, deadline=deadline,
        cache=cache, with_tree=with_tree, **extra)
    prov = provenance(sched)
    meta = {"degraded": prov["degraded"], "rung": prov["rung"],
            "pid": os.getpid()}
    # degraded schedules are served (every rung is legal) but never
    # frame-cached: the next request re-plans clean
    return ({"ok": True, "result": sched, "meta": meta},
            not prov["degraded"], [])


def _compute_autotune(req, cache, deadline):
    from ..core.autotune import autotune

    scop = req["scop"]
    kwargs = dict(req.get("kwargs") or {})
    result = autotune(scop, deadline=deadline, cache=cache, **kwargs)
    meta = {"degraded": result.degraded, "source": result.source,
            "pid": os.getpid()}
    pushes: List[Tuple[Any, Dict[str, Any]]] = []
    if not result.degraded:
        # winner-store push: the search already scheduled the winning
        # base through the cache, so its Schedule is warm here — hand
        # it up so the daemon can pre-encode the frame a follower's
        # plain `schedule` request for the tuned config would ask for
        try:
            wcfg = result.config.scheduler_config()
            wkey = schedule_key(scop, wcfg, "lex")
            sched = cache.get(wkey) if wkey is not None else None
            if sched is not None and not getattr(sched, "degraded", False):
                pushes.append((("schedule", wkey, False),
                               {"ok": True, "result": sched,
                                "meta": {"degraded": False, "rung": 0,
                                         "pid": os.getpid(),
                                         "pushed": True}}))
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            pass          # the push is an optimization, never a failure
    return ({"ok": True, "result": result, "meta": meta},
            not result.degraded, pushes)


def _compute_plan(req, cache, deadline):
    from ..core import akg

    kind = req.get("kind")
    planners = {"matmul": akg.plan_matmul,
                "attention": akg.plan_attention,
                "mamba_scan": akg.plan_mamba_scan,
                "scan_gate": akg.plan_scan_gate}
    if kind not in planners:
        return ({"ok": False, "error": "bad_request",
                 "detail": f"unknown plan kind {kind!r}"}, False, [])
    args = tuple(req.get("args") or ())
    kwargs = dict(req.get("kwargs") or {})
    plan = planners[kind](*args, **kwargs)
    meta = {"degraded": plan.degraded, "pid": os.getpid()}
    return ({"ok": True, "result": plan, "meta": meta},
            not plan.degraded, [])


# ---------------------------------------------------------------------------
# the worker pool
# ---------------------------------------------------------------------------


class WorkerCrash(Exception):
    """A pool worker died (or wedged past its cap) computing a job.
    Internal to the daemon — on the wire this becomes the typed
    ``worker_crashed`` error kind."""


def _worker_main(conn, cache_dir: Optional[str], disk: bool,
                 chaos: bool) -> None:
    """One pool worker: recv job → compute → send result, forever.

    Forked from the daemon after the scheduling stack is imported, so
    the fork inherits warm modules.  Marks itself a server process
    (its own akg/plan work must never route back through a client),
    opens its own ScheduleCache handle on the shared pool directory
    (the disk tier's atomic publishes make cross-process sharing safe),
    and exits via ``os._exit`` so inherited atexit machinery (pytest,
    coverage) never runs in the child."""
    global _IN_POOL_WORKER
    _IN_POOL_WORKER = True
    schedclient.mark_server_process()
    try:
        signal.signal(signal.SIGINT, signal.SIG_IGN)
        signal.signal(signal.SIGTERM, signal.SIG_DFL)
    except (ValueError, OSError):
        pass
    cache = ScheduleCache(cache_dir=cache_dir, disk=disk)
    code = 0
    while True:
        try:
            job = conn.recv()
        except (EOFError, OSError):
            break
        if job is None:                   # clean shutdown sentinel
            break
        op, req = job
        t0 = time.perf_counter()
        try:
            resp, cacheable, pushes = compute_request(op, req, cache,
                                                      chaos=chaos)
        except (KeyboardInterrupt, SystemExit):
            code = 1
            break
        except Exception as e:            # typed marshalling, never a crash
            resp, cacheable, pushes = (
                {"ok": False, "error": "internal",
                 "detail": f"{type(e).__name__}: {e}"}, False, [])
        payload = (resp, cacheable, pushes, time.perf_counter() - t0)
        try:
            conn.send(payload)
        except (BrokenPipeError, OSError):
            break
        except Exception as e:            # unpicklable result: typed reply
            try:
                conn.send(({"ok": False, "error": "internal",
                            "detail": f"unmarshallable result: "
                                      f"{type(e).__name__}: {e}"},
                           False, [], time.perf_counter() - t0))
            except Exception:
                break
    os._exit(code)


class _WorkerProc:
    __slots__ = ("proc", "conn")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn


class WorkerPool:
    """N forked worker processes, each serving one job at a time.

    Dispatch is pull-based: a daemon connection thread takes an idle
    worker off the queue, sends the job down its pipe, and waits for
    the reply while watching liveness — so a ``kill -9`` of a worker is
    detected within the poll interval, the corpse is replaced, and
    :meth:`run` retries the job once on a fresh worker.  A worker that
    exceeds the job cap (the request deadline plus grace, or
    ``job_timeout_s``) is presumed wedged, killed and replaced the same
    way.  Workers are forked *after* the scheduling stack is imported
    into the daemon, so every worker starts warm and respawns never
    race daemon threads through the import machinery."""

    POLL_S = 0.1
    GRACE_S = 10.0

    def __init__(self, workers: int, cache_dir: Optional[str], *,
                 disk: bool = True, chaos: bool = False,
                 job_timeout_s: float = 600.0):
        # warm the stack once in the parent; every fork inherits it
        from ..core import akg              # noqa: F401
        from ..core import autotune         # noqa: F401
        from ..core import config           # noqa: F401
        from ..core import scheduler        # noqa: F401

        self.workers = workers
        self.cache_dir = cache_dir
        self.disk = disk
        self.chaos = chaos
        self.job_timeout_s = job_timeout_s
        try:
            self._ctx = multiprocessing.get_context("fork")
        except ValueError:                  # non-POSIX: cold spawns
            self._ctx = multiprocessing.get_context()
        self._lock = threading.Lock()
        self._idle: "queue.Queue[_WorkerProc]" = queue.Queue()
        self._procs: List[_WorkerProc] = []
        self.spawned = 0
        self.crashes = 0
        self.jobs = 0
        self._closed = False
        for _ in range(workers):
            self._idle.put(self._spawn())

    def _spawn(self) -> _WorkerProc:
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_worker_main,
            args=(child, self.cache_dir, self.disk, self.chaos),
            daemon=True, name="schedd-worker")
        proc.start()
        child.close()
        w = _WorkerProc(proc, parent)
        with self._lock:
            self._procs.append(w)
            self.spawned += 1
        return w

    def _retire(self, w: _WorkerProc) -> None:
        with self._lock:
            if w in self._procs:
                self._procs.remove(w)
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.kill()
        w.proc.join(timeout=5.0)

    def _acquire(self, deadline: Optional[Deadline]) -> _WorkerProc:
        cap = self.job_timeout_s
        if deadline is not None and deadline.budget_s is not None:
            cap = min(cap, max(deadline.remaining(), 0.0) + self.GRACE_S)
        end = time.monotonic() + cap
        while True:
            if self._closed:
                raise WorkerCrash("pool closed")
            try:
                w = self._idle.get(timeout=self.POLL_S)
            except queue.Empty:
                if time.monotonic() >= end:
                    raise WorkerCrash(
                        f"no idle worker within {cap:.1f}s "
                        f"({self.workers} workers all busy)")
                continue
            if w.proc.is_alive():
                return w
            # a corpse parked in the idle queue (killed between jobs)
            with self._lock:
                self.crashes += 1
            self._retire(w)
            self._idle.put(self._spawn())

    def run_once(self, op: str, req: Dict[str, Any],
                 deadline: Optional[Deadline]) -> Tuple:
        """One job on one worker; raises :class:`WorkerCrash` when the
        worker dies or wedges.  Returns the worker's
        ``(resp, cacheable, pushes, compute_s)`` tuple."""
        w = self._acquire(deadline)
        with self._lock:
            self.jobs += 1
        lost = False
        try:
            # the budget is re-measured at dispatch: pool queue wait has
            # already consumed part of the client's remaining time
            if deadline is not None and deadline.budget_s is not None:
                req = dict(req, deadline_s=max(deadline.remaining(), 0.0))
            cap = self.job_timeout_s
            if deadline is not None and deadline.budget_s is not None:
                cap = min(cap, max(deadline.remaining(), 0.0) + self.GRACE_S)
            try:
                w.conn.send((op, req))
                end = time.monotonic() + cap
                while True:
                    if w.conn.poll(self.POLL_S):
                        return w.conn.recv()
                    if not w.proc.is_alive():
                        raise WorkerCrash(
                            f"worker pid {w.proc.pid} died mid-job")
                    if time.monotonic() >= end:
                        raise WorkerCrash(
                            f"worker pid {w.proc.pid} wedged past "
                            f"{cap:.1f}s cap; killed")
            except (EOFError, BrokenPipeError, OSError) as e:
                raise WorkerCrash(f"worker pipe died: {e}") from e
        except WorkerCrash:
            lost = True
            raise
        finally:
            if lost:
                with self._lock:
                    self.crashes += 1
                self._retire(w)
                if not self._closed:
                    self._idle.put(self._spawn())
            else:
                self._idle.put(w)

    def run(self, op: str, req: Dict[str, Any],
            deadline: Optional[Deadline],
            on_crash: Optional[Callable[[WorkerCrash], None]] = None
            ) -> Tuple:
        """:meth:`run_once` with one bounded retry on a fresh worker —
        a random crash is recovered transparently; a poison request
        burns exactly two workers, then surfaces as
        :class:`WorkerCrash` for the daemon to marshal as the typed
        ``worker_crashed`` response."""
        try:
            return self.run_once(op, req, deadline)
        except WorkerCrash as e:
            if on_crash is not None:
                on_crash(e)
            return self.run_once(op, req, deadline)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"workers": self.workers, "spawned": self.spawned,
                    "crashes": self.crashes, "jobs": self.jobs,
                    "idle": self._idle.qsize()}

    def close(self) -> None:
        self._closed = True
        while True:                       # polite sentinel to idle workers
            try:
                w = self._idle.get_nowait()
            except queue.Empty:
                break
            try:
                w.conn.send(None)
            except (BrokenPipeError, OSError):
                pass
        with self._lock:
            procs = list(self._procs)
            self._procs = []
        for w in procs:
            w.proc.join(timeout=1.0)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2.0)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=2.0)
            try:
                w.conn.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------


class _Flight:
    """One in-flight keyed computation; waiters block on the event and
    read the identical encoded response frame."""

    __slots__ = ("event", "frame")

    def __init__(self):
        self.event = threading.Event()
        self.frame: Optional[bytes] = None


class _Shutdown(Exception):
    pass


class SchedDaemon:
    """See the module docstring.  Thread-per-connection; all shared
    state (the flight table, the frame cache, counters) is mutated
    under ``_lock``; the ScheduleCache itself relies on the GIL plus
    atomic on-disk publishes, same as the multi-process case."""

    def __init__(self, sock_path: Optional[str],
                 cache_dir: Optional[str] = None, *,
                 workers: int = 0, max_inflight: int = 8,
                 conn_timeout: float = 10.0, frame_cache_cap: int = 256,
                 frame_cache_bytes: int = 32 << 20,
                 job_timeout: float = 600.0, chaos: bool = False,
                 listen: Optional[str] = None,
                 auth_key: Optional[bytes] = None,
                 peers: Tuple[str, ...] = (),
                 push_storm_max: Optional[int] = None,
                 push_storm_window: Optional[float] = None):
        self.sock_path = sock_path
        self.listen = listen
        self.auth_key = auth_key
        self.peers = tuple(peers)
        if listen is not None and auth_key is None:
            raise ValueError(
                "refusing to listen on TCP without a shared key: pickle "
                "from an unauthenticated network peer is code execution "
                f"(set ${wire.KEY_ENV} or pass --keyfile)")
        if sock_path is None and listen is None:
            raise ValueError("daemon needs --sock and/or --listen")
        self.tcp_port: Optional[int] = None   # set by start() (port 0 ok)
        self.cache = ScheduleCache(cache_dir=cache_dir)
        self.max_inflight = max_inflight
        self.conn_timeout = conn_timeout
        self.chaos = chaos
        self.journal = (AutotuneJournal(os.path.join(self.cache.dir,
                                                     JOURNAL_FILE))
                        if self.cache.disk else None)
        self.recovered: List[str] = (self.journal.recover()
                                     if self.journal else [])
        self._lock = threading.Lock()
        self._flights: Dict[Any, _Flight] = {}
        self._frames = FrameCache(cap_entries=frame_cache_cap,
                                  cap_bytes=frame_cache_bytes)
        self.pool: Optional[WorkerPool] = (
            WorkerPool(workers, self.cache.dir, disk=self.cache.disk,
                       chaos=chaos, job_timeout_s=job_timeout)
            if workers > 0 else None)
        self._listener: Optional[socket.socket] = None
        self._tcp_listener: Optional[socket.socket] = None
        self._accept_threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._peer_clients: Dict[str, Any] = {}
        if push_storm_max is None:
            push_storm_max = int(os.environ.get(
                "POLYTOPS_PUSH_STORM_MAX", PUSH_STORM_MAX))
        if push_storm_window is None:
            push_storm_window = float(os.environ.get(
                "POLYTOPS_PUSH_STORM_WINDOW", PUSH_STORM_WINDOW_S))
        self.push_storm_max = max(push_storm_max, 0)
        self.push_storm_window = max(push_storm_window, 0.0)
        self._push_admits: Deque[float] = deque()
        self.counters: Dict[str, int] = {
            "requests": 0, "computed": 0, "coalesced": 0, "frame_hits": 0,
            "shed": 0, "bad_frames": 0, "version_skew": 0, "slow_loris": 0,
            "degraded": 0, "errors": 0, "pool_jobs": 0, "worker_crashes": 0,
            "winner_pushes": 0, "auth_failed": 0, "idle_closed": 0,
            "peer_pushes_sent": 0, "peer_pushes_recv": 0,
            "peer_pushes_capped": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        if self.sock_path is not None:
            d = os.path.dirname(self.sock_path)
            if d:
                os.makedirs(d, exist_ok=True)
            try:
                os.unlink(self.sock_path)  # stale socket from a kill -9
            except FileNotFoundError:
                pass
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(self.sock_path)
            os.chmod(self.sock_path, 0o600)   # same-user peers only
            self._start_listener(self._listener, tcp=False)
        if self.listen is not None:
            kind, target = wire.parse_address(self.listen)
            if kind != "tcp":
                raise ValueError(f"--listen wants host:port, got "
                                 f"{self.listen!r}")
            tl = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            tl.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            tl.bind(target)
            self.tcp_port = tl.getsockname()[1]   # resolves port 0
            self._tcp_listener = tl
            self._start_listener(tl, tcp=True)

    def _start_listener(self, listener: socket.socket, *,
                        tcp: bool) -> None:
        listener.listen(64)
        listener.settimeout(0.2)
        t = threading.Thread(
            target=self._accept_loop, args=(listener, tcp),
            name=f"schedd-accept-{'tcp' if tcp else 'unix'}", daemon=True)
        t.start()
        self._accept_threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._accept_threads:
            t.join(timeout=5.0)
        for listener in (self._listener, self._tcp_listener):
            if listener is not None:
                try:
                    listener.close()
                except OSError:
                    pass
        if self.pool is not None:
            self.pool.close()
        for c in self._peer_clients.values():
            c.close()
        if self.sock_path is not None:
            try:
                os.unlink(self.sock_path)
            except OSError:
                pass

    def wait(self) -> None:
        while not self._stop.wait(timeout=0.5):
            pass

    def _accept_loop(self, listener: socket.socket, tcp: bool) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            if tcp:
                try:
                    conn.setsockopt(socket.IPPROTO_TCP,
                                    socket.TCP_NODELAY, 1)
                except OSError:
                    pass
            threading.Thread(target=self._handle_conn, args=(conn, tcp),
                             daemon=True).start()

    # -- connection handling ----------------------------------------------

    def _handle_conn(self, conn: socket.socket, tcp: bool = False) -> None:
        conn.settimeout(self.conn_timeout)
        session: Optional[wire.Session] = None
        handshaken = False
        try:
            # the hello (and the whole handshake) is JSON under the
            # pre-auth cap: nothing a yet-unauthenticated peer sends is
            # ever unpickled or buffered beyond a few KiB
            hello = wire.recv_frame(conn, eof_ok=True, json_codec=True,
                                    max_bytes=wire.PRE_AUTH_MAX_FRAME_BYTES)
            if hello is None:
                return
            if hello.get("op") != "hello":
                self._count("bad_frames")
                wire.send_frame(conn, {"ok": False, "error": "bad_frame",
                                       "detail": "expected hello"},
                                json_codec=True)
                return
            skew = wire.version_skew(hello)
            if skew:
                self._count("version_skew")
                wire.send_frame(conn, {"ok": False, "error": "version_skew",
                                       "detail": skew}, json_codec=True)
                return
            hello_ok = {"ok": True, "op": "hello", "pid": os.getpid(),
                        **wire.wire_versions()}
            try:
                session = wire.server_handshake(
                    conn, hello, key=self.auth_key, require_auth=tcp,
                    hello_ok=hello_ok)
            except wire.AuthFailed:
                self._count("auth_failed")   # typed reply already sent
                return
            handshaken = True
            while True:
                try:
                    req = wire.recv_frame(conn, eof_ok=True,
                                          session=session, idle_ok=True)
                except wire.IdleTimeout:
                    # a pooled keep-alive connection went quiet at a
                    # frame boundary — that's reuse working, not a
                    # stalled peer
                    self._count("idle_closed")
                    return
                if req is None:
                    return
                self._count("requests")
                if not isinstance(req, dict):
                    self._count("bad_frames")
                    wire.send_frame(conn, {
                        "ok": False, "error": "bad_frame",
                        "detail": f"request is {type(req).__name__}, "
                                  f"not a dict"}, session=session)
                    continue
                # local_only: the inline handlers call into akg, whose
                # remote hook must never route the daemon's own work
                # back to a daemon (ourselves, for the in-process test
                # harness); pool workers carry the server mark instead
                with schedclient.local_only():
                    frame = self._dispatch(req)
                self._send_prepared(conn, session, frame)
        except _Shutdown as e:
            try:
                self._send_prepared(conn, session, e.args[0])  # "bye"
            except OSError:
                pass
            self._stop.set()
        except wire.AuthFailed as e:
            # a post-handshake MAC mismatch: typed reply, drop the conn
            self._count("auth_failed")
            try:
                wire.send_frame(conn, {"ok": False, "error": "auth_failed",
                                       "detail": str(e)}, session=session)
            except OSError:
                pass
        except wire.ProtocolError as e:
            self._count("bad_frames")
            try:          # best effort: the peer may already be gone
                wire.send_frame(conn, {"ok": False, "error": "bad_frame",
                                       "detail": str(e)},
                                json_codec=not handshaken,
                                session=session)
            except OSError:
                pass
        except socket.timeout:
            self._count("slow_loris")     # stalled peer: drop it
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    @staticmethod
    def _send_prepared(conn: socket.socket,
                       session: Optional["wire.Session"],
                       frame: bytes) -> None:
        """Send a pre-encoded (possibly frame-cached) response frame,
        appending this connection's MAC tag when authenticated — cached
        bytes are shared across connections, tags never are."""
        if session is None:
            conn.sendall(frame)
        else:
            body = frame[wire.HEADER_LEN:]
            conn.sendall(frame + session.sign(body))

    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    # -- dispatch ----------------------------------------------------------

    def _dispatch(self, req: Dict[str, Any]) -> bytes:
        op = req.get("op")
        if op == "ping":
            return wire.encode_frame({"ok": True, "op": "pong",
                                      "pid": os.getpid()})
        if op == "stats":
            return wire.encode_frame({"ok": True, "result": self.stats()})
        if op == "shutdown":
            frame = wire.encode_frame({"ok": True, "op": "bye"})
            raise _Shutdown(frame)        # _handle_conn sets the stop flag
        handlers = {"schedule": self._handle_schedule,
                    "autotune": self._handle_autotune,
                    "plan": self._handle_plan,
                    "winner_push": self._handle_winner_push}
        if op not in handlers:
            return wire.encode_frame({"ok": False, "error": "bad_request",
                                      "detail": f"unknown op {op!r}"})
        try:
            return handlers[op](req)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:     # a handler bug must not kill the daemon
            self._count("errors")
            return wire.encode_frame({
                "ok": False, "error": "internal",
                "detail": f"{type(e).__name__}: {e}"})

    def _deadline(self, req: Dict[str, Any]) -> Optional[Deadline]:
        budget = req.get("deadline_s")
        return Deadline(float(budget)) if budget is not None else None

    # -- coalescing + compute core ----------------------------------------

    def _serve_keyed(self, key: Optional[Any], op: str,
                     req: Dict[str, Any],
                     deadline: Optional[Deadline]) -> bytes:
        """Coalesce + shed + frame-cache around one keyed computation.

        The computation itself runs through :meth:`_compute_job`
        (inline or on a pool worker).  The encoded frame is shared with
        every coalesced waiter and, when cacheable (non-degraded
        success), admitted to the latency-saved frame cache weighted by
        the flight's measured wall time; winner pushes are admitted
        *before* the flight event wakes the waiters."""
        owner_flight: Optional[_Flight] = None
        existing: Optional[_Flight] = None
        if key is not None:
            with self._lock:
                cached = self._frames.get(key)
                if cached is not None:
                    self.counters["frame_hits"] += 1
                    return cached
                existing = self._flights.get(key)
                if existing is not None:
                    self.counters["coalesced"] += 1
                else:
                    if len(self._flights) >= self.max_inflight:
                        self.counters["shed"] += 1
                        return wire.encode_frame({
                            "ok": False, "error": "overloaded",
                            "detail": f"{len(self._flights)} computations "
                                      f"in flight (cap {self.max_inflight})"})
                    owner_flight = _Flight()
                    self._flights[key] = owner_flight
            if owner_flight is None:
                budget = None
                if deadline is not None and deadline.budget_s is not None:
                    budget = max(deadline.remaining(), 0.0)
                if not existing.event.wait(
                        timeout=budget if budget is not None else 600.0):
                    return wire.encode_frame({
                        "ok": False, "error": "deadline",
                        "detail": "coalesced wait exceeded the budget"})
                assert existing.frame is not None
                return existing.frame
        else:
            with self._lock:
                if len(self._flights) >= self.max_inflight:
                    self.counters["shed"] += 1
                    return wire.encode_frame({
                        "ok": False, "error": "overloaded",
                        "detail": f"{len(self._flights)} computations "
                                  f"in flight (cap {self.max_inflight})"})

        self._count("computed")
        try:
            resp, cacheable, pushes, compute_s = self._compute_job(
                key, op, req, deadline)
            # encode inside the try: an unencodable result must not
            # leave coalesced waiters blocked on a never-set flight
            frame = wire.encode_frame(resp)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            resp, cacheable, pushes, compute_s = (
                {"ok": False, "error": "internal",
                 "detail": f"{type(e).__name__}: {e}"}, False, [], 0.0)
            frame = wire.encode_frame(resp)
        meta = resp.get("meta") if isinstance(resp, dict) else None
        if isinstance(meta, dict) and meta.get("degraded"):
            self._count("degraded")
        if not resp.get("ok") and resp.get("error") in ("internal",
                                                        "worker_crashed"):
            self._count("errors")
        if owner_flight is not None:
            admitted: List[Tuple[Any, Dict[str, Any]]] = []
            with self._lock:
                self._flights.pop(key, None)
                if cacheable and resp.get("ok"):
                    self._frames.put(key, frame, compute_s)
                # winner-store push BEFORE event.set(): a follower woken
                # by this flight already finds the pushed frame warm
                for pkey, presp in pushes or ():
                    try:
                        pframe = wire.encode_frame(presp)
                    except Exception:
                        continue
                    if self._admit_push_locked(
                            pkey, pframe, compute_s * PUSH_COST_FRACTION):
                        self.counters["winner_pushes"] += 1
                        admitted.append((pkey, presp))
            owner_flight.frame = frame
            owner_flight.event.set()
            if admitted and self.peers:
                self._push_to_peers(admitted, compute_s)
        return frame

    def _admit_push_locked(self, pkey: Any, pframe: bytes,
                           cost_s: float) -> bool:
        """The winner-push admission path (held ``_lock`` required):
        never displace an existing frame or race an in-flight
        computation for the same key."""
        if pkey in self._frames or pkey in self._flights:
            return False
        return bool(self._frames.put(pkey, pframe, cost_s))

    # -- peer winner push ---------------------------------------------------

    def _peer_client(self, peer: str):
        c = self._peer_clients.get(peer)
        if c is None:
            c = schedclient.SchedClient(
                peer, connect_timeout=1.0, request_timeout=10.0,
                retries=0, key=self.auth_key)
            self._peer_clients[peer] = c
        return c

    def _push_to_peers(self, admitted: List[Tuple[Any, Dict[str, Any]]],
                       compute_s: float) -> None:
        """Forward freshly admitted winner frames to every ``--peers``
        daemon, asynchronously and best-effort: a slow or dead peer
        costs a background thread a timeout, never a client request.
        Only *locally computed* winners are forwarded (the receiving
        handler never re-forwards), so a fleet cannot push in circles."""

        def _send() -> None:
            for peer in self.peers:
                c = self._peer_client(peer)
                for pkey, presp in admitted:
                    try:
                        with schedclient.local_only():
                            c._request({"op": "winner_push", "key": pkey,
                                        "resp": presp,
                                        "compute_s": compute_s}, 10.0)
                        self._count("peer_pushes_sent")
                    except (wire.SchedClientError, OSError):
                        break             # skip this peer's remaining keys

        threading.Thread(target=_send, name="schedd-peer-push",
                         daemon=True).start()

    def _compute_job(self, key: Optional[Any], op: str,
                     req: Dict[str, Any],
                     deadline: Optional[Deadline]) -> Tuple:
        """One computation: pool dispatch (with crash retry + journal
        witnessing) when a pool exists, else inline.  Returns
        ``(resp, cacheable, pushes, compute_s)``; only unexpected
        daemon-side failures raise."""
        fault_point("pool.dispatch")
        jkey: Optional[str] = None
        if (op == "autotune" and self.journal is not None
                and isinstance(key, tuple) and len(key) == 2):
            jkey = str(key[1])
            # journal BEFORE the computation (including any chaos hold):
            # the work is accepted the moment we own the flight, so a
            # kill -9 during it is exactly the "crash mid-request" the
            # journal must witness
            self.journal.begin(jkey)
        outcome = "done"
        try:
            if self.pool is not None:
                self._count("pool_jobs")

                def witness(crash: WorkerCrash) -> None:
                    self._count("worker_crashes")
                    if jkey is not None and self.journal is not None:
                        self.journal.crashed(jkey, str(crash))

                try:
                    return self.pool.run(op, req, deadline, on_crash=witness)
                except WorkerCrash as e:
                    outcome = "crashed"
                    witness(e)
                    return ({"ok": False, "error": "worker_crashed",
                             "detail": str(e)}, False, [], 0.0)
            t0 = time.perf_counter()
            resp, cacheable, pushes = compute_request(
                op, req, self.cache, chaos=self.chaos, deadline=deadline)
            return resp, cacheable, pushes, time.perf_counter() - t0
        finally:
            if jkey is not None and self.journal is not None \
                    and outcome == "done":
                # done even on typed failure: the work is over either
                # way — only an unwitnessed crash leaves an orphan
                self.journal.done(jkey)

    # -- handlers ----------------------------------------------------------

    def _handle_schedule(self, req: Dict[str, Any]) -> bytes:
        from ..core.config import SchedulerConfig

        scop = req["scop"]
        config = req.get("config") or SchedulerConfig()
        engine = req.get("engine", "lex")
        with_tree = bool(req.get("with_tree", False))
        extra = dict(req.get("extra") or {})
        try:
            skey = schedule_key(scop, config, engine, extra=extra)
        except Exception:
            skey = None
        key = ("schedule", skey, with_tree) if skey is not None else None
        return self._serve_keyed(key, "schedule", req, self._deadline(req))

    def _handle_autotune(self, req: Dict[str, Any]) -> bytes:
        scop = req["scop"]
        kwargs = dict(req.get("kwargs") or {})
        try:
            digest = hashlib.sha256(json.dumps(
                {"scop": scop_fingerprint(scop),
                 "kwargs": {k: kwargs[k] for k in sorted(kwargs)}},
                sort_keys=True, separators=(",", ":"),
                default=str).encode()).hexdigest()
            key: Optional[Any] = ("autotune", digest)
        except Exception:
            key = None
        return self._serve_keyed(key, "autotune", req, self._deadline(req))

    def _handle_plan(self, req: Dict[str, Any]) -> bytes:
        kind = req.get("kind")
        if kind not in ("matmul", "attention", "mamba_scan", "scan_gate"):
            # reject before burning a flight slot or a pool worker
            return wire.encode_frame({
                "ok": False, "error": "bad_request",
                "detail": f"unknown plan kind {kind!r}"})
        args = tuple(req.get("args") or ())
        kwargs = dict(req.get("kwargs") or {})
        try:
            key: Optional[Any] = ("plan", kind, args,
                                  tuple(sorted(kwargs.items())))
        except TypeError:
            key = None
        return self._serve_keyed(key, "plan", req, self._deadline(req))

    def _handle_winner_push(self, req: Dict[str, Any]) -> bytes:
        """A sibling daemon pushing an autotune winner's schedule frame.
        Reuses the local admission path; never re-forwarded (the sender
        is the only daemon that computed it), so pushes cannot loop."""
        pkey = req.get("key")
        presp = req.get("resp")
        if not (isinstance(presp, dict) and presp.get("ok")
                and pkey is not None):
            return wire.encode_frame({
                "ok": False, "error": "bad_request",
                "detail": "winner_push wants key + ok resp"})
        meta = presp.get("meta")
        if not (isinstance(meta, dict) and not meta.get("degraded")):
            return wire.encode_frame({
                "ok": False, "error": "bad_request",
                "detail": "refusing a degraded winner push"})
        try:
            cost_s = float(req.get("compute_s") or 0.0)
            pframe = wire.encode_frame(presp)
        except Exception as e:
            return wire.encode_frame({
                "ok": False, "error": "bad_request",
                "detail": f"unencodable push: {type(e).__name__}: {e}"})
        with self._lock:
            if not self._push_storm_ok_locked():
                self.counters["peer_pushes_capped"] += 1
                self._frames.stats["push_capped"] += 1
                return wire.encode_frame({"ok": True, "admitted": False,
                                          "capped": True})
            admitted = self._admit_push_locked(
                pkey, pframe, cost_s * PUSH_COST_FRACTION)
            if admitted:
                self.counters["peer_pushes_recv"] += 1
                self._push_admits.append(time.monotonic())
        return wire.encode_frame({"ok": True, "admitted": admitted})

    def _push_storm_ok_locked(self) -> bool:
        """Sliding-window admission bound on peer pushes (held ``_lock``
        required): True while fewer than ``push_storm_max`` pushes were
        admitted in the trailing ``push_storm_window`` seconds."""
        now = time.monotonic()
        horizon = now - self.push_storm_window
        while self._push_admits and self._push_admits[0] < horizon:
            self._push_admits.popleft()
        return len(self._push_admits) < self.push_storm_max

    # -- introspection -----------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self.counters)
            inflight = len(self._flights)
            frames = self._frames.snapshot()
        return {
            "pid": os.getpid(),
            "sock": self.sock_path,
            "listen": self.listen,
            "tcp_port": self.tcp_port,
            "peers": list(self.peers),
            "cache_dir": self.cache.dir,
            "counters": counters,
            "inflight": inflight,
            "workers": self.pool.workers if self.pool is not None else 0,
            "pool": self.pool.stats() if self.pool is not None else None,
            "frame_cache": frames["entries"],
            "frames": frames,
            "cache": self.cache.stats.as_dict(),
            "journal_recovered": len(self.recovered),
            "journal_recovered_keys": list(self.recovered),
            "versions": wire.wire_versions(),
            "chaos": self.chaos,
        }


def default_socket_path() -> str:
    env = os.environ.get(wire.SOCKET_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "polytops",
                        "schedd.sock")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sock", default=default_socket_path(),
                    help="Unix socket path (default $POLYTOPS_SCHEDD_SOCK "
                         "or ~/.cache/polytops/schedd.sock)")
    ap.add_argument("--cache-dir", default=None,
                    help="schedule-cache pool (default schedcache's)")
    ap.add_argument("--workers", type=int, default=0,
                    help="forked worker processes for keyed computations "
                         "(0 = compute inline in the connection thread)")
    ap.add_argument("--max-inflight", type=int, default=8)
    ap.add_argument("--conn-timeout", type=float, default=10.0,
                    help="per-connection recv timeout (slow-loris guard)")
    ap.add_argument("--job-timeout", type=float, default=600.0,
                    help="hard cap on one worker job (wedge guard)")
    ap.add_argument("--frame-cache-cap", type=int, default=256,
                    help="frame-cache entry cap")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="also serve TCP (requires a shared key via "
                         "--keyfile or $POLYTOPS_SCHEDD_KEY); port 0 "
                         "binds an ephemeral port (see --port-file)")
    ap.add_argument("--keyfile", default=None,
                    help="file holding the shared TCP auth key")
    ap.add_argument("--peers", default="",
                    help="comma-separated sibling daemon addresses to "
                         "push autotune winners to")
    ap.add_argument("--port-file", default=None,
                    help="write the bound TCP port here once listening "
                         "(ephemeral-port discovery)")
    ap.add_argument("--push-storm-max", type=int, default=None,
                    help="peer winner pushes admitted per storm window "
                         f"(default $POLYTOPS_PUSH_STORM_MAX or "
                         f"{PUSH_STORM_MAX})")
    ap.add_argument("--push-storm-window", type=float, default=None,
                    help="sliding window seconds for --push-storm-max "
                         f"(default $POLYTOPS_PUSH_STORM_WINDOW or "
                         f"{PUSH_STORM_WINDOW_S})")
    ap.add_argument("--chaos", action="store_true",
                    help="enable the test-only test_delay_s / "
                         "test_kill_worker request fields")
    args = ap.parse_args(argv)

    # the daemon's own scheduling work must never route back through a
    # client pointed at ourselves
    schedclient.mark_server_process()

    auth_key = wire.load_key(args.keyfile)
    peers = tuple(p.strip() for p in args.peers.split(",") if p.strip())
    daemon = SchedDaemon(args.sock, cache_dir=args.cache_dir,
                         workers=args.workers,
                         max_inflight=args.max_inflight,
                         conn_timeout=args.conn_timeout,
                         frame_cache_cap=args.frame_cache_cap,
                         job_timeout=args.job_timeout, chaos=args.chaos,
                         listen=args.listen, auth_key=auth_key,
                         peers=peers,
                         push_storm_max=args.push_storm_max,
                         push_storm_window=args.push_storm_window)
    daemon.start()
    if args.port_file and daemon.tcp_port is not None:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(daemon.tcp_port))
        os.replace(tmp, args.port_file)
    listening = " + ".join(
        s for s in (args.sock,
                    f"tcp:{daemon.tcp_port}" if daemon.tcp_port else None)
        if s)
    print(f"schedd: pid {os.getpid()} listening on {listening} "
          f"(cache {daemon.cache.dir}, workers {args.workers}, "
          f"peers {len(peers)}, "
          f"journal recovered {len(daemon.recovered)})", flush=True)

    def _term(signum, frame):
        daemon._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    try:
        daemon.wait()
    finally:
        daemon.stop()
    print("schedd: stopped", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
