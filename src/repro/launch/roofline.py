"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e, per brief):
  peak 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs / (chips × peak)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes_per_chip / link_bw

cost_analysis() reports whole-program (per-device-program × device
count semantics differ by backend: on the CPU SPMD backend the numbers
are for one device's program — we therefore treat them as per-chip and
do NOT divide again; see EXPERIMENTS.md §Dry-run notes).

Collective bytes are parsed from the optimized HLO: each all-reduce
counts 2× its shard bytes (ring), all-gather/reduce-scatter/all-to-all
count ~1× (×(n−1)/n ≈ 1), collective-permute 1×.
"""
from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link (≈ aggregate per-chip usable)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLL_RE = re.compile(
    r"(\S+)\s*=\s*((?:\([^)]*\)|\S+))\s*(all-reduce|all-gather|reduce-scatter"
    r"|all-to-all|collective-permute)(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s32|u32|s8|u8|s16|u16|s64|u64|pred)"
                       r"\[([0-9,]*)\]")

_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> Dict[str, Any]:
    """Sum result-shape bytes per collective kind over the optimized HLO."""
    out: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in _FACTORS}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        shape_txt = m.group(2)
        b = _shape_bytes(shape_txt)
        if kind in out:
            out[kind]["count"] += 1
            out[kind]["bytes"] += b
    out["weighted_bytes"] = sum(
        v["bytes"] * _FACTORS[k] for k, v in out.items() if k in _FACTORS)
    return out


def model_flops(cfg, shape) -> float:
    """6·N_active·D (training) / 2·N_active·D (inference) useful FLOPs."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Parameters touched per token (MoE counts top_k experts)."""
    d, hd = cfg.d_model, cfg.hd
    per_layer_attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    per_layer_mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0
    per_layer_moe = 3 * d * cfg.d_ff * cfg.top_k if cfg.n_experts else 0
    if cfg.shared_expert:
        per_layer_moe += per_layer_mlp
    di = cfg.d_inner
    per_layer_mamba = 2 * d * di + di * (cfg.dt_rank_ + 2 * cfg.ssm_state) \
        + cfg.dt_rank_ * di + di * d
    total = 0.0
    from ..model.transformer import layer_specs
    for spec in layer_specs(cfg, "decoder"):
        if spec.mixer == "attn":
            total += per_layer_attn
        elif spec.mixer == "mamba":
            total += per_layer_mamba
        if spec.cross:
            total += per_layer_attn
        if spec.ffn == "moe":
            total += per_layer_moe
        elif spec.ffn == "mlp":
            total += per_layer_mlp
    for _ in range(cfg.enc_layers):
        total += per_layer_attn + 3 * cfg.d_model * cfg.d_ff
    total += 2 * cfg.vocab * cfg.d_model   # embed + head
    return total


def roofline_terms(cfg, shape, cost: Dict, coll: Dict, n_dev: int) -> Dict[str, Any]:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = float(coll.get("weighted_bytes", 0)) / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape)
    hlo_total = flops * n_dev
    return {
        **terms,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_per_dev": flops,
        "model_flops_frac": (mf / hlo_total) if hlo_total else 0.0,
        "step_time_lower_bound_s": max(terms.values()),
        "mfu_upper_bound": (mf / (n_dev * PEAK_FLOPS)) / max(max(terms.values()), 1e-12),
    }
