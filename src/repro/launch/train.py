"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
        --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt [--smoke]

On a real TPU fleet this process runs per host with jax.distributed
initialization; on this box it drives the same Trainer on one device
(--smoke reduces the arch). The --mesh flag lowers onto the production
mesh topology (requires the 512-device env, i.e. run under dryrun's
XLA_FLAGS — documented, not default).
"""
from __future__ import annotations

import argparse

from ..configs.registry import get_arch
from ..optim.adamw import AdamWConfig
from ..train.loop import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="use the production mesh (needs 512 host devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        arch = arch.smoke()
    cfg = TrainConfig(
        arch=arch, total_steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, n_micro=args.n_micro, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
        use_mesh=args.mesh, multi_pod=args.multi_pod,
    )
    trainer = Trainer(cfg)
    out = trainer.fit()
    print(f"done: {out}")
    trainer.close()


if __name__ == "__main__":
    main()
