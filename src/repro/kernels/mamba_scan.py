"""Selective-scan (Mamba-1 recurrence) Pallas kernel.

h_t = a_t ⊙ h_{t-1} + b_t over the sequence, with the hidden state
(d_block × state) resident in VMEM scratch across sequence chunks:
grid = (batch, d_blocks, seq_chunks), the chunk axis minormost. Inside a
chunk the recurrence runs as a fori_loop (sequential in time, vector
across the d_block lanes — the TPU-native layout for this kernel: state
dim broadcast over lanes, time sequential).

Block geometry comes from the scheduler: ``repro.core.akg.plan_mamba_scan``
schedules the recurrence SCoP (t sequential-outermost by the h
dependence, d/n parallel inside) and lowers its schedule tree to a
KernelPlan — chunk = the t tile, d_block = the d tile — through the
same ``lower_to_kernel_plan`` path as matmul and flash attention.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, c_ref, o_ref, h_ref, *, chunk: int, n_chunks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)        # (bd, st)
        b_t = b_ref[0, t].astype(jnp.float32)        # (bd, st)
        c_t = c_ref[0, t].astype(jnp.float32)        # (st,)
        h = a_t * h + b_t
        o_ref[0, t] = (h @ c_t).astype(o_ref.dtype)  # (bd,)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def selective_scan(a_bar: jnp.ndarray, b_bar: jnp.ndarray, c: jnp.ndarray,
                   d_block: Optional[int] = None, chunk: Optional[int] = None,
                   interpret: bool = True) -> jnp.ndarray:
    """a_bar, b_bar: (batch, seq, d_inner, state); c: (batch, seq, state).
    Returns y: (batch, seq, d_inner) = Σ_n h[., ., d, n]·c[., ., n].
    Default block geometry comes from the PolyTOPS schedule tree."""
    bsz, seq, di, st = a_bar.shape
    if d_block is None or chunk is None:
        from ..core.akg import plan_mamba_scan
        plan = plan_mamba_scan(seq, di, st)
        d_block = d_block if d_block is not None else plan.tile["d"]
        chunk = chunk if chunk is not None else plan.tile["t"]
    d_block = min(d_block, di)
    while di % d_block:
        d_block //= 2
    chunk = min(chunk, seq)
    while seq % chunk:
        chunk //= 2
    n_chunks = seq // chunk
    grid = (bsz, di // d_block, n_chunks)
    # layout: (b, seq, d, st) blocks of (1, chunk, d_block, st)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, st), lambda b, dblk, t: (b, t, dblk, 0)),
            pl.BlockSpec((1, chunk, d_block, st), lambda b, dblk, t: (b, t, dblk, 0)),
            pl.BlockSpec((1, chunk, st), lambda b, dblk, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b, dblk, t: (b, t, dblk)),
        out_shape=jax.ShapeDtypeStruct((bsz, seq, di), a_bar.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, st), jnp.float32)],
        interpret=interpret,
    )(a_bar, b_bar, c)
    return out
