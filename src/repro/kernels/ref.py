"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q, k, v: (bh, seq, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(a_bar: jnp.ndarray, b_bar: jnp.ndarray,
                       c: jnp.ndarray) -> jnp.ndarray:
    """Associative-scan reference for the Mamba recurrence."""
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    a32 = a_bar.astype(jnp.float32)
    b32 = b_bar.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))
    return y.astype(a_bar.dtype)


def scan_gate_ref(a_bar: jnp.ndarray, b_bar: jnp.ndarray, c: jnp.ndarray,
                  x_skip: jnp.ndarray, d_skip: jnp.ndarray, z: jnp.ndarray,
                  h0: jnp.ndarray = None):
    """Fused scan+skip+gate reference: h_t = a⊙h+b from h0, then
    o_t = (h_t·c_t + x_t⊙d_skip) ⊙ silu(z_t).  Returns (o, h_last)."""
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    a32 = a_bar.astype(jnp.float32)
    b32 = b_bar.astype(jnp.float32)
    cum_a, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    if h0 is not None:
        h = h + cum_a * h0.astype(jnp.float32)[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))
    y = y + x_skip.astype(jnp.float32) * d_skip.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    o = y * (z32 * jax.nn.sigmoid(z32))
    return o.astype(x_skip.dtype), h[:, -1]
