"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (the
kernels target TPU; interpret mode executes the kernel bodies in Python
for correctness validation). On TPU set REPRO_PALLAS_COMPILE=1.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import mamba_scan as _ms
from . import matmul_polytops as _mm
from . import scan_gate as _sg

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@partial(jax.jit, static_argnames=("interpret",))
def matmul(a, b, interpret: bool = INTERPRET):
    return _mm.matmul(a, b, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, causal: bool = True, q_offset=None,
                    interpret: bool = INTERPRET):
    """q: (b, s, h, d); k/v: (b, s, hkv, d) — GQA repeats kv heads.
    ``q_offset`` (scalar int32) positions the q chunk for causal
    masking against a longer kv prefix (chunked prefill)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, q_offset=q_offset,
                              interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("interpret",))
def selective_scan(a_bar, b_bar, c, interpret: bool = INTERPRET):
    return _ms.selective_scan(a_bar, b_bar, c, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def scan_gate(a_bar, b_bar, c, x_skip, d_skip, z, h0=None,
              interpret: bool = INTERPRET):
    """Fused selective-scan + skip + SiLU gate with state carry.
    Returns (o (b, s, di), h_last (b, di, st))."""
    return _sg.scan_gate(a_bar, b_bar, c, x_skip, d_skip, z, h0=h0,
                         interpret=interpret)
