"""Fused selective-scan + skip + SiLU-gate Pallas kernel (Mamba block tail).

One kernel computes what the jnp path spreads over four ops:

    h_t = a_t ⊙ h_{t-1} + b_t                      (recurrence)
    y_t = h_t · c_t + x_t ⊙ d_skip                 (contraction + skip)
    o_t = y_t ⊙ silu(z_t)                          (gate)

with the hidden state (d_block × state) VMEM-resident across sequence
chunks and an explicit initial state ``h0`` — the carry that lets a
serving engine process a prompt in chunks (continuous batching) without
ever materializing the (b, s, d, n) hidden-state tensor in HBM between
ops.  The final state is returned for the next chunk.

Block geometry comes from the scheduler: ``repro.core.akg.plan_scan_gate``
builds the fused SCoP (recurrence + gate statement in one t/d nest),
ranks the enumerated schedule bases with
:func:`repro.core.autotune.rank_pallas_plans`, and lowers the winner
through the same ``lower_to_kernel_plan`` bridge as every other kernel —
chunk = the t tile, d_block = the d tile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, c_ref, x_ref, dk_ref, z_ref, h0_ref,
            o_ref, hout_ref, h_ref, *, chunk: int, n_chunks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    dk = dk_ref[0].astype(jnp.float32)               # (bd,)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)        # (bd, st)
        b_t = b_ref[0, t].astype(jnp.float32)        # (bd, st)
        c_t = c_ref[0, t].astype(jnp.float32)        # (st,)
        x_t = x_ref[0, t].astype(jnp.float32)        # (bd,)
        z_t = z_ref[0, t].astype(jnp.float32)        # (bd,)
        h = a_t * h + b_t
        y = h @ c_t + x_t * dk
        o_ref[0, t] = (y * (z_t * jax.nn.sigmoid(z_t))).astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(pl.program_id(2) == n_chunks - 1)
    def _store_state():
        hout_ref[0] = h_ref[...]


def scan_gate(a_bar: jnp.ndarray, b_bar: jnp.ndarray, c: jnp.ndarray,
              x_skip: jnp.ndarray, d_skip: jnp.ndarray, z: jnp.ndarray,
              h0: Optional[jnp.ndarray] = None,
              d_block: Optional[int] = None, chunk: Optional[int] = None,
              interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a_bar, b_bar: (b, s, di, st); c: (b, s, st); x_skip, z: (b, s, di);
    d_skip: (di,); h0: (b, di, st) f32 or None (zeros).
    Returns (o (b, s, di), h_last (b, di, st) f32)."""
    bsz, seq, di, st = a_bar.shape
    if d_block is None or chunk is None:
        from ..core.akg import plan_scan_gate
        plan = plan_scan_gate(seq, di, st)
        d_block = d_block if d_block is not None else plan.tile["d"]
        chunk = chunk if chunk is not None else plan.tile["t"]
    d_block = min(d_block, di)
    while di % d_block:
        d_block //= 2
    chunk = min(chunk, seq)
    while seq % chunk:
        chunk //= 2
    n_chunks = seq // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, di, st), jnp.float32)
    dk2 = d_skip.reshape(1, di)
    grid = (bsz, di // d_block, n_chunks)
    out, h_last = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, st), lambda b, dblk, t: (b, t, dblk, 0)),
            pl.BlockSpec((1, chunk, d_block, st), lambda b, dblk, t: (b, t, dblk, 0)),
            pl.BlockSpec((1, chunk, st), lambda b, dblk, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, d_block), lambda b, dblk, t: (b, t, dblk)),
            pl.BlockSpec((1, d_block), lambda b, dblk, t: (0, dblk)),
            pl.BlockSpec((1, chunk, d_block), lambda b, dblk, t: (b, t, dblk)),
            pl.BlockSpec((1, d_block, st), lambda b, dblk, t: (b, dblk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, dblk, t: (b, t, dblk)),
            pl.BlockSpec((1, d_block, st), lambda b, dblk, t: (b, dblk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, seq, di), x_skip.dtype),
            jax.ShapeDtypeStruct((bsz, di, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, st), jnp.float32)],
        interpret=interpret,
    )(a_bar, b_bar, c, x_skip, dk2, z, h0.astype(jnp.float32))
    return out, h_last
