"""Pallas kernel microbenchmarks.

On this CPU container the kernels execute in interpret mode, so absolute
times are NOT TPU times — the CSV reports (a) interpret-mode sanity
timings, (b) the PolyTOPS plan for each kernel (the actual deliverable:
grid order/tiles), and (c) the XLA-reference timing for context.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from ..core.akg import plan_attention, plan_matmul
from . import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run(out=sys.stdout):
    print("kernel,us_per_call,plan", file=out)
    r = jax.random.PRNGKey(0)
    for m, n, k in [(256, 256, 256), (512, 512, 512)]:
        a = jax.random.normal(r, (m, k), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(r, 1), (k, n), jnp.float32)
        plan = plan_matmul(m, n, k)
        t_i = _time(lambda x, y: ops.matmul(x, y), a, b, reps=1)
        t_x = _time(lambda x, y: ref.matmul_ref(x, y), a, b)
        print(f"matmul_{m}x{n}x{k}_interpret,{t_i:.1f},"
              f"order={'>'.join(plan.loop_order)} tiles={plan.tile}", file=out)
        print(f"matmul_{m}x{n}x{k}_xla_ref,{t_x:.1f},-", file=out)
    b_, s, h, d = 1, 512, 4, 64
    q = jax.random.normal(r, (b_, s, h, d), jnp.float32) * 0.3
    kk = jax.random.normal(jax.random.fold_in(r, 2), (b_, s, h, d), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(r, 3), (b_, s, h, d), jnp.float32)
    plan = plan_attention(s, s, d)
    t_i = _time(lambda *x: ops.flash_attention(*x), q, kk, v, reps=1)
    print(f"flash_attn_{s}_interpret,{t_i:.1f},"
          f"bq={plan.tile['q']} bk={plan.tile['kk']} lanes={plan.vector_iter}",
          file=out)
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (1, 128, 256, 16))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 4), (1, 128, 256, 16)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 5), (1, 128, 16))
    t_i = _time(lambda *x: ops.selective_scan(*x), a_bar, b_bar, c, reps=1)
    print(f"mamba_scan_128_interpret,{t_i:.1f},state-in-VMEM chunked", file=out)
