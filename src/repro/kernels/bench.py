"""Pallas kernel microbenchmarks + CI smoke gate.

On this CPU container the kernels execute in interpret mode, so absolute
times are NOT TPU times — the CSV reports (a) interpret-mode sanity
timings, (b) the PolyTOPS plan for each kernel (the actual deliverable:
grid order/tiles), and (c) the XLA-reference timing for context.

``python -m repro.kernels.bench --smoke`` is the JAX-CPU smoke gate run
by ``scripts/tier1.sh`` / CI: every kernel executes through the
schedule-tree → ``lower_to_kernel_plan`` lowering (interpret mode) and
must numerically match its pure-jnp oracle in ``repro.kernels.ref`` —
exit status 1 on any mismatch.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.akg import (plan_attention, plan_matmul, plan_mamba_scan,
                        plan_scan_gate)
from . import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run(out=sys.stdout):
    print("kernel,us_per_call,plan", file=out)
    r = jax.random.PRNGKey(0)
    for m, n, k in [(256, 256, 256), (512, 512, 512)]:
        a = jax.random.normal(r, (m, k), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(r, 1), (k, n), jnp.float32)
        plan = plan_matmul(m, n, k)
        t_i = _time(lambda x, y: ops.matmul(x, y), a, b, reps=1)
        t_x = _time(lambda x, y: ref.matmul_ref(x, y), a, b)
        print(f"matmul_{m}x{n}x{k}_interpret,{t_i:.1f},"
              f"order={'>'.join(plan.loop_order)} tiles={plan.tile}", file=out)
        print(f"matmul_{m}x{n}x{k}_xla_ref,{t_x:.1f},-", file=out)
    b_, s, h, d = 1, 512, 4, 64
    q = jax.random.normal(r, (b_, s, h, d), jnp.float32) * 0.3
    kk = jax.random.normal(jax.random.fold_in(r, 2), (b_, s, h, d), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(r, 3), (b_, s, h, d), jnp.float32)
    plan = plan_attention(s, s, d)
    t_i = _time(lambda *x: ops.flash_attention(*x), q, kk, v, reps=1)
    print(f"flash_attn_{s}_interpret,{t_i:.1f},"
          f"bq={plan.tile['q']} bk={plan.tile['kk']} lanes={plan.vector_iter}",
          file=out)
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (1, 128, 256, 16))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 4), (1, 128, 256, 16)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 5), (1, 128, 16))
    plan = plan_mamba_scan(128, 256, 16)
    t_i = _time(lambda *x: ops.selective_scan(*x), a_bar, b_bar, c, reps=1)
    print(f"mamba_scan_128_interpret,{t_i:.1f},"
          f"chunk={plan.tile['t']} dblock={plan.tile['d']} state-in-VMEM",
          file=out)
    x_skip = jax.random.normal(jax.random.fold_in(r, 6), (1, 128, 256))
    dk = jax.random.normal(jax.random.fold_in(r, 7), (256,))
    z = jax.random.normal(jax.random.fold_in(r, 8), (1, 128, 256))
    plan = plan_scan_gate(128, 256, 16)
    t_i = _time(lambda *x: ops.scan_gate(*x)[0], a_bar, b_bar, c, x_skip,
                dk, z, reps=1)
    print(f"scan_gate_128_interpret,{t_i:.1f},"
          f"chunk={plan.tile['t']} dblock={plan.tile['d']} fused-gate",
          file=out)


def smoke(out=sys.stdout) -> int:
    """CI gate: run every Pallas kernel (small shapes, interpret mode)
    through the schedule-tree lowering and check numerical agreement
    with the pure-jnp oracles.  Returns the number of failures."""
    failures = 0
    r = jax.random.PRNGKey(0)

    def check(name, got, want, tol):
        nonlocal failures
        got = np.asarray(got, np.float32)
        want = np.asarray(want, np.float32)
        err = float(np.max(np.abs(got - want)))
        ok = np.allclose(got, want, rtol=tol, atol=tol)
        print(f"{name},{'PASS' if ok else 'FAIL'},max_abs_err={err:.3e}",
              file=out)
        if not ok:
            failures += 1

    m = n = k = 128
    a = jax.random.normal(r, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(r, 1), (k, n), jnp.float32)
    plan = plan_matmul(m, n, k)
    print(f"plan_matmul,{'>'.join(plan.loop_order)},vec={plan.vector_iter} "
          f"tiles={plan.tile}", file=out)
    check("matmul_smoke", ops.matmul(a, b, interpret=True),
          ref.matmul_ref(a, b), 1e-4)

    bsz, s, h, d = 1, 128, 2, 64
    q = jax.random.normal(r, (bsz, s, h, d), jnp.float32) * 0.3
    kk = jax.random.normal(jax.random.fold_in(r, 2), (bsz, s, h, d),
                           jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(r, 3), (bsz, s, h, d),
                          jnp.float32)
    plan = plan_attention(s, s, d)
    print(f"plan_attention,{'>'.join(plan.loop_order)},vec={plan.vector_iter} "
          f"tiles={plan.tile}", file=out)
    got = ops.flash_attention(q, kk, v, causal=True, interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(bsz * h, s, d),
        kk.transpose(0, 2, 1, 3).reshape(bsz * h, s, d),
        v.transpose(0, 2, 1, 3).reshape(bsz * h, s, d),
        causal=True).reshape(bsz, h, s, d).transpose(0, 2, 1, 3)
    check("flash_attention_smoke", got, want, 1e-4)

    bsz, s, di, st = 1, 64, 128, 8
    a_bar = jax.nn.sigmoid(jax.random.normal(r, (bsz, s, di, st))) * 0.9
    b_bar = jax.random.normal(jax.random.fold_in(r, 4),
                              (bsz, s, di, st)) * 0.1
    c = jax.random.normal(jax.random.fold_in(r, 5), (bsz, s, st))
    plan = plan_mamba_scan(s, di, st)
    print(f"plan_mamba_scan,{'>'.join(plan.loop_order)},"
          f"vec={plan.vector_iter} tiles={plan.tile}", file=out)
    check("mamba_scan_smoke", ops.selective_scan(a_bar, b_bar, c,
                                                 interpret=True),
          ref.selective_scan_ref(a_bar, b_bar, c), 1e-4)

    # fused scan+skip+gate kernel (autotuned via rank_pallas_plans),
    # full-sequence and chunked with the h0 state carry
    x_skip = jax.random.normal(jax.random.fold_in(r, 6), (bsz, s, di))
    dk = jax.random.normal(jax.random.fold_in(r, 7), (di,))
    z = jax.random.normal(jax.random.fold_in(r, 8), (bsz, s, di))
    plan = plan_scan_gate(s, di, st)
    print(f"plan_scan_gate,{'>'.join(plan.loop_order)},"
          f"vec={plan.vector_iter} tiles={plan.tile}", file=out)
    o_got, h_got = ops.scan_gate(a_bar, b_bar, c, x_skip, dk, z,
                                 interpret=True)
    o_want, h_want = ref.scan_gate_ref(a_bar, b_bar, c, x_skip, dk, z)
    check("scan_gate_smoke", o_got, o_want, 1e-4)
    check("scan_gate_state_smoke", h_got, h_want, 1e-4)
    m_ = s // 2
    _, h1 = ops.scan_gate(a_bar[:, :m_], b_bar[:, :m_], c[:, :m_],
                          x_skip[:, :m_], dk, z[:, :m_], interpret=True)
    o2, h2 = ops.scan_gate(a_bar[:, m_:], b_bar[:, m_:], c[:, m_:],
                           x_skip[:, m_:], dk, z[:, m_:], h0=h1,
                           interpret=True)
    check("scan_gate_chunk_carry_smoke", o2, o_want[:, m_:], 1e-4)
    check("scan_gate_chunk_state_smoke", h2, h_want, 1e-4)

    print(f"pallas_smoke,{'PASS' if not failures else 'FAIL'},"
          f"failures={failures}", file=out)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the numerical smoke gate instead of timings")
    args = ap.parse_args(argv)
    if args.smoke:
        return 1 if smoke() else 0
    run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
