"""Causal flash attention Pallas kernel (online softmax).

Block geometry from repro.core.akg.plan_attention (PolyTOPS schedules
the QKᵀ core: head_dim → lanes, q/k block band → grid). Grid is
(batch·heads, q_blocks, k_blocks) with the k axis minormost; the running
(max, sum, acc) state lives in VMEM scratch across k blocks. Causality
is handled by masking within the diagonal block and by pl.when-skipping
blocks above the diagonal.

``q_offset`` supports chunked prefill: the q rows are a contiguous
chunk starting at that (traced, scalar) position of the sequence, so
causality masks against ``q_offset + row`` — one compiled kernel serves
every chunk position.  The offset rides in SMEM; 0 recovers the plain
causal kernel bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.akg import plan_attention

NEG_INF = -1e30


def _kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, k_steps: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    off = off_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            rows = off + qi * bq \
                + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v_ref[0].astype(jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the (offset) diagonal
        pl.when(off + qi * bq + bq - 1 >= ki * bk)(_block)
    else:
        _block()

    @pl.when(ki == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    q_offset: Optional[jnp.ndarray] = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q, k, v: (bh, seq, d) — batch×heads flattened. GQA repetition is
    handled by the ops wrapper.  ``q_offset`` (scalar int32, traced)
    places the q rows at that sequence position for causal masking —
    the chunked-prefill case where k holds ``q_offset + sq`` (or more,
    trailing rows masked out by causality) valid entries."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    plan = plan_attention(sq, sk, d)
    bq = min(block_q or plan.tile.get("q", 128), sq)
    bk = min(block_k or plan.tile.get("kk", 128), sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    k_steps = sk // bk
    grid = (bh, sq // bq, k_steps)
    scale = 1.0 / (d ** 0.5)
    if q_offset is None:
        q_offset = jnp.zeros((), jnp.int32)
    off = jnp.asarray(q_offset, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, k_steps=k_steps,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(off, q, k, v)
