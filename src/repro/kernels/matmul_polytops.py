"""PolyTOPS-planned tiled matmul Pallas kernel.

The grid order and BlockSpec tile shapes come from a PolyTOPS schedule
of the matmul SCoP (repro.core.akg.plan_matmul): tensor-style
(contiguity ≻ proximity) scheduling yields the (i, k, j) loop order with
j vectorized — mapped here to a (mi, ni, ki) grid where the k grid axis
is minormost (sequential accumulation into a VMEM f32 scratch) and the
j/lane dimension lives in the 128-wide minor axis of every tile.

TPU notes: tiles are multiples of (8, 128); the MXU consumes
(bm×bk)·(bk×bn) per grid step; accumulation dtype is f32 regardless of
input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.akg import KernelPlan, plan_matmul


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


def matmul(a: jnp.ndarray, b: jnp.ndarray,
           plan: Optional[KernelPlan] = None,
           interpret: bool = True) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N] with PolyTOPS-planned tiling."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    plan = plan or plan_matmul(m, n, k)
    bm = _pick(plan.tile.get("i", 128), m)
    bn = _pick(plan.tile.get("j", 128), n)
    bk = _pick(plan.tile.get("kk", 128), k)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
