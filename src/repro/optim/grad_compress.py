"""Gradient compression with error feedback (cross-pod traffic saver).

At 2+ pods the inter-pod all-reduce rides the slower DCI links; casting
gradients to bf16 for the reduction halves that traffic. Error feedback
(Seide et al.) accumulates the quantization residual locally so the
compression is unbiased over time.

Usage inside the train step (see train/loop.py): the accumulated f32
gradients are compressed before the optimizer; the residual buffer is
part of the training state (sharded like the params).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, residual) -> Tuple[Any, Any]:
    """Returns (compressed bf16 grads, new residual)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(jnp.bfloat16)
        new_r = corrected - q.astype(jnp.float32)
        return q, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = treedef.unflatten([o[0] for o in out])
    new_res = treedef.unflatten([o[1] for o in out])
    return comp, new_res


def decompress(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
