"""AdamW with decoupled weight decay, pure pytree implementation.

Optimizer states inherit the parameter shardings (ZeRO-style: with FSDP
param specs the m/v states are sharded over data×model automatically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
