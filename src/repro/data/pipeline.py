"""Deterministic synthetic LM data pipeline.

Design goals (scaled-down versions of what a 1000-node fleet needs):
* **Determinism & resumability**: batch(step) is a pure function of
  (seed, step) — restoring a checkpoint at step k replays the exact
  stream with no data state beyond the step counter.
* **Shardability**: per-host slicing by (host_id, n_hosts) so each host
  materializes only its rows (single-host here, but the API is the
  multi-host one).
* **Document structure**: synthetic "documents" with EOS boundaries and
  a skewed unigram distribution — enough signal for a train-loss-drops
  integration test, and packing behaves like real data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 1
    mean_doc_len: int = 64
    host_id: int = 0
    n_hosts: int = 1


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row]))

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step, row)
        out = np.empty(cfg.seq_len + 1, np.int32)
        pos = 0
        while pos < cfg.seq_len + 1:
            dlen = int(rng.exponential(cfg.mean_doc_len)) + 8
            # skewed unigram over a per-doc "topic" slice of the vocab
            topic = int(rng.integers(0, max(cfg.vocab // 64, 1)))
            lo = 2 + topic * 61 % max(cfg.vocab - 64, 2)
            doc = (lo + rng.zipf(1.5, size=dlen) % 61).astype(np.int32)
            doc = np.clip(doc, 2, cfg.vocab - 1)
            doc[-1] = cfg.eos_id
            take = min(dlen, cfg.seq_len + 1 - pos)
            out[pos:pos + take] = doc[:take]
            pos += take
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rows = [self._row(step, cfg.host_id * per_host + r)
                for r in range(per_host)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
