"""Fault tolerance & straggler mitigation for the training loop.

At 1000+ nodes the failure model is: (a) hard node loss → job restarts
(possibly on fewer pods) and restores the latest checkpoint, resharding
elastically; (b) stragglers → detected by step-time anomaly tracking;
the scheduler-level remedies (hot spares, re-slicing) are cluster-side,
but the *detection* signal and the in-job policy hooks live here.

``run_resilient`` wraps the step loop: simulated/real exceptions trigger
restore-and-continue, bounded by ``max_restarts``. The same hook is
where a real deployment calls its cluster manager.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than k× the mean."""
    alpha: float = 0.1
    threshold: float = 2.5
    ewma: Optional[float] = None
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        is_straggler = (self.ewma is not None
                        and seconds > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append(step)
        self.ewma = (seconds if self.ewma is None
                     else (1 - self.alpha) * self.ewma + self.alpha * seconds)
        return is_straggler


@dataclass
class FaultPolicy:
    max_restarts: int = 3
    on_straggler: str = "log"       # 'log' | 'skip-sync' (doc'd; cluster-side)
    checkpoint_every: int = 50


class Preemption(Exception):
    """Raised (or injected in tests) to simulate node loss."""


def run_resilient(step_fn: Callable[[int], Dict], start_step: int,
                  total_steps: int, restore_fn: Callable[[], int],
                  save_fn: Callable[[int], None],
                  policy: Optional[FaultPolicy] = None,
                  monitor: Optional[StragglerMonitor] = None,
                  log_fn: Callable[[str], None] = print) -> Dict:
    """Run step_fn(step) for steps [start, total); on failure restore the
    latest checkpoint and continue. Returns summary stats."""
    policy = policy or FaultPolicy()
    monitor = monitor or StragglerMonitor()
    restarts = 0
    step = start_step
    metrics: Dict = {}
    while step < total_steps:
        try:
            t0 = time.time()
            metrics = step_fn(step)
            dt = time.time() - t0
            if monitor.observe(step, dt):
                log_fn(f"[fault] straggler suspected at step {step} "
                       f"({dt:.2f}s vs ewma {monitor.ewma:.2f}s) — policy="
                       f"{policy.on_straggler}")
            if (step + 1) % policy.checkpoint_every == 0:
                save_fn(step + 1)
            step += 1
        except Preemption as e:
            restarts += 1
            if restarts > policy.max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={policy.max_restarts}") from e
            log_fn(f"[fault] preemption at step {step}: {e}; restoring")
            step = restore_fn()
    return {"final_step": step, "restarts": restarts,
            "stragglers": list(monitor.flagged), "last_metrics": metrics}
