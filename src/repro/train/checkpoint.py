"""Fault-tolerant checkpointing: atomic, versioned, reshardable.

* **Atomic**: write to ``step_K.tmp/`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint.
* **Keep-N**: old checkpoints garbage-collected after a successful save.
* **Elastic restore**: leaves are stored as host numpy arrays with their
  pytree paths; restore ``device_put``s onto whatever mesh/shardings the
  *current* job uses — restarting on a different topology (e.g. after
  losing a pod) reshards transparently.
* On a real multi-host cluster each host writes only its addressable
  shards (jax.experimental.multihost_utils); single-host here, same API.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """npz can't round-trip ml_dtypes (bf16 loads as void) — store such
    leaves as uint16 views plus a dtype manifest."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.float16, np.int8, np.uint8,
                             np.int16, np.uint16, np.uint64, np.bool_):
            arr = arr.view(np.uint16) if arr.dtype.itemsize == 2 else \
                arr.astype(np.float32)
        flat[key] = arr
    return flat, dtypes


def _unflatten_cast(npz, dtypes: Dict[str, str]):
    import ml_dtypes
    out = []
    for k in npz.files:
        arr = npz[k]
        want = dtypes.get(k, str(arr.dtype))
        if str(arr.dtype) != want:
            if want == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            else:
                arr = arr.astype(np.dtype(want))
        out.append(arr)
    return out


def save(ckpt_dir, step: int, params, opt_state, extra: Optional[Dict] = None,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        return final          # idempotent: step already checkpointed
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    p_flat, p_dt = _flatten(params)
    o_flat, o_dt = _flatten(opt_state)
    np.savez(tmp / "params.npz", **p_flat)
    np.savez(tmp / "opt.npz", **o_flat)
    treedefs = {
        "params": jax.tree.structure(params),
        "opt": jax.tree.structure(opt_state),
    }
    with open(tmp / "treedef.pkl", "wb") as f:
        pickle.dump(treedefs, f)
    meta = {"step": step, "time": time.time(),
            "dtypes": {"params": p_dt, "opt": o_dt}, **(extra or {})}
    (tmp / "meta.json").write_text(json.dumps(meta))
    os.replace(tmp, final)
    # GC old checkpoints
    ckpts = sorted(p for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_") and not p.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_") and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir, step: Optional[int] = None,
            param_shardings=None, opt_shardings=None
            ) -> Tuple[Any, Any, Dict]:
    """Load a checkpoint; optionally place leaves with the given
    shardings (elastic resharding onto the current mesh)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    with open(d / "treedef.pkl", "rb") as f:
        treedefs = pickle.load(f)
    meta = json.loads((d / "meta.json").read_text())
    dtypes = meta.get("dtypes", {"params": {}, "opt": {}})
    p_flat = np.load(d / "params.npz")
    o_flat = np.load(d / "opt.npz")
    params = jax.tree.unflatten(treedefs["params"],
                                _unflatten_cast(p_flat, dtypes["params"]))
    opt = jax.tree.unflatten(treedefs["opt"],
                             _unflatten_cast(o_flat, dtypes["opt"]))

    def place(tree, shardings):
        if shardings is None:
            import jax.numpy as jnp
            return jax.tree.map(jnp.asarray, tree)
        return jax.tree.map(jax.device_put, tree, shardings)

    return place(params, param_shardings), place(opt, opt_shardings), meta
