"""jit-able train / prefill / serve step builders.

``make_train_step`` implements microbatched gradient accumulation
(lax.scan over micro-steps, f32 accumulators) + AdamW. The returned
functions are pure — the launcher decides shardings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from ..model import transformer as T
from ..optim import adamw


def _batch_kw(cfg: ArchConfig, batch: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    kw = {}
    if cfg.family == "vlm":
        kw["frontend"] = batch["frontend"]
    if cfg.enc_layers:
        kw["enc_frontend"] = batch["enc_frontend"]
    return kw


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, n_micro: int):
    def loss_fn(params, tokens, labels, extra):
        return T.lm_loss(params, cfg, tokens, labels, **extra)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        gb, seq = tokens.shape
        mb = gb // n_micro
        tok_m = tokens.reshape(n_micro, mb, seq)
        lab_m = labels.reshape(n_micro, mb, seq)
        extra = _batch_kw(cfg, batch)
        extra_m = jax.tree.map(
            lambda x: x.reshape((n_micro, mb) + x.shape[1:]), extra)

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        from ..model.sharding import constrain_grads

        def micro(acc, xs):
            tok, lab, ex = xs
            loss, grads = jax.value_and_grad(loss_fn)(params, tok, lab, ex)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return constrain_grads(acc), loss

        acc, losses = jax.lax.scan(micro, acc0, (tok_m, lab_m, extra_m))
        grads = jax.tree.map(lambda a: a / n_micro, acc)
        params2, opt_state2, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=jnp.mean(losses))
        return params2, opt_state2, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        extra = _batch_kw(cfg, batch)
        logits, cache = T.prefill(params, cfg, batch["tokens"], **extra)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One decode step over a full KV cache (the decode_*/long_* shape)."""
    def serve_step(params, batch):
        memory = batch.get("memory")
        logits, new_cache = T.decode_step(
            params, cfg, batch["token"], batch["cache"], batch["cache_len"],
            memory)
        return logits, new_cache
    return serve_step
