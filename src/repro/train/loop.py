"""Training orchestrator: mesh, data, steps, checkpoints, fault hooks.

Scales from a single CPU device (integration tests, examples) to the
production mesh (same code path the dry-run lowers). The loop is
deliberately framework-shaped: config in, metrics out, restart-safe.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from ..data.pipeline import DataConfig, SyntheticLM
from ..model import transformer as T
from ..model.sharding import (clear_logical_rules, clear_param_handlers,
                              set_logical_rules, set_moe_groups,
                              set_param_handlers)
from ..optim import adamw
from . import checkpoint as CKPT
from . import fault as FAULT
from . import steps as STEPS


@dataclass
class TrainConfig:
    arch: ArchConfig
    total_steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    n_micro: int = 1
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    use_mesh: bool = False          # production mesh (dry-run topology)
    multi_pod: bool = False
    grad_compress: bool = False


class Trainer:
    def __init__(self, cfg: TrainConfig):
        self.cfg = cfg
        self.arch = cfg.arch
        self.mesh = None
        if cfg.use_mesh:
            from ..launch import mesh as M
            self.mesh = M.make_production_mesh(multi_pod=cfg.multi_pod)
            rules = M.logical_rules(self.arch, self.mesh, batch=cfg.global_batch)
            set_logical_rules(self.mesh, rules)
            gf, gr = M.make_param_handlers(self.arch, self.mesh)
            set_param_handlers(gf, gr)
            set_moe_groups(M.axis_size(self.mesh, M.dp_axes(self.mesh)))
        self.data = SyntheticLM(DataConfig(
            vocab=self.arch.vocab, seq_len=cfg.seq_len,
            global_batch=cfg.global_batch, seed=cfg.seed))
        key = jax.random.PRNGKey(cfg.seed)
        self.params = T.init_params(key, self.arch)
        self.opt_state = adamw.init(self.params)
        self.step_fn = jax.jit(
            STEPS.make_train_step(self.arch, cfg.opt, cfg.n_micro))
        self.step = 0
        self.history: list = []

    # -- checkpointing ----------------------------------------------------
    def save(self, step: int):
        if self.cfg.ckpt_dir:
            CKPT.save(self.cfg.ckpt_dir, step, self.params, self.opt_state,
                      extra={"arch": self.arch.name})

    def restore(self) -> int:
        if not self.cfg.ckpt_dir:
            return 0
        latest = CKPT.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return 0
        self.params, self.opt_state, meta = CKPT.restore(self.cfg.ckpt_dir)
        self.step = meta["step"]
        return self.step

    # -- main loop ----------------------------------------------------------
    def run_step(self, step: int) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in self.data.batch(step).items()}
        if self.arch.family == "vlm":
            batch["frontend"] = jnp.zeros(
                (self.cfg.global_batch, self.arch.frontend_len,
                 self.arch.d_model), jnp.bfloat16)
        if self.arch.enc_layers:
            batch["enc_frontend"] = jnp.zeros(
                (self.cfg.global_batch, self.arch.frontend_len,
                 self.arch.d_model), jnp.bfloat16)
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch)
        m = {k: float(v) for k, v in metrics.items()}
        self.history.append(m)
        if step % self.cfg.log_every == 0:
            print(f"[train] step={step} loss={m['loss']:.4f} "
                  f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.3f}", flush=True)
        return m

    def fit(self) -> Dict:
        start = self.restore()
        policy = FAULT.FaultPolicy(checkpoint_every=self.cfg.ckpt_every)
        out = FAULT.run_resilient(
            self.run_step, start, self.cfg.total_steps,
            restore_fn=self.restore, save_fn=self.save, policy=policy)
        if self.cfg.ckpt_dir:
            self.save(self.cfg.total_steps)
        return out

    def close(self):
        clear_logical_rules()
        clear_param_handlers()
