"""Mamba-1 block (selective SSM) — falcon-mamba / jamba layers.

Sequence processing uses an associative scan over the diagonal SSM
recurrence h_t = a_t ⊙ h_{t-1} + b_t (a_t = exp(Δ_t·A)), which is both
TPU-friendly (log-depth) and exact. Decode keeps (conv_state, ssm_state)
as the cache.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from .layers import dense_init
from .sharding import shard_activation


def init_mamba(key, cfg: ArchConfig, dtype) -> Dict:
    d, di, st, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, dtr + 2 * st, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, st + 1, dtype=jnp.float32), (di, st))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _ssm_scan(a, b):
    """Associative scan over (decay, increment) pairs along axis 1."""
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by
    return jax.lax.associative_scan(combine, (a, b), axis=1)


def _ssm_inputs(p, cfg: ArchConfig, xs):
    """Input-dependent recurrence coefficients from post-conv
    activations xs (b, s, di): (a_bar, b_bar (b, s, di, st), Cm (b, s, st))."""
    st, dtr = cfg.ssm_state, cfg.dt_rank_
    proj = xs @ p["x_proj"]                                     # (b, s, dtr+2st)
    dt_r, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])                                    # (di, st)
    a_bar = jnp.exp(dt[..., None] * A)                          # (b, s, di, st)
    b_bar = (dt[..., None] * Bm[..., None, :]) * xs.astype(jnp.float32)[..., None]
    return a_bar, b_bar, Cm


def _fused_scan_gate(cfg: ArchConfig, xs) -> bool:
    from .pallas_mode import mode
    md = mode()
    return (md.enabled and md.fused_scan_gate
            and xs.shape[1] >= md.min_scan_seq)


def _selective_ssm(p, cfg: ArchConfig, xs, return_last: bool = False):
    """xs: (b, s, di) post-conv activations; returns ((b, s, di), h_last)."""
    a_bar, b_bar, Cm = _ssm_inputs(p, cfg, xs)
    _, h = _ssm_scan(a_bar, b_bar)                              # (b, s, di, st)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm)
    y = y + xs.astype(jnp.float32) * p["d_skip"]
    return y.astype(xs.dtype), (h[:, -1] if return_last else None)


def mamba(p, cfg: ArchConfig, x, return_state: bool = False):
    """Full-sequence Mamba block. x: (b, s, d)."""
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, [di], axis=-1)
    xs = shard_activation(xs, ("batch", "seq", "ffn"))
    # causal depthwise conv
    w = p["conv_w"].astype(jnp.float32)                        # (cw, di)
    cw = w.shape[0]
    pre_conv = xs
    pad = jnp.pad(xs.astype(jnp.float32), ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + xs.shape[1], :] * w[i] for i in range(cw))
    xs = jax.nn.silu(conv + p["conv_b"]).astype(x.dtype)
    if _fused_scan_gate(cfg, xs):
        from ..kernels import ops
        a_bar, b_bar, Cm = _ssm_inputs(p, cfg, xs)
        y, h_full = ops.scan_gate(a_bar, b_bar, Cm, xs, p["d_skip"], z)
        h_last = h_full if return_state else None
    else:
        y, h_last = _selective_ssm(p, cfg, xs, return_last=return_state)
        y = y * jax.nn.silu(z)
    y = shard_activation(y, ("batch", "seq", "ffn"))
    out = y @ p["out_proj"]
    if return_state:
        conv_state = pre_conv[:, -(cw - 1):, :]
        return out, (conv_state, h_last)
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, layer_count: int, dtype) -> Dict:
    di = cfg.d_inner
    return {
        "conv": jnp.zeros((layer_count, batch, cfg.conv_width - 1, di), dtype),
        "ssm": jnp.zeros((layer_count, batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(p, cfg: ArchConfig, x, conv_state, ssm_state
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (b, 1, d); conv_state: (b, cw-1, di);
    ssm_state: (b, di, st)."""
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, [di], axis=-1)                       # (b, 1, di)
    w = p["conv_w"].astype(jnp.float32)
    hist = jnp.concatenate([conv_state.astype(jnp.float32),
                            xs.astype(jnp.float32)], axis=1)    # (b, cw, di)
    conv = jnp.einsum("bcd,cd->bd", hist, w) + p["conv_b"]
    xs1 = jax.nn.silu(conv).astype(x.dtype)                    # (b, di)
    proj = xs1 @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    a_bar = jnp.exp(dt[..., None] * A)                          # (b, di, st)
    b_bar = (dt[..., None] * Bm[:, None, :]) * xs1.astype(jnp.float32)[..., None]
    h = ssm_state * a_bar + b_bar
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xs1.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = y @ p["out_proj"]
    return out, hist[:, 1:].astype(conv_state.dtype), h


def mamba_chunk(p, cfg: ArchConfig, x, conv_state, ssm_state
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked-prefill Mamba with explicit state carry: x (b, c, d) is a
    contiguous chunk of the sequence; conv_state (b, cw-1, di) and
    ssm_state (b, di, st) carry the causal conv tail and hidden state
    from the previous chunk.  The fused Pallas route hands ``ssm_state``
    to the scan+gate kernel's ``h0``; the jnp route folds it in through
    the associative scan's cumulative decay.  Returns
    (out, new_conv_state, h_last)."""
    di = cfg.d_inner
    c = x.shape[1]
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, [di], axis=-1)                       # (b, c, di)
    w = p["conv_w"].astype(jnp.float32)
    cw = w.shape[0]
    pre = jnp.concatenate([conv_state, xs], axis=1)            # (b, cw-1+c, di)
    hist = pre.astype(jnp.float32)
    conv = sum(hist[:, i:i + c, :] * w[i] for i in range(cw))
    new_conv = pre[:, -(cw - 1):, :] if cw > 1 else conv_state
    xs = jax.nn.silu(conv + p["conv_b"]).astype(x.dtype)
    a_bar, b_bar, Cm = _ssm_inputs(p, cfg, xs)
    if _fused_scan_gate(cfg, xs):
        from ..kernels import ops
        y, h_last = ops.scan_gate(a_bar, b_bar, Cm, xs, p["d_skip"], z,
                                  h0=ssm_state)
    else:
        cum_a, h = _ssm_scan(a_bar, b_bar)
        h = h + cum_a * ssm_state.astype(jnp.float32)[:, None]
        y = jnp.einsum("bsdn,bsn->bsd", h, Cm)
        y = (y + xs.astype(jnp.float32) * p["d_skip"]).astype(xs.dtype)
        y = y * jax.nn.silu(z)
        h_last = h[:, -1]
    out = y @ p["out_proj"]
    return out, new_conv, h_last
