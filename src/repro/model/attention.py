"""GQA attention: full / sliding-window / cross, with KV-cache decode.

Activation shardings are annotated with ``with_sharding_constraint``
using logical axis names resolved by the caller-installed mesh rules
(see repro.launch.mesh.logical_axis_rules); under a plain jit (smoke
tests) the constraints are no-ops.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from .layers import apply_mrope, apply_rope, dense_init, rmsnorm, rmsnorm_init
from .sharding import shard_activation

NEG_INF = -2.3819763e38

# q-chunked attention (flash-style memory behaviour without a custom
# kernel): when > 0 and seq divides, attention computes q in chunks via
# lax.map with per-chunk rematerialization, bounding the live logits to
# (batch, heads, chunk, seq_kv). Installed by the launcher for long-seq
# shapes; 0 = full materialization (baseline).
ATTN_CHUNK = 0


def _pallas():
    from .pallas_mode import mode
    return mode()


def _flash(q, k, v, q_offset=None):
    from ..kernels import ops
    return ops.flash_attention(q, k, v, causal=True, q_offset=q_offset)


def init_attention(key, cfg: ArchConfig, dtype) -> Dict:
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _qkv(p, cfg: ArchConfig, x, positions, mrope_positions=None):
    hd = cfg.hd
    q = _split_heads(x @ p["wq"], cfg.n_heads, hd)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions)
        k = apply_mrope(k, mrope_positions)
    else:
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
    q = shard_activation(q, ("batch", "seq", "heads", None))
    k = shard_activation(k, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int):
    """q: (b, sq, h, d); k/v: (b, skv, hkv, d); mask: (b, sq, skv) or None."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    qf = q.astype(jnp.float32) / math.sqrt(d)
    q_g = qf.reshape(b, sq, hkv, n_rep, d)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", q_g, k.astype(jnp.float32))
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(v.dtype)


def _sdpa_chunked(q, k, v, n_rep: int, window: int, chunk: int,
                  causal: bool = True):
    """Map over q chunks; per-chunk remat keeps only (q,k,v) live."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    nc = sq // chunk
    qr = q.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)

    def one(args):
        qc, ci = args
        rows = ci * chunk + jnp.arange(chunk)[:, None]
        cols = jnp.arange(skv)[None, :]
        m = rows >= cols if causal else jnp.ones((chunk, skv), bool)
        if window:
            m &= (rows - cols) < window
        mask = jnp.broadcast_to(m[None], (b, chunk, skv))
        return _sdpa(qc, k, v, mask, n_rep)

    out = jax.lax.map(jax.checkpoint(one),
                      (qr, jnp.arange(nc, dtype=jnp.int32)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def causal_mask(sq: int, window: int = 0) -> jnp.ndarray:
    i = jnp.arange(sq)[:, None]
    j = jnp.arange(sq)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    return m[None]   # (1, sq, sq)


def attention(p, cfg: ArchConfig, x, positions, *, window: int = 0,
              mrope_positions=None, return_kv: bool = False):
    """Training/prefill self-attention (causal, optional sliding window)."""
    q, k, v = _qkv(p, cfg, x, positions, mrope_positions)
    sq = x.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    md = _pallas()
    if md.enabled and window == 0 and sq >= md.min_attn_q:
        out = _flash(q, k, v)
    elif ATTN_CHUNK and sq > ATTN_CHUNK and sq % ATTN_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, n_rep, window, ATTN_CHUNK)
    else:
        out = _sdpa(q, k, v, causal_mask(sq, window), n_rep)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_noncausal(p, cfg: ArchConfig, x, positions) -> jnp.ndarray:
    """Encoder self-attention (bidirectional)."""
    q, k, v = _qkv(p, cfg, x, positions)
    out = _sdpa(q, k, v, None, cfg.n_heads // cfg.n_kv_heads)
    return out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]


def cross_attention(p, cfg: ArchConfig, x, memory, positions) -> jnp.ndarray:
    """Decoder cross-attention over encoder memory (no rope on memory)."""
    hd = cfg.hd
    q = _split_heads(x @ p["wq"], cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    q = apply_rope(q, positions)
    k = _split_heads(memory @ p["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(memory @ p["wv"], cfg.n_kv_heads, hd)
    out = _sdpa(q, k, v, None, cfg.n_heads // cfg.n_kv_heads)
    return out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, layer_count: int,
                  dtype) -> Dict:
    hd = cfg.hd
    shape = (layer_count, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, cfg: ArchConfig, x, k_cache, v_cache, cache_len,
                     *, window: int = 0, mrope_positions=None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode: x (b, 1, d); k/v_cache (b, S, hkv, hd) hold
    `cache_len` valid entries; returns (out, new_k_entry, new_v_entry)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions, mrope_positions)
    k_all = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, cache_len, axis=1)
    v_all = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, cache_len, axis=1)
    S = k_all.shape[1]
    j = jnp.arange(S)[None, None, :]
    mask = j <= cache_len
    if window:
        mask &= j > (cache_len - window)
    out = _sdpa(q, k_all, v_all, jnp.broadcast_to(mask, (b, 1, S)),
                cfg.n_heads // cfg.n_kv_heads)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, k_all, v_all


# ---------------------------------------------------------------------------
# serving fast path: chunked prefill + ragged paged decode
# ---------------------------------------------------------------------------

def chunk_attention(p, cfg: ArchConfig, x, k_cache, v_cache, offset, kv_len,
                    *, window: int = 0
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked-prefill self-attention: x (b, c, d) holds rows
    ``[offset, offset+c)`` of the sequence (``offset`` a traced scalar);
    the chunk's k/v are written into the cache at ``offset`` and
    attention runs causally over ``cache[:, :kv_len]`` — ``kv_len`` the
    static page-aligned prefix covering ``offset + c`` (unwritten rows
    beyond the diagonal are masked, so the page bound is exact).  The
    Pallas route uses the flash kernel's SMEM ``q_offset``: one compiled
    kernel serves every chunk position.  Returns (out, k_cache, v_cache).
    """
    b, c, _ = x.shape
    positions = jnp.broadcast_to(offset + jnp.arange(c)[None, :], (b, c))
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), offset, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), offset, axis=1)
    kp = k_cache[:, :kv_len]
    vp = v_cache[:, :kv_len]
    md = _pallas()
    if md.enabled and window == 0 and c >= md.min_attn_q:
        out = _flash(q, kp, vp, q_offset=offset)
    else:
        rows = offset + jnp.arange(c)[:, None]
        cols = jnp.arange(kv_len)[None, :]
        m = rows >= cols
        if window:
            m &= (rows - cols) < window
        out = _sdpa(q, kp, vp, jnp.broadcast_to(m[None], (b, c, kv_len)),
                    cfg.n_heads // cfg.n_kv_heads)
    out = out.reshape(b, c, -1) @ p["wo"]
    return out, k_cache, v_cache


def paged_decode_attention(p, cfg: ArchConfig, x, k_cache, v_cache, lengths,
                           kv_len, *, window: int = 0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Ragged one-token decode over a page-aligned KV prefix.

    x: (b, 1, d); lengths: (b,) int32 per-slot valid lengths (each
    slot's token is written at its own ``lengths[i]`` — no shared
    ``max(lengths)`` that would expose stale rows in shorter slots);
    ``kv_len``: static, attention reads only ``cache[:, :kv_len]``.
    Bit-identical to :func:`decode_attention` over the full cache —
    masked entries contribute exact zeros to the softmax — while moving
    only the used pages.  Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    positions = lengths[:, None].astype(jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    upd = jax.vmap(
        lambda c, n, l: jax.lax.dynamic_update_slice_in_dim(c, n, l, axis=0))
    k_cache = upd(k_cache, k_new.astype(k_cache.dtype), lengths)
    v_cache = upd(v_cache, v_new.astype(v_cache.dtype), lengths)
    kp = k_cache[:, :kv_len]
    vp = v_cache[:, :kv_len]
    j = jnp.arange(kv_len)[None, None, :]
    mask = j <= lengths[:, None, None]
    if window:
        mask &= j > (lengths[:, None, None] - window)
    out = _sdpa(q, kp, vp, jnp.broadcast_to(mask, (b, 1, kv_len)),
                cfg.n_heads // cfg.n_kv_heads)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, k_cache, v_cache
