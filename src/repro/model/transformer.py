"""Model assembly: decoder stacks (dense/MoE/SSM/hybrid), encoder-decoder,
VLM/audio frontends (stubs per brief), train/prefill/decode entry points.

Layers are grouped into repeating *pattern blocks* (e.g. jamba's
8-layer mamba×7+attn block, gemma3's 5 local + 1 global) and executed
with ``lax.scan`` over stacked parameters — one block of HLO regardless
of depth, which keeps the 512-device dry-run compilable on one host.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from . import attention as ATT
from . import mlp as MLP
from . import ssm as SSM
from .layers import dtype_of, embed, embed_init, rmsnorm, rmsnorm_init, unembed
from .sharding import gather_params_for_compute, shard_activation


# When True, layer stacks run as unrolled Python loops instead of
# lax.scan — used by the dry-run cost probes (XLA's cost_analysis counts
# a while body once regardless of trip count, so probes must unroll).
UNROLL = False

# Activation checkpointing policy for the layer stack ('none' | 'full' |
# 'dots'). 'full' recomputes the whole block in backward (only the
# inter-block carry is saved) — without it a scanned stack saves every
# attention matrix for backward (O(layers·seq²) — 49 GiB/device for
# qwen2-vl train_4k). 'dots' saves matmul outputs (less recompute, more
# memory) — a §Perf hillclimbing knob.
REMAT = "full"


def _maybe_remat(fn):
    if REMAT == "none":
        return fn
    if REMAT == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)


@dataclass(frozen=True)
class LayerSpec:
    mixer: str          # 'attn' | 'mamba' | 'enc_attn'
    window: int         # sliding window (0 = full)
    ffn: str            # 'mlp' | 'moe' | 'none'
    cross: bool = False


def layer_specs(cfg: ArchConfig, role: str = "decoder") -> List[LayerSpec]:
    n = cfg.enc_layers if role == "encoder" else cfg.n_layers
    specs = []
    for i in range(n):
        if role == "encoder":
            specs.append(LayerSpec("enc_attn", 0, "mlp"))
            continue
        if cfg.family == "ssm":
            specs.append(LayerSpec("mamba", 0, "none"))
            continue
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        window = 0
        if cfg.sliding_window and not cfg.is_global_attn_layer(i):
            window = cfg.sliding_window
        ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
        specs.append(LayerSpec(mixer, window, ffn, cross=cfg.cross_attention))
    return specs


def pattern_period(cfg: ArchConfig, role: str = "decoder") -> int:
    if role == "encoder" or cfg.family == "ssm":
        return 1
    p = 1
    if cfg.attn_every:
        p = cfg.attn_every
    if cfg.n_experts:
        p = _lcm(p, cfg.moe_every)
    if cfg.local_global_ratio:
        p = _lcm(p, cfg.local_global_ratio + 1)
    return p


def _lcm(a, b):
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model)}
    if spec.mixer in ("attn", "enc_attn"):
        p["mixer"] = ATT.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer"] = SSM.init_mamba(ks[0], cfg, dtype)
    if spec.cross:
        p["ln_x"] = rmsnorm_init(cfg.d_model)
        p["cross"] = ATT.init_attention(ks[1], cfg, dtype)
    if spec.ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = MLP.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = MLP.init_moe(ks[2], cfg, dtype)
    return p


def _init_stack(key, cfg: ArchConfig, role: str, dtype) -> Dict:
    specs = layer_specs(cfg, role)
    period = pattern_period(cfg, role)
    n = len(specs)
    repeats, tail_n = divmod(n, period)
    # stacked params per slot in the period
    slots = []
    for s in range(period):
        keys = jax.random.split(jax.random.fold_in(key, s), max(repeats, 1))
        layers = [_init_layer(keys[r], cfg, specs[s], dtype) for r in range(repeats)]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
                     if repeats > 0 else None)
    tail = [
        _init_layer(jax.random.fold_in(key, 10_000 + i), cfg,
                    specs[repeats * period + i], dtype)
        for i in range(tail_n)
    ]
    return {"slots": slots, "tail": tail}


def init_params(key, cfg: ArchConfig) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_ln": rmsnorm_init(cfg.d_model),
        "decoder": _init_stack(ks[1], cfg, "decoder", dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[2], cfg.vocab, cfg.d_model, dtype)
    if cfg.enc_layers:
        p["encoder"] = _init_stack(ks[3], cfg, "encoder", dtype)
        p["enc_final_ln"] = rmsnorm_init(cfg.d_model)
    if cfg.frontend_stub:
        # learned projection applied to stub frontend embeddings
        from .layers import dense_init
        p["frontend_proj"] = dense_init(ks[4], cfg.d_model, cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(p, spec: LayerSpec, cfg: ArchConfig, x, positions,
                 memory=None, mrope_positions=None, collect: bool = False):
    aux = jnp.zeros((), jnp.float32)
    kv = None
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        r = ATT.attention(p["mixer"], cfg, h, positions, window=spec.window,
                          mrope_positions=mrope_positions, return_kv=collect)
        if collect:
            h, (k, v) = r
            kv = {"k": k, "v": v}
        else:
            h = r
    elif spec.mixer == "enc_attn":
        h = ATT.attention_noncausal(p["mixer"], cfg, h, positions)
    else:
        r = SSM.mamba(p["mixer"], cfg, h, return_state=collect)
        if collect:
            h, (conv_st, ssm_st) = r
            kv = {"conv": conv_st, "ssm": ssm_st}
        else:
            h = r
    x = x + h
    if spec.cross and memory is not None:
        h = ATT.cross_attention(p["cross"], cfg,
                                rmsnorm(x, p["ln_x"], cfg.norm_eps),
                                memory, positions)
        x = x + h
    if spec.ffn == "mlp":
        x = x + MLP.mlp(p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif spec.ffn == "moe":
        h, aux = MLP.moe(p["ffn"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        x = x + h
    x = shard_activation(x, ("batch", "seq", None))
    return x, aux, kv


def _run_stack(stack, cfg: ArchConfig, role: str, x, positions,
               memory=None, mrope_positions=None, collect: bool = False):
    specs = layer_specs(cfg, role)
    period = pattern_period(cfg, role)
    repeats = len(specs) // period
    aux_total = jnp.zeros((), jnp.float32)
    cache = {"slots": [], "tail": []} if collect else None
    if repeats > 0:
        def body(carry, slot_params):
            xc, aux = carry
            kvs = []
            for s in range(period):
                p_s = gather_params_for_compute(slot_params[s])
                xc, a, kv = _apply_layer(p_s, specs[s], cfg, xc,
                                         positions, memory, mrope_positions,
                                         collect)
                aux = aux + a
                kvs.append(kv)
            return (xc, aux), (tuple(kvs) if collect else None)
        body_ck = _maybe_remat(body)
        if UNROLL:
            ys_list = []
            carry = (x, aux_total)
            for r in range(repeats):
                carry, y = body_ck(carry, jax.tree.map(lambda v: v[r],
                                                       tuple(stack["slots"])))
                ys_list.append(y)
            (x, aux_total) = carry
            ys = (jax.tree.map(lambda *vs: jnp.stack(vs), *ys_list)
                  if collect else None)
        else:
            (x, aux_total), ys = jax.lax.scan(body_ck, (x, aux_total),
                                              tuple(stack["slots"]))
        if collect:
            cache["slots"] = list(ys)
    for i, p in enumerate(stack["tail"]):
        x, a, kv = _apply_layer(p, specs[repeats * period + i], cfg, x,
                                positions, memory, mrope_positions, collect)
        aux_total = aux_total + a
        if collect:
            cache["tail"].append(kv)
    if collect:
        return x, aux_total, cache
    return x, aux_total


def _frontend_embeds(params, cfg: ArchConfig, stub: jnp.ndarray) -> jnp.ndarray:
    return stub @ params["frontend_proj"]


def _mrope_positions(cfg: ArchConfig, batch: int, seq: int):
    """(b, s, 3) positions: image patches get (0, h, w) grid, text gets
    linear (t, t, t) after the patch block (Qwen2-VL scheme)."""
    fl = cfg.frontend_len
    grid = int(math.sqrt(max(fl, 1)))
    idx = jnp.arange(seq)
    in_img = idx < fl
    h = jnp.where(in_img, (idx % max(fl, 1)) // max(grid, 1), 0)
    w = jnp.where(in_img, idx % max(grid, 1), 0)
    t = jnp.where(in_img, 0, idx - fl + grid)
    pos = jnp.stack([t, jnp.where(in_img, h, t), jnp.where(in_img, w, t)], -1)
    return jnp.broadcast_to(pos[None], (batch, seq, 3)).astype(jnp.int32)


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray,
            frontend: Optional[jnp.ndarray] = None,
            enc_frontend: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss).

    tokens: (b, s_text). For frontend archs, ``frontend`` (b, fl, d) is
    prepended (vlm) ; for enc-dec, ``enc_frontend`` feeds the encoder.
    """
    x = embed(tokens, params["embed"])
    b = tokens.shape[0]
    mrope_pos = None
    if cfg.frontend_stub and cfg.family in ("vlm",) and frontend is not None:
        fe = _frontend_embeds(params, cfg, frontend)
        x = jnp.concatenate([fe, x], axis=1)
    seq = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))
    if cfg.mrope:
        mrope_pos = _mrope_positions(cfg, b, seq)
    x = shard_activation(x, ("batch", "seq", None))

    memory = None
    if cfg.enc_layers:
        enc_in = _frontend_embeds(params, cfg, enc_frontend)
        epos = jnp.broadcast_to(jnp.arange(enc_in.shape[1])[None],
                                (b, enc_in.shape[1]))
        memory, _ = _run_stack(params["encoder"], cfg, "encoder",
                               shard_activation(enc_in, ("batch", "seq", None)),
                               epos)
        memory = rmsnorm(memory, params["enc_final_ln"], cfg.norm_eps)

    x, aux = _run_stack(params["decoder"], cfg, "decoder", x, positions,
                        memory, mrope_pos)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(x, head)
    logits = shard_activation(logits, ("batch", "seq", "vocab"))
    return logits, aux


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray,
            frontend: Optional[jnp.ndarray] = None,
            enc_frontend: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Dict]:
    """Prefill: full forward that also materializes the decode cache.
    Returns (last-position logits (b, vocab), cache)."""
    x = embed(tokens, params["embed"])
    b = tokens.shape[0]
    mrope_pos = None
    if cfg.frontend_stub and cfg.family == "vlm" and frontend is not None:
        x = jnp.concatenate([_frontend_embeds(params, cfg, frontend), x], axis=1)
    seq = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))
    if cfg.mrope:
        mrope_pos = _mrope_positions(cfg, b, seq)
    x = shard_activation(x, ("batch", "seq", None))
    memory = None
    if cfg.enc_layers:
        enc_in = _frontend_embeds(params, cfg, enc_frontend)
        epos = jnp.broadcast_to(jnp.arange(enc_in.shape[1])[None],
                                (b, enc_in.shape[1]))
        memory, _ = _run_stack(params["encoder"], cfg, "encoder", enc_in, epos)
        memory = rmsnorm(memory, params["enc_final_ln"], cfg.norm_eps)
    x, _, cache = _run_stack(params["decoder"], cfg, "decoder", x, positions,
                             memory, mrope_pos, collect=True)
    x = rmsnorm(x[:, -1:, :], params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(x[:, 0, :], head)
    return logits, cache


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    """Cache pytree mirroring the stack structure."""
    dtype = dtype_of(cfg.dtype)
    specs = layer_specs(cfg, "decoder")
    period = pattern_period(cfg, "decoder")
    repeats = len(specs) // period
    hd = cfg.hd

    def slot_cache(spec: LayerSpec, count: int, stacked: bool):
        lead = (count,) if stacked else ()
        if spec.mixer == "attn" or spec.mixer == "enc_attn":
            shape = lead + (batch, max_len, cfg.n_kv_heads, hd)
            c = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        else:
            c = {
                "conv": jnp.zeros(lead + (batch, cfg.conv_width - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros(lead + (batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
            }
        return c

    slots = [slot_cache(specs[s], repeats, True) for s in range(period)] \
        if repeats else []
    tail = [slot_cache(specs[repeats * period + i], 0, False)
            for i in range(len(specs) - repeats * period)]
    return {"slots": slots, "tail": tail}


# In a cache built by init_cache, "slots" entries are stacked over
# layer-repeats so batch is axis 1; "tail" entries are per-layer so
# batch is axis 0.  The helpers below use that structural fact (not a
# shape heuristic — matching on sizes is exactly the ``bdim is None``
# bug the serving engine used to have).

def _slot_axis_map(cache, fn_slots, fn_tail):
    return {"slots": [jax.tree.map(fn_slots, c) for c in cache["slots"]],
            "tail": [jax.tree.map(fn_tail, c) for c in cache["tail"]]}


def cache_slot_view(cache: Dict, i) -> Dict:
    """Batch-size-1 view of batch slot ``i`` (traced index ok)."""
    return _slot_axis_map(
        cache,
        lambda v: jax.lax.dynamic_slice_in_dim(v, i, 1, axis=1),
        lambda v: jax.lax.dynamic_slice_in_dim(v, i, 1, axis=0))


def cache_slot_write(cache: Dict, sub: Dict, i) -> Dict:
    """Write a b=1 sub-cache (from :func:`cache_slot_view`) back at slot
    ``i``; under jit with donated operands this is an in-place row
    update, not a full-cache copy."""
    def wr(axis):
        return lambda v, s: jax.lax.dynamic_update_slice_in_dim(
            v, s.astype(v.dtype), i, axis=axis)
    return {"slots": [jax.tree.map(wr(1), c, sc)
                      for c, sc in zip(cache["slots"], sub["slots"])],
            "tail": [jax.tree.map(wr(0), c, sc)
                     for c, sc in zip(cache["tail"], sub["tail"])]}


def zero_cache_slot(cache: Dict, i) -> Dict:
    """Zero every cache row of batch slot ``i`` — reused-slot hygiene:
    a new request admitted into a slot must never see KV rows, conv
    tails or SSM state left by a longer previous occupant."""
    def z(axis):
        def go(v):
            row = jax.lax.dynamic_slice_in_dim(v, i, 1, axis=axis)
            return jax.lax.dynamic_update_slice_in_dim(
                v, jnp.zeros_like(row), i, axis=axis)
        return go
    return _slot_axis_map(cache, z(1), z(0))


def _decode_layer(p, spec: LayerSpec, cfg: ArchConfig, x, cache, cache_len,
                  memory=None, mrope_positions=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        h, k_all, v_all = ATT.decode_attention(
            p["mixer"], cfg, h, cache["k"], cache["v"], cache_len,
            window=spec.window, mrope_positions=mrope_positions)
        new_cache = {"k": k_all, "v": v_all}
    else:
        h, conv, ssm_st = SSM.mamba_decode(p["mixer"], cfg, h,
                                           cache["conv"], cache["ssm"])
        new_cache = {"conv": conv, "ssm": ssm_st}
    x = x + h
    if spec.cross and memory is not None:
        b = x.shape[0]
        pos = jnp.full((b, 1), cache_len, jnp.int32)
        x = x + ATT.cross_attention(p["cross"], cfg,
                                    rmsnorm(x, p["ln_x"], cfg.norm_eps),
                                    memory, pos)
    if spec.ffn == "mlp":
        x = x + MLP.mlp(p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif spec.ffn == "moe":
        h, _ = MLP.moe(p["ffn"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        x = x + h
    return x, new_cache


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, cache: Dict,
                cache_len: jnp.ndarray, memory: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. token: (b, 1) int32; returns (logits (b, vocab),
    new cache)."""
    specs = layer_specs(cfg, "decoder")
    period = pattern_period(cfg, "decoder")
    repeats = len(specs) // period
    x = embed(token, params["embed"])
    mrope_pos = None
    if cfg.mrope:
        b = token.shape[0]
        base = _mrope_positions(cfg, b, 1)
        mrope_pos = base + cache_len.astype(jnp.int32)
    new_cache: Dict[str, Any] = {"slots": [], "tail": []}
    if repeats:
        def body(carry, xs):
            xc = carry
            slot_params, slot_caches = xs
            new_slots = []
            for s in range(period):
                p_s = gather_params_for_compute(slot_params[s])
                xc, nc = _decode_layer(p_s, specs[s], cfg, xc,
                                       slot_caches[s], cache_len, memory,
                                       mrope_pos)
                new_slots.append(nc)
            return xc, tuple(new_slots)
        scan_xs = (tuple(params["decoder"]["slots"]), tuple(cache["slots"]))
        if UNROLL:
            ys_list = []
            for r in range(repeats):
                x, y = body(x, jax.tree.map(lambda v: v[r], scan_xs))
                ys_list.append(y)
            new_slots = jax.tree.map(lambda *vs: jnp.stack(vs), *ys_list)
        else:
            x, new_slots = jax.lax.scan(body, x, scan_xs)
        new_cache["slots"] = list(new_slots)
    for i, p in enumerate(params["decoder"]["tail"]):
        x, nc = _decode_layer(p, specs[repeats * period + i], cfg, x,
                              cache["tail"][i], cache_len, memory, mrope_pos)
        new_cache["tail"].append(nc)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(x[:, 0, :], head)
    return logits, new_cache


# ---------------------------------------------------------------------------
# serving fast path: chunked prefill + ragged paged decode
# ---------------------------------------------------------------------------

def _chunk_layer(p, spec: LayerSpec, cfg: ArchConfig, x, cache, offset,
                 kv_len):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        h, k_all, v_all = ATT.chunk_attention(
            p["mixer"], cfg, h, cache["k"], cache["v"], offset, kv_len,
            window=spec.window)
        new_cache = {"k": k_all, "v": v_all}
    else:
        h, conv, ssm_st = SSM.mamba_chunk(p["mixer"], cfg, h,
                                          cache["conv"], cache["ssm"])
        new_cache = {"conv": conv, "ssm": ssm_st}
    x = x + h
    if spec.ffn == "mlp":
        x = x + MLP.mlp(p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif spec.ffn == "moe":
        h, _ = MLP.moe(p["ffn"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        x = x + h
    return x, new_cache


def _stack_walk(params, cfg: ArchConfig, x, cache, layer_fn):
    """Shared slot-scan + tail walk for the serving step functions:
    ``layer_fn(p, spec, x, layer_cache) -> (x, new_layer_cache)``."""
    specs = layer_specs(cfg, "decoder")
    period = pattern_period(cfg, "decoder")
    repeats = len(specs) // period
    new_cache: Dict[str, Any] = {"slots": [], "tail": []}
    if repeats:
        def body(carry, xs):
            xc = carry
            slot_params, slot_caches = xs
            new_slots = []
            for s in range(period):
                p_s = gather_params_for_compute(slot_params[s])
                xc, nc = layer_fn(p_s, specs[s], xc, slot_caches[s])
                new_slots.append(nc)
            return xc, tuple(new_slots)
        scan_xs = (tuple(params["decoder"]["slots"]), tuple(cache["slots"]))
        if UNROLL:
            ys_list = []
            for r in range(repeats):
                x, y = body(x, jax.tree.map(lambda v: v[r], scan_xs))
                ys_list.append(y)
            new_slots = jax.tree.map(lambda *vs: jnp.stack(vs), *ys_list)
        else:
            x, new_slots = jax.lax.scan(body, x, scan_xs)
        new_cache["slots"] = list(new_slots)
    for i, p in enumerate(params["decoder"]["tail"]):
        x, nc = layer_fn(p, specs[repeats * period + i], x, cache["tail"][i])
        new_cache["tail"].append(nc)
    return x, new_cache


def chunk_step(params, cfg: ArchConfig, tokens: jnp.ndarray, cache: Dict,
               offset, kv_len: int) -> Tuple[jnp.ndarray, Dict]:
    """Prefill one chunk of a sequence into an existing cache.

    tokens: (b, c) — rows ``[offset, offset+c)`` of the prompt (offset a
    traced scalar, 0 for the first chunk); cache: (typically a b=1
    :func:`cache_slot_view`) with all rows < offset already prefilled.
    Returns (logits (b, c, vocab) for *every* chunk position — the
    caller picks the last real one to seed decoding — and the updated
    cache)."""
    x = embed(tokens, params["embed"])
    x = shard_activation(x, ("batch", "seq", None))
    x, new_cache = _stack_walk(
        params, cfg, x, cache,
        lambda p, spec, xc, lc: _chunk_layer(p, spec, cfg, xc, lc, offset,
                                             kv_len))
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(x, head)
    return logits, new_cache


def _serve_decode_layer(p, spec: LayerSpec, cfg: ArchConfig, x, cache,
                        lengths, active, kv_len):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        h, k_all, v_all = ATT.paged_decode_attention(
            p["mixer"], cfg, h, cache["k"], cache["v"], lengths, kv_len,
            window=spec.window)
        # inactive slots (mid-prefill / retired) write at their own
        # lengths[i] — a row the next prefill chunk or admission zeroing
        # overwrites, so no select is needed on the KV pages
        new_cache = {"k": k_all, "v": v_all}
    else:
        h, conv, ssm_st = SSM.mamba_decode(p["mixer"], cfg, h,
                                           cache["conv"], cache["ssm"])
        # the recurrent states are the *carry* of an in-flight prefill:
        # a garbage decode update would corrupt the next chunk, so keep
        # inactive slots' states untouched
        sel = active[:, None, None]
        new_cache = {"conv": jnp.where(sel, conv, cache["conv"]),
                     "ssm": jnp.where(sel, ssm_st, cache["ssm"])}
    x = x + h
    if spec.ffn == "mlp":
        x = x + MLP.mlp(p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif spec.ffn == "moe":
        h, _ = MLP.moe(p["ffn"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        x = x + h
    return x, new_cache


def serve_decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                      cache: Dict, lengths: jnp.ndarray,
                      active: jnp.ndarray, kv_len: int
                      ) -> Tuple[jnp.ndarray, Dict]:
    """Ragged continuous-batching decode step.

    token: (b, 1) int32; lengths: (b,) per-slot valid cache lengths
    (each slot attends to and extends its *own* prefix — no shared
    ``max(lengths)``); active: (b,) bool — slots currently decoding;
    kv_len: static page-aligned bound ≥ max(lengths)+1.  Returns
    (logits (b, vocab), new cache)."""
    x = embed(token, params["embed"])
    x, new_cache = _stack_walk(
        params, cfg, x, cache,
        lambda p, spec, xc, lc: _serve_decode_layer(p, spec, cfg, xc, lc,
                                                    lengths, active, kv_len))
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(x[:, 0, :], head)
    return logits, new_cache


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ArchConfig, tokens, labels, frontend=None,
            enc_frontend=None) -> jnp.ndarray:
    logits, aux = forward(params, cfg, tokens, frontend, enc_frontend)
    # frontend positions don't produce next-token predictions
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:, :]
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + 0.01 * aux
