"""Core layers (pure JAX, pytree params, no framework dependency).

Conventions:
* params are nested dicts of jnp arrays; per-layer stacks carry a
  leading layer axis and are consumed via lax.scan (fast compile —
  essential for the 512-device dry-run on one CPU host).
* matmul params live in the model dtype (bf16 by default); norms,
  softmax and rope math run in f32.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, object]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rmsnorm_init(dim: int) -> jnp.ndarray:
    return jnp.zeros((dim,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e6) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e6) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Tuple[int, int, int] = (1, 1, 2),
                theta: float = 1e6) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the head_dim/2 rotary channels are split into
    (t, h, w) sections, each rotated by its own position stream.
    positions3: (..., seq, 3)."""
    half = x.shape[-1] // 2
    tot = sum(sections)
    bounds = [half * s // tot for s in sections]
    freqs = rope_freqs(x.shape[-1], theta)
    # per-channel section id
    sec_id = jnp.concatenate([
        jnp.full((b,), i, jnp.int32) for i, b in enumerate(bounds)
    ])
    p = positions3.astype(jnp.float32)                       # (..., seq, 3)
    chan_pos = jnp.take(p, sec_id, axis=-1)                  # (..., seq, half)
    angles = chan_pos * freqs
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

# embedding lookup mode: 'take' all-gathers a vocab-sharded table (best
# for many tokens, e.g. training); 'onehot' contracts a one-hot against
# the local table shard + tiny all-reduce (best for decode, where
# gathering the whole table for a handful of tokens dominates the
# collective term). The launcher flips this per shape.
EMBED_MODE = "take"


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    if EMBED_MODE == "onehot":
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        return oh @ table
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return x @ table.T
