"""Logical-axis activation sharding.

Models annotate activations with *logical* axis names; the launcher
installs a mapping to physical mesh axes. Outside any mesh (unit tests)
the constraints are identity.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def set_logical_rules(mesh, rules: Dict[str, Optional[object]]):
    """rules: logical name -> physical mesh axis (str | tuple | None)."""
    _state.mesh = mesh
    _state.rules = dict(rules)


def clear_logical_rules():
    _state.mesh = None
    _state.rules = None


def shard_activation(x, logical_axes: Sequence[Optional[str]]):
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if mesh is None or rules is None:
        return x
    spec = []
    for ax in logical_axes:
        spec.append(None if ax is None else rules.get(ax))
    # trailing axes default to unsharded
    spec = spec[: x.ndim] + [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def set_moe_groups(n: int):
    """Number of routing groups for MoE dispatch (= DP shard count).
    Grouped routing keeps dispatch tensors linear in tokens-per-shard;
    the group axis maps to the 'batch' logical rule."""
    _state.moe_groups = n


def moe_groups() -> int:
    return getattr(_state, "moe_groups", None) or 1


def set_param_handlers(gather_fn=None, grad_fn=None):
    """Install FSDP handlers: ``gather_fn(tree)`` re-constrains sliced
    per-layer params to their compute (TP-only) sharding *inside* scan
    bodies — preventing XLA from hoisting the data-axis all-gather of the
    whole stacked parameters out of the loop; ``grad_fn(tree)`` pins
    gradient accumulators back to the full (FSDP) spec so each micro-step
    reduce-scatters instead of keeping full gradients live."""
    _state.gather_fn = gather_fn
    _state.grad_fn = grad_fn


def clear_param_handlers():
    _state.gather_fn = None
    _state.grad_fn = None
    _state.moe_groups = None


def gather_params_for_compute(tree):
    fn = getattr(_state, "gather_fn", None)
    return fn(tree) if fn is not None else tree


def constrain_grads(tree):
    fn = getattr(_state, "grad_fn", None)
    return fn(tree) if fn is not None else tree
