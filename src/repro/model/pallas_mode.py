"""Process-wide switch wiring PolyTOPS-planned Pallas kernels into the
model layers.

The model layers (:mod:`.attention`, :mod:`.mlp`, :mod:`.ssm`) consult
:func:`mode` at trace time: when ``enabled``, the jnp einsum paths are
replaced by the Pallas kernels in :mod:`repro.kernels` — block geometry
from ``repro.core.akg`` plans — wherever the operand shapes clear the
per-kernel thresholds below.  Everything stays a pure function of the
same inputs, so a jit retrace picks the mode up and numerical parity
against the jnp path is a plain ``allclose`` (asserted by
``tests/test_serve.py`` and the serving engine's startup parity check).

Thresholds exist because this container runs the kernels in interpret
mode (CPU): the flash-attention kernel beats the materialized-softmax
jnp path from ~64 query rows up, while a 32-row matmul is cheaper as
one XLA dot.  On a real TPU (``REPRO_PALLAS_COMPILE=1``) the thresholds
drop to the kernels' minimum tile sizes.

Follows the module-level-config idiom of ``transformer.UNROLL`` /
``attention.ATTN_CHUNK``: the launcher installs the mode once, layers
read it at trace time.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class PallasMode:
    enabled: bool = False
    #: route a matmul through the planned kernel only at/above this many
    #: output rows (tokens) — below it one XLA dot wins
    min_matmul_rows: int = 256
    #: flash attention only for query chunks at/above this length
    min_attn_q: int = 32
    #: fused scan+gate kernel only for sequence chunks at/above this
    min_scan_seq: int = 32
    #: use the fused scan+gate kernel (vs the plain selective_scan one)
    fused_scan_gate: bool = True


_MODE = PallasMode()


def mode() -> PallasMode:
    return _MODE


def configure(**kw) -> PallasMode:
    """Install a new mode (fields as keyword overrides); returns it."""
    global _MODE
    _MODE = replace(PallasMode(), **kw)
    return _MODE


@contextmanager
def pallas_mode(**kw):
    """Scoped :func:`configure` — restores the previous mode on exit."""
    global _MODE
    prev = _MODE
    _MODE = replace(PallasMode(), **kw)
    try:
        yield _MODE
    finally:
        _MODE = prev
