"""SwiGLU MLP and Mixture-of-Experts.

MoE uses top-k token-choice routing with a capacity-bounded one-hot
dispatch (einsum form): the dispatch tensors shard over the expert axis
(`model` mesh axis), which keeps the per-chip footprint at
tokens × experts/chips × capacity. An all-to-all materializes in the
HLO when expert-parallel and data-parallel tokens exchange — exactly
the collective the roofline analysis tracks.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.registry import ArchConfig
from .layers import dense_init
from .sharding import shard_activation


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(p, x):
    from .pallas_mode import mode
    md = mode()
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    if md.enabled and rows >= md.min_matmul_rows:
        # PolyTOPS-planned matmul kernel: worth it once the token count
        # amortizes the grid (below the threshold one XLA dot wins)
        from ..kernels import ops
        x2 = x.reshape(rows, x.shape[-1])
        h = jax.nn.silu(ops.matmul(x2, p["w_gate"])) * ops.matmul(x2, p["w_up"])
        h = h.reshape(x.shape[:-1] + (h.shape[-1],))
        h = shard_activation(h, ("batch", "seq", "ffn"))
        return ops.matmul(h.reshape(rows, -1),
                          p["w_down"]).reshape(x.shape)
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard_activation(h, ("batch", "seq", "ffn"))
    return h @ p["w_down"]


def init_moe(key, cfg: ArchConfig, dtype) -> Dict:
    e = cfg.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_ff
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(k1, d, e, jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d), jnp.float32) * (f ** -0.5)).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(k5, d, f, dtype)
    return p


def moe(p, cfg: ArchConfig, x, capacity_factor: float = 1.25
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped token-choice MoE. Returns (output, aux_loss). x: (b, s, d).

    Tokens are split into G routing groups (G = DP shard count, installed
    by the launcher): each group routes its own tokens with a per-group
    capacity, so dispatch tensors are (G, t/G, e, cap_g) — linear in
    tokens — and the group↔expert exchange lowers to an all-to-all
    between the DP and expert-parallel ('model') mesh axes.
    """
    from .sharding import moe_groups
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = moe_groups()
    if t % g or (t // g) < 1:
        g = 1
    tg = t // g
    xt = x.reshape(g, tg, d)
    xt = shard_activation(xt, ("batch", None, None))
    logits = (xt.astype(jnp.float32) @ p["router"])            # (g, tg, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (g, tg, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(capacity_factor * tg * k / e) + 3 & ~3, 4)
    # position of each (token, k) slot within its expert queue (per group)
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (g, tg, k, e)
    pos_in_e = (jnp.cumsum(oh.reshape(g, tg * k, e), axis=1)
                - 1).reshape(g, tg, k, e)
    pos = jnp.sum(pos_in_e * oh, axis=-1)                      # (g, tg, k)
    keep = pos < cap
    disp4 = (jax.nn.one_hot(gate_idx, e, dtype=x.dtype)[..., None]
             * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                              dtype=x.dtype)[..., None, :])[..., :cap]
    comb4 = disp4 * gate_vals[..., None, None].astype(x.dtype)
    disp = disp4.sum(2)                                        # (g, tg, e, cap)
    comb = comb4.sum(2)
    disp = shard_activation(disp, ("batch", None, "experts", None))
    comb = shard_activation(comb, ("batch", None, "experts", None))

    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)                # (g, e, cap, d)
    xe = shard_activation(xe, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])          # (g, e, cap, d)
    ye = shard_activation(ye, ("batch", "experts", None, None))
    out = jnp.einsum("gtec,gecd->gtd", comb, ye).reshape(b, s, d)

    if cfg.shared_expert:
        out = out + mlp(p["shared"], x)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.astype(x.dtype), aux
