"""repro: PolyTOPS reproduction + multi-pod JAX LM framework."""
