"""SeamlessM4T large v2 — encoder-decoder, multimodal (speech frontend
stubbed per brief). [arXiv:2308.11596; hf] 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    enc_layers=24, cross_attention=True,
    frontend_stub=True, frontend_len=4096,
)
