"""Qwen2-VL 7B — M-RoPE, dynamic resolution (patch frontend stubbed per
brief). [arXiv:2409.12191; hf] 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    mrope=True, frontend_stub=True, frontend_len=256,
    fsdp=True,
)
