"""Jamba v0.1 52B — hybrid Mamba+Attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536. Attention every 8th layer; MoE every other layer."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    ssm_state=16, attn_every=8, d_inner_mult=2,
    fsdp=True, sub_quadratic=True,
)
