"""Architecture registry: the 10 assigned architectures × their shapes.

Each config is exact per the assignment brief (sources noted in the
arch files). ``ArchConfig`` is consumed by ``repro.model`` builders and
``repro.launch`` (dry-run / train / serve).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0        # 0 → d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1       # MoE on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 1
    shared_expert: bool = False
    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0      # hybrid: attention on every k-th layer (jamba: 8)
    d_inner_mult: int = 2
    dt_rank: int = 0         # 0 → d_model // 16
    conv_width: int = 4
    # attention flavour
    qk_norm: bool = False
    sliding_window: int = 0
    local_global_ratio: int = 0   # gemma3: 5 local : 1 global
    mrope: bool = False
    # encoder-decoder
    enc_layers: int = 0
    cross_attention: bool = False
    frontend_stub: bool = False   # audio/vlm: frontend supplies embeddings
    frontend_len: int = 0         # stub sequence length (frames / patches)
    # numerics & distribution policy
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    fsdp: bool = False            # shard params over the data axis too
    sub_quadratic: bool = False   # eligible for long_500k
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(self.d_model // 16, 8)

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and (layer % self.moe_every == self.moe_offset % self.moe_every)

    def is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every:
            return layer % self.attn_every == self.attn_every - 1
        return True

    def is_global_attn_layer(self, layer: int) -> bool:
        if not self.local_global_ratio:
            return True
        return layer % (self.local_global_ratio + 1) == self.local_global_ratio

    def scaled(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced config of the same family for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, (self.attn_every or 2)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab=512,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            enc_layers=2 if self.enc_layers else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            frontend_len=8 if self.frontend_stub else 0,
            dt_rank=8,
            fsdp=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "jamba_v0_1_52b",
    "seamless_m4t_large_v2",
    "qwen3_moe_30b_a3b",
    "llama4_scout_17b_a16e",
    "qwen3_8b",
    "gemma3_4b",
    "granite_3_2b",
    "qwen3_0_6b",
    "falcon_mamba_7b",
    "qwen2_vl_7b",
]


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def runnable_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells, applying the brief's skip rules:
    long_500k only for sub-quadratic archs."""
    cells = []
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((aid, shape.name))
    return cells


def all_cells() -> List[Tuple[str, str]]:
    return [(aid, s) for aid in ARCH_IDS for s in SHAPES]
