"""Gemma-3 4B — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144. Sliding window 1024 on local layers."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    sliding_window=1024, local_global_ratio=5,
    sub_quadratic=True,   # 5/6 layers are O(w); global layers keep full KV
)
