"""Falcon-Mamba 7B — pure Mamba-1, attention-free.
[arXiv:2410.05355; unverified] 64L d_model=4096 vocab=65024 ssm_state=16."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm_state=16, d_inner_mult=2,
    fsdp=True, sub_quadratic=True,
)
