"""Qwen3-MoE 30B-A3B — 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf] 48L d_model=2048 32H (GQA kv=4)
expert d_ff=768 vocab=151936."""
from .registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, moe_every=1, moe_offset=0,
    qk_norm=True, fsdp=True,
)
