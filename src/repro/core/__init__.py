"""PolyTOPS: configurable, flexible polyhedral scheduler (CGO 2024).

Public API:

    from repro.core import Scop, schedule_scop, config

    k = Scop("gemm", params={"N": 512})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            with k.loop("kk", 0, "N"):
                k.stmt("C[i,j] = C[i,j] + A[i,kk] * B[kk,j]")
    sched = schedule_scop(k, config.tensor_style())
    print(sched.pretty())

Code generation: one schedule-tree IR (repro.core.schedtree) feeds every
backend — repro.core.codegen (numpy), repro.core.cbackend (C), and
repro.core.akg.lower_to_kernel_plan (Pallas kernel plans).
"""
from . import config
from .config import (DimConfig, Directive, FusionSpec, SchedulerConfig,
                     bigloops_style, feautrier_style, isl_style, pluto_style,
                     tensor_style)
from .deps import compute_dependences
from .schedcache import ScheduleCache, cached_schedule_scop
from .scheduler import PolyTOPSScheduler, Schedule, SchedulingError, schedule_scop
from .scop import Scop

__all__ = [
    "Scop", "schedule_scop", "cached_schedule_scop", "ScheduleCache",
    "PolyTOPSScheduler", "Schedule",
    "SchedulingError", "SchedulerConfig", "DimConfig", "Directive",
    "FusionSpec", "compute_dependences", "config", "pluto_style",
    "tensor_style", "isl_style", "feautrier_style", "bigloops_style",
]
