"""Pipeline-wide failure model: fault injection, deadlines, degradation.

Scheduling-as-a-service means a schedule request must *never* crash the
caller: a corrupt cache file, a gcc OOM, a subprocess timeout or an ILP
blowup has to degrade the answer, not abort the process.  This module is
the shared vocabulary for that:

* **Fault-injection registry** — named sites (:data:`FAULT_SITES`)
  threaded through the scheduling stack.  Production code calls
  :func:`fault_point` at each site (a no-op when nothing is armed);
  tests and the chaos harness (``scripts/chaos_sweep.py``) arm sites
  with seeded failures or delays via :meth:`FaultRegistry.arm` /
  :func:`inject`.

* **Wall-clock deadlines** — a :class:`Deadline` is threaded through
  the scheduler's dimension loop and the autotuner's candidate loop and
  checked at band/SCC/candidate boundaries; a breach raises
  :class:`DeadlineExceeded`, which the degradation ladder turns into
  the best answer computable in the time that was granted.

* **Degradation ladder** — :func:`schedule_with_ladder` steps down
  deterministically on any fault or deadline breach:

  ====  ==============  =====================================================
  rung  name            result
  ====  ==============  =====================================================
  0     full            the configured schedule (possibly a warm cache hit)
  1     partial         the legal schedule prefix already solved (per-dim
                        ILPs are per-SCC decomposed, so this keeps every
                        SCC result completed before the fault) completed
                        with the program-order suffix
  2     pluto_default   a fresh pluto-style schedule, no custom config
  3     identity        the program-order identity schedule — always legal,
                        needs no solver at all
  ====  ==============  =====================================================

  Provenance (``degraded``, ``fallback_level``, ``degrade_reasons``)
  is recorded on the returned ``Schedule`` and surfaced through
  ``schedcache`` payloads and ``akg`` kernel plans.

* **Typed errors** — :class:`MeasurementError` carries the kind / tag /
  phase of a failed compile-and-measure attempt so the autotuner can
  record, retry once and exclude instead of aborting the search;
  :class:`InjectedFault` marks registry-injected failures.

Everything here is deterministic: armed faults fire on exact call
counts (or on a seeded per-arm RNG when armed probabilistically), so
the same seed + the same faults always walks the same ladder rungs and
produces bit-identical schedules (the chaos gate asserts this).
"""
from __future__ import annotations

import random
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

#: every named fault site threaded through the pipeline.  Arming an
#: unknown site is an error — a typo must not silently never fire.
FAULT_SITES = (
    "ilp.solve",        # per-dimension lexmin (scheduler, both pipelines)
    "farkas.project",   # Farkas multiplier elimination (farkas.py)
    "fm.bounds",        # Fourier–Motzkin bound chains (polyhedron.bounds_of)
    "cache.read",       # schedcache pickle / crunner result-cache reads
    "cache.write",      # schedcache pickle / crunner / measurements writes
    "cc.compile",       # gcc invocation (crunner)
    "cc.run",           # compiled-binary execution (crunner)
    "measure",          # the measurement policy entry (crunner)
    "pool.dispatch",    # schedd worker-pool job dispatch (launch/schedd)
)

#: the four-rung degradation ladder, best → worst
LADDER = ("full", "partial", "pluto_default", "identity")


class ResilienceError(RuntimeError):
    """Base of every typed error this module raises."""


class InjectedFault(ResilienceError):
    """A failure injected by the fault registry (never raised in
    production — only when a test / chaos harness armed the site)."""

    def __init__(self, site: str, message: str = ""):
        super().__init__(message or f"injected fault at {site}")
        self.site = site


class DeadlineExceeded(ResilienceError):
    """A wall-clock deadline was breached at a checkpoint."""

    def __init__(self, stage: str, budget_s: float, elapsed_s: float):
        super().__init__(
            f"deadline exceeded at {stage!r}: "
            f"{elapsed_s:.3f}s elapsed > {budget_s:.3f}s budget")
        self.stage = stage
        self.budget_s = budget_s
        self.elapsed_s = elapsed_s


class MeasurementError(ResilienceError):
    """A compile-and-measure attempt failed in a *known* way.

    ``kind`` is one of: ``source_blowup`` | ``compile_timeout`` |
    ``compile_failed`` | ``run_timeout`` | ``run_failed`` | ``parse`` |
    ``checksum_mismatch`` | ``injected``.  ``tag`` is the crunner build
    tag (candidate label), ``phase`` the pipeline phase that died
    (``codegen``/``compile``/``run``/``parse``/``measure``).
    """

    def __init__(self, kind: str, tag: str = "", phase: str = "",
                 detail: str = ""):
        super().__init__(
            f"measurement failed [{kind}] tag={tag or '?'} "
            f"phase={phase or '?'}" + (f": {detail}" if detail else ""))
        self.kind = kind
        self.tag = tag
        self.phase = phase
        self.detail = detail

    def row(self) -> Dict[str, str]:
        """Plain-dict rendering for failure logs / result provenance."""
        return {"kind": self.kind, "tag": self.tag, "phase": self.phase,
                "detail": self.detail[:200]}


# ---------------------------------------------------------------------------
# fault-injection registry
# ---------------------------------------------------------------------------


@dataclass
class _Arm:
    site: str
    error: Optional[Callable[[], BaseException]]  # None → delay-only arm
    times: int                  # remaining firings (<0 → unlimited)
    delay_s: float
    p: float
    rng: Optional[random.Random]
    skip: int = 0               # let this many calls pass before firing

    def should_fire(self) -> bool:
        if self.times == 0:
            return False
        if self.skip > 0:
            self.skip -= 1
            return False
        if self.rng is not None and self.rng.random() >= self.p:
            return False
        return True


class FaultRegistry:
    """Named fault sites a test / chaos harness can arm.

    Disarmed sites cost one dict lookup per :func:`fault_point` call —
    the registry is always live, there is no build flag.  ``fired``
    counts every firing per site, so a harness can assert that an armed
    site actually executed (a fault that never fires is a sweep bug,
    not a pass).
    """

    def __init__(self):
        self._arms: Dict[str, _Arm] = {}
        self.fired: Dict[str, int] = {}

    def arm(self, site: str, *, error: Any = InjectedFault,
            times: int = 1, delay_s: float = 0.0, p: float = 1.0,
            seed: int = 0, skip: int = 0) -> None:
        """Arm ``site`` to fail/delay on its next ``times`` firings.

        ``error`` may be an exception class (instantiated per firing),
        an exception instance factory, a ready instance, or ``None``
        for a delay-only arm.  ``p`` < 1 makes firings probabilistic on
        a per-arm ``random.Random(seed)`` — deterministic for a fixed
        seed and call sequence.  ``skip`` lets that many calls pass
        cleanly before the arm starts firing — the knob for injecting a
        fault *mid*-pipeline (e.g. after the first scheduling dimension
        completed, to exercise the partial-prefix ladder rung).
        """
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}; "
                             f"known: {', '.join(FAULT_SITES)}")
        factory: Optional[Callable[[], BaseException]]
        if error is None:
            factory = None
        elif isinstance(error, BaseException):
            factory = lambda error=error: error
        elif isinstance(error, type) and issubclass(error, BaseException):
            if issubclass(error, InjectedFault):
                factory = lambda site=site: error(site)
            else:
                factory = lambda site=site: error(f"injected fault at {site}")
        elif callable(error):
            factory = error
        else:
            raise TypeError(f"unusable error spec for {site!r}: {error!r}")
        rng = random.Random(seed) if p < 1.0 else None
        self._arms[site] = _Arm(site, factory, times, delay_s, p, rng,
                                skip=skip)

    def disarm(self, site: Optional[str] = None) -> None:
        """Disarm one site, or every site when ``site`` is None."""
        if site is None:
            self._arms.clear()
        else:
            self._arms.pop(site, None)

    def reset(self) -> None:
        """Disarm everything and zero the firing counters."""
        self._arms.clear()
        self.fired.clear()

    def armed(self, site: str) -> bool:
        arm = self._arms.get(site)
        return arm is not None and arm.times != 0

    def fire(self, site: str) -> None:
        """Called by production code at a fault site.  No-op unless the
        site is armed; otherwise sleeps/raises per the arm."""
        arm = self._arms.get(site)
        if arm is None or not arm.should_fire():
            return
        if arm.times > 0:
            arm.times -= 1
        self.fired[site] = self.fired.get(site, 0) + 1
        if arm.delay_s > 0:
            time.sleep(arm.delay_s)
        if arm.error is not None:
            raise arm.error()


#: the process-wide registry every fault site fires through
REGISTRY = FaultRegistry()


def fault_point(site: str) -> None:
    """Production-side hook: fire ``site`` on the global registry."""
    REGISTRY.fire(site)


@contextmanager
def inject(site: str, **kw):
    """Arm ``site`` for the duration of a ``with`` block (test helper)."""
    REGISTRY.arm(site, **kw)
    try:
        yield REGISTRY
    finally:
        REGISTRY.disarm(site)


# ---------------------------------------------------------------------------
# wall-clock deadlines
# ---------------------------------------------------------------------------


class Deadline:
    """A wall-clock budget checked at pipeline boundaries.

    ``Deadline(None)`` never expires (the default everywhere, so the
    hot path pays a ``None`` check only).  Deadlines are *shared* down
    the pipeline: the scheduler, tree build and autotuner all check the
    same object, so the budget covers the request end to end, not each
    stage separately.
    """

    __slots__ = ("budget_s", "_t0", "_clock")

    def __init__(self, budget_s: Optional[float],
                 clock: Callable[[], float] = time.monotonic):
        self.budget_s = budget_s
        self._clock = clock
        self._t0 = clock()

    @classmethod
    def after(cls, budget_s: Optional[float]) -> "Deadline":
        return cls(budget_s)

    def elapsed(self) -> float:
        return self._clock() - self._t0

    def remaining(self) -> float:
        if self.budget_s is None:
            return float("inf")
        return self.budget_s - self.elapsed()

    def expired(self) -> bool:
        return self.budget_s is not None and self.elapsed() > self.budget_s

    def check(self, stage: str = "") -> None:
        """Raise :class:`DeadlineExceeded` if the budget is spent."""
        if self.budget_s is None:
            return
        el = self.elapsed()
        if el > self.budget_s:
            raise DeadlineExceeded(stage, self.budget_s, el)


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------


def _mark(sched, level: int, reasons: List[str]):
    sched.degraded = level > 0
    sched.fallback_level = level
    sched.degrade_reasons = list(reasons)
    return sched


def identity_schedule(scop, deps=None):
    """Rung 3: the program-order identity schedule — built row by row
    with *no* solver, LP or FM involved, so it cannot fail.  Always
    legal: it is the order the program text already executes in."""
    from fractions import Fraction

    from .scheduler import Schedule, ScheduleRow

    stmts = scop.statements
    maxd = max((s.dim for s in stmts), default=0)
    rows: Dict[int, List[ScheduleRow]] = {s.index: [] for s in stmts}
    bands: List[int] = []
    parallel: List[bool] = []
    for level in range(maxd + 1):
        for s in stmts:
            b = s.beta[level] if level < len(s.beta) else 0
            rows[s.index].append(ScheduleRow("scalar", {("cst",): Fraction(b)}))
        bands.append(2 * level)
        parallel.append(False)
        if level < maxd:
            for s in stmts:
                coeffs = ({("it", level): Fraction(1)} if level < s.dim else {})
                rows[s.index].append(ScheduleRow("linear", coeffs))
            bands.append(2 * level + 1)
            parallel.append(False)
    return Schedule(scop, rows, bands, parallel, set(), {}, [], True,
                    list(deps or []), {"fallback": True, "identity": True})


def _attach_tree(sched, deadline: Optional[Deadline]) -> None:
    """Rung acceptance includes the FM bound pass: a schedule whose tree
    cannot be built (an fm.bounds fault, an FM blowup) is not servable —
    the ladder steps down instead of letting the emitter crash later."""
    from .schedtree import schedule_tree

    if deadline is not None:
        deadline.check("schedtree")
    schedule_tree(sched)


def schedule_with_ladder(scop, config=None, engine: str = "lex",
                         deadline: Optional[Deadline] = None,
                         cache=None, with_tree: bool = False,
                         **kwargs):
    """Schedule ``scop``, degrading deterministically instead of raising.

    The only exceptions that escape are ``KeyboardInterrupt``/
    ``SystemExit`` — any other failure (injected fault, deadline breach,
    solver error, FM blowup, cache trouble) steps down the
    :data:`LADDER` until the identity rung, which cannot fail.

    ``cache`` (a ``schedcache.ScheduleCache``) serves rung 0 through the
    structural cache; degraded schedules are **never** published to it —
    a transient fault must not poison future compiles of the same kernel
    shape.  ``with_tree=True`` additionally requires the schedule tree
    to build (the AKG kernel-plan path), making tree construction part
    of each rung's acceptance test.
    """
    from .config import SchedulerConfig, pluto_style
    from .scheduler import PolyTOPSScheduler

    config = config or SchedulerConfig()
    reasons: List[str] = []

    # -- rung 0: the full configured schedule ------------------------------
    scheduler = PolyTOPSScheduler(scop, config, engine=engine,
                                  deadline=deadline, **kwargs)
    try:
        if cache is not None:
            from .schedcache import cached_schedule_scop
            sched = cached_schedule_scop(scop, config, engine=engine,
                                         cache=cache, with_tree=with_tree,
                                         deadline=deadline, **kwargs)
            if with_tree and getattr(sched, "_tree", None) is None:
                # cached_schedule_scop treats the tree as an optional
                # payload and swallows build failures; for the ladder
                # the tree is part of rung acceptance — force it so an
                # FM fault steps the ladder down instead of surfacing
                # later in the kernel-plan lowering
                _attach_tree(sched, deadline)
        else:
            sched = scheduler.schedule()
            if with_tree:
                _attach_tree(sched, deadline)
        return _mark(sched, 0, reasons)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:  # noqa: BLE001 — the ladder exists to catch all
        reasons.append(f"full: {type(e).__name__}: {e}")

    # -- rung 1: salvage the legal prefix already solved -------------------
    # Every dimension the scheduler completed is legality-constrained
    # (weak satisfaction of all active dependences), so any prefix
    # completed with the program-order suffix is a legal schedule; the
    # per-dim ILPs are per-SCC decomposed, so the prefix carries every
    # SCC result solved before the fault.
    try:
        sched = scheduler.partial_schedule()
        if sched is not None:
            if with_tree:
                _attach_tree(sched, deadline)
            return _mark(sched, 1, reasons)
        reasons.append("partial: no completed prefix to salvage")
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:  # noqa: BLE001
        reasons.append(f"partial: {type(e).__name__}: {e}")

    # -- rung 2: pluto-default strategy ------------------------------------
    try:
        sched = PolyTOPSScheduler(scop, pluto_style(), engine=engine,
                                  deadline=deadline).schedule()
        if with_tree:
            _attach_tree(sched, deadline)
        return _mark(sched, 2, reasons)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:  # noqa: BLE001
        reasons.append(f"pluto_default: {type(e).__name__}: {e}")

    # -- rung 3: program-order identity — cannot fail ----------------------
    deps = getattr(scheduler, "deps", None)
    sched = identity_schedule(scop, deps)
    if with_tree:
        try:
            _attach_tree(sched, None)   # identity trees are trivial FM
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001
            reasons.append(f"identity tree: {type(e).__name__}: {e}")
    return _mark(sched, 3, reasons)


def provenance(sched) -> Dict[str, Any]:
    """The degradation provenance of any Schedule (including ones
    unpickled from a pre-resilience cache, which lack the fields)."""
    level = int(getattr(sched, "fallback_level", 0))
    return {
        "degraded": bool(getattr(sched, "degraded", False)),
        "fallback_level": level,
        "rung": LADDER[level] if 0 <= level < len(LADDER) else str(level),
        "reasons": list(getattr(sched, "degrade_reasons", [])),
    }
