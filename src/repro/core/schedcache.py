"""Structural schedule cache: (Scop, SchedulerConfig, engine) → Schedule.

The AKG-style integration puts PolyTOPS on the compile hot path of every
custom op, and serving/benchmark loops schedule the *same kernel shapes*
over and over.  This module makes repeat scheduling a dictionary lookup:

* **Cache key** — a SHA-256 over a canonical JSON rendering of the SCoP
  structure (statement iterators, domains, access subscripts, beta
  vectors, loop nesting), the full scheduler configuration (including
  the fields ``to_json`` elides: coefficient bounds, parametric-shift,
  fusion mode), the engine, and a format version.  Two structurally
  identical kernels built through any code path hash equal; any change
  that could alter the resulting schedule changes the key.
  Configurations with a Python ``strategy`` callback are *uncacheable*
  (the callback's behaviour is not hashable) and bypass the cache.

* **Two tiers** — a process-local dict, then an on-disk pickle pool
  (``$POLYTOPS_CACHE_DIR`` or ``~/.cache/polytops/sched``) so separate
  processes (benchmark sweeps, serving workers) share warm schedules.
  Disk failures degrade to cache-miss behaviour, but never silently
  anymore: every outcome is counted in :class:`CacheStats`
  (hits/misses/disk_hits/corrupt/evicted) and a corrupt pickle is
  *quarantined* — moved aside for inspection and recomputed, so one bad
  file can't re-corrupt every future read.  Writes are atomic
  (tmp+rename) and the measurement pool appends under an advisory lock.
  The ``cache.read``/``cache.write`` fault sites let the chaos harness
  inject disk failures deterministically.

Cached ``Schedule`` objects carry their own ``Scop``/dependence objects;
per-dependence compiled-LP state is stripped on pickling (see
``Dependence.__getstate__``), so entries stay compact.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import pickle
import tempfile
from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

from .config import SchedulerConfig
from .ilp import SOLVER_TAG
from .resilience import fault_point
from .schedtree import TREE_VERSION
from .scop import Scop

try:
    import fcntl
except ImportError:          # non-POSIX: appends still line-atomic via O_APPEND
    fcntl = None

# bump when Schedule layout or scheduler semantics change incompatibly
# (v2: exact lexsimplex backend became the default — canonical optima
# differ from the HiGHS-era vertices, so v1 entries must not be reused;
# v3: Schedule carries degradation-ladder provenance fields — pre-
# resilience pickles lack them and must not be served)
CACHE_VERSION = 3


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------

def _affine_json(expr) -> list:
    return sorted((str(k), str(v)) for k, v in expr.items() if v)


def scop_fingerprint(scop: Scop) -> Dict[str, Any]:
    """Canonical, order-stable rendering of everything about a SCoP that
    can influence its schedule."""
    stmts = []
    for s in scop.statements:
        stmts.append({
            "iters": list(s.iters),
            "domain": sorted((kind, _affine_json(e)) for e, kind in s.domain),
            "accesses": [
                [a.array, a.is_write, [_affine_json(sub) for sub in a.subscripts]]
                for a in s.accesses
            ],
            "beta": list(s.beta),
            "loop_ids": list(s.loop_ids),
        })
    return {
        "params": dict(sorted(scop.params.items())),
        "param_min": scop.param_min,
        "stmts": stmts,
    }


def config_fingerprint(cfg: SchedulerConfig) -> Optional[Dict[str, Any]]:
    """Canonical config rendering, or None when the config is not
    cacheable (dynamic strategy callback)."""
    if cfg.strategy is not None:
        return None
    fp = cfg.to_json()
    # to_json omits fields that nevertheless steer the scheduler
    fp["coeff_bound"] = cfg.coeff_bound
    fp["cst_bound"] = cfg.cst_bound
    fp["parametric_shift"] = cfg.parametric_shift
    fp["custom_constraints"] = {
        str(k): list(v) for k, v in sorted(cfg.custom_constraints.items(),
                                           key=lambda kv: str(kv[0]))
    }
    return fp


def schedule_key(scop: Scop, cfg: SchedulerConfig, engine: str,
                 extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
    """Stable digest for a (Scop, config, engine) triple, or None when
    the combination cannot be cached.  The key carries the solver tag of
    the exact backend: a pivoting/canonicalization change that could
    alter the chosen optimum invalidates every entry.  ``extra`` carries
    any scheduler kwargs that can change the result (``incremental``,
    ``decompose``); under the exact engine both pipelines provably agree,
    but the keys stay distinct so a disagreement could never be masked
    by cache sharing."""
    cfp = config_fingerprint(cfg)
    if cfp is None:
        return None
    payload = json.dumps(
        {"v": CACHE_VERSION, "engine": engine, "solver": SOLVER_TAG,
         # cached Schedule payloads may carry a memoized schedule tree
         # (see cached_schedule_scop); a tree-format/construction change
         # must invalidate them even when the schedule rows are unchanged
         "tree": TREE_VERSION,
         "scop": scop_fingerprint(scop), "config": cfp,
         "extra": dict(sorted((extra or {}).items()))},
        sort_keys=True, separators=(",", ":"),
    )
    return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

def default_cache_dir() -> Optional[str]:
    d = os.environ.get("POLYTOPS_CACHE_DIR")
    if d:
        return d
    home = os.path.expanduser("~")
    return os.path.join(home, ".cache", "polytops", "sched")


@dataclass
class CacheStats:
    """Every cache outcome, counted — nothing is swallowed untallied.

    ``corrupt`` counts quarantined on-disk entries (unpicklable payload,
    injected read fault); ``evicted`` counts entries dropped by a cap
    (the ScheduleCache memory tier's FIFO cap, or a FrameCache's
    entry/byte caps).  ``bytes`` and ``latency_saved_s`` are maintained
    by :class:`FrameCache` only: the bytes currently retained, and the
    cumulative measured compute-seconds that warm hits avoided
    recomputing.  ``push_capped`` counts peer winner pushes refused by
    the schedd storm cap (rate-bounded admission protecting the frame
    cache from fleet-wide push bursts).  Indexable like the historical
    stats dict (``stats["hits"]``) so existing callers keep working.
    """
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    corrupt: int = 0
    evicted: int = 0
    bytes: int = 0
    latency_saved_s: float = 0.0
    push_capped: int = 0

    def __getitem__(self, k: str):
        return getattr(self, k)

    def __setitem__(self, k: str, v) -> None:
        setattr(self, k, v)

    def as_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["latency_saved_s"] = round(d["latency_saved_s"], 6)
        return d


class ScheduleCache:
    """In-memory + on-disk schedule cache.  Disk trouble degrades to a
    miss; corrupt entries are quarantined and counted, never raised."""

    def __init__(self, cache_dir: Optional[str] = None, disk: bool = True,
                 mem_cap: int = 4096):
        self.mem: Dict[str, Any] = {}
        self.dir = cache_dir if cache_dir is not None else default_cache_dir()
        self.disk = disk and self.dir is not None
        self.mem_cap = mem_cap
        self.stats = CacheStats()

    def _path(self, key: str) -> str:
        return os.path.join(self.dir, key[:2], key + ".pkl")

    def _quarantine(self, path: str) -> None:
        """Move a corrupt entry aside (bad file kept for inspection,
        recomputed as a miss — never a crash, never re-read)."""
        self.stats.corrupt += 1
        try:
            qdir = os.path.join(self.dir, "quarantine")
            os.makedirs(qdir, exist_ok=True)
            os.replace(path, os.path.join(qdir, os.path.basename(path)))
        except OSError:
            try:
                os.unlink(path)
            except OSError:
                pass

    def get(self, key: Optional[str]):
        if key is None:
            self.stats.misses += 1
            return None
        hit = self.mem.get(key)
        if hit is not None:
            self.stats.hits += 1
            return hit
        if self.disk:
            path = self._path(key)
            try:
                fault_point("cache.read")
                with open(path, "rb") as f:
                    hit = pickle.load(f)
                self._mem_put(key, hit)
                self.stats.hits += 1
                self.stats.disk_hits += 1
                return hit
            except FileNotFoundError:
                pass
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                # one retry distinguishes a transient IO/injected fault
                # (passes the second time — serve it) from genuine
                # corruption (fails again — quarantine, count, recompute)
                try:
                    with open(path, "rb") as f:
                        hit = pickle.load(f)
                    self._mem_put(key, hit)
                    self.stats.hits += 1
                    self.stats.disk_hits += 1
                    return hit
                except (KeyboardInterrupt, SystemExit):
                    raise
                except Exception:
                    if os.path.exists(path):
                        self._quarantine(path)
        self.stats.misses += 1
        return None

    def _mem_put(self, key: str, sched) -> None:
        if key not in self.mem and len(self.mem) >= self.mem_cap:
            # FIFO eviction: dicts preserve insertion order, and the
            # disk tier still holds the entry for a later warm read
            self.mem.pop(next(iter(self.mem)))
            self.stats.evicted += 1
        self.mem[key] = sched

    def put(self, key: Optional[str], sched) -> None:
        if key is None:
            return
        self._mem_put(key, sched)
        if not self.disk:
            return
        try:
            fault_point("cache.write")
            d = os.path.dirname(self._path(key))
            os.makedirs(d, exist_ok=True)
            # atomic publish: temp file + rename, so concurrent workers
            # never observe a torn pickle
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    pickle.dump(sched, f, protocol=pickle.HIGHEST_PROTOCOL)
                os.replace(tmp, self._path(key))
            except Exception:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            pass

    def clear(self) -> None:
        self.mem.clear()


# ---------------------------------------------------------------------------
# frame cache: pre-encoded response frames, retained by latency saved
# ---------------------------------------------------------------------------


class _Frame:
    """One cached frame: the encoded bytes, the measured seconds the
    original computation took (what a warm hit saves), and hit count."""

    __slots__ = ("frame", "compute_s", "hits", "seq")

    def __init__(self, frame: bytes, compute_s: float, seq: int):
        self.frame = frame
        self.compute_s = compute_s
        self.hits = 0
        self.seq = seq

    @property
    def score(self) -> float:
        """Measured compute seconds saved per byte of cache spent."""
        return self.compute_s / max(1, len(self.frame))


class FrameCache:
    """Latency-saved-weighted cache of pre-encoded response frames.

    The schedd daemon keeps warm, non-degraded responses as encoded
    frames so a repeat request is one ``sendall``.  FIFO eviction (the
    PR-7 policy) treats a 2-second autotune the same as a 2-millisecond
    plan; this cache instead scores every entry by the **measured
    compute seconds a warm hit saves per byte of cache spent**
    (``compute_s / len(frame)``, from the flight timings the daemon
    already collects) and always evicts the lowest score first —
    including the newcomer, so a cheap-to-recompute frame never
    displaces an expensive one.

    Retention is provably no worse than FIFO: every eviction discards
    the minimum-score element of a full cache, so any key FIFO would
    still hold was only dropped here in favour of keys scoring at least
    as high (``tests/test_framecache.py`` replays random traces against
    a FIFO baseline to pin this down).

    ``stats`` is a :class:`CacheStats`: ``hits``/``misses`` per lookup,
    ``evicted`` per cap-driven drop (newcomer rejections included),
    ``bytes`` the currently retained total, and ``latency_saved_s`` the
    cumulative compute seconds that hits avoided.  Not thread-safe —
    the daemon serializes access under its own lock.
    """

    def __init__(self, cap_entries: int = 256, cap_bytes: int = 32 << 20,
                 stats: Optional[CacheStats] = None):
        self.cap_entries = cap_entries
        self.cap_bytes = cap_bytes
        self.stats = stats if stats is not None else CacheStats()
        self._entries: Dict[Any, _Frame] = {}
        self._seq = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Any) -> bool:
        return key in self._entries

    def get(self, key: Any) -> Optional[bytes]:
        e = self._entries.get(key)
        if e is None:
            self.stats.misses += 1
            return None
        e.hits += 1
        self.stats.hits += 1
        self.stats.latency_saved_s += e.compute_s
        return e.frame

    def put(self, key: Any, frame: bytes, compute_s: float) -> bool:
        """Admit ``frame`` (``compute_s`` = measured seconds the
        computation took).  Returns True when the key is retained after
        cap enforcement — a newcomer scoring below everything already
        cached is dropped immediately (and counted as evicted)."""
        old = self._entries.pop(key, None)
        if old is not None:
            self.stats.bytes -= len(old.frame)
        e = _Frame(frame, max(0.0, float(compute_s)), self._seq)
        self._seq += 1
        if old is not None:
            e.hits = old.hits
        self._entries[key] = e
        self.stats.bytes += len(frame)
        self._enforce_caps()
        return key in self._entries

    def _enforce_caps(self) -> None:
        while self._entries and (len(self._entries) > self.cap_entries
                                 or self.stats.bytes > self.cap_bytes):
            victim = min(self._entries,
                         key=lambda k: (self._entries[k].score,
                                        self._entries[k].seq))
            dropped = self._entries.pop(victim)
            self.stats.bytes -= len(dropped.frame)
            self.stats.evicted += 1

    def retained_latency_s(self) -> float:
        """Total measured compute seconds the retained set would save if
        every entry were hit once — the quantity the eviction policy
        maximizes (per byte), and what the property test compares
        against a FIFO baseline."""
        return sum(e.compute_s for e in self._entries.values())

    def clear(self) -> None:
        self._entries.clear()
        self.stats.bytes = 0

    def snapshot(self) -> Dict[str, Any]:
        """Introspection row for daemon stats: caps, occupancy and the
        score range of the retained set."""
        scores = sorted(e.score for e in self._entries.values())
        return {
            "entries": len(self._entries),
            "cap_entries": self.cap_entries,
            "bytes": self.stats.bytes,
            "cap_bytes": self.cap_bytes,
            "retained_latency_s": round(self.retained_latency_s(), 6),
            "min_score": scores[0] if scores else None,
            "max_score": scores[-1] if scores else None,
            "stats": self.stats.as_dict(),
        }


_GLOBAL: Optional[ScheduleCache] = None


def global_cache() -> ScheduleCache:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = ScheduleCache()
    return _GLOBAL


def cached_schedule_scop(scop: Scop, config: Optional[SchedulerConfig] = None,
                         engine: str = "lex",
                         cache: Optional[ScheduleCache] = None,
                         with_tree: bool = False, deadline=None, **kwargs):
    """Drop-in cached variant of :func:`repro.core.scheduler.schedule_scop`.

    Uncacheable configs (strategy callbacks) schedule normally.  The
    returned Schedule is shared between callers of the same key — treat
    it as immutable.  Deliberately no ``deps`` pass-through: a cached
    Schedule embeds its Dependence objects (codegen reads their
    ``satisfied_at``), so sharing a caller's dependence list across
    entries would let a later scheduling run mutate earlier cache hits.

    ``with_tree=True`` (the AKG kernel-plan hot path) builds the
    schedule tree (:func:`repro.core.schedtree.schedule_tree`) before
    publishing, so the cache payload carries the FM bounds too — a warm
    process skips both the scheduler *and* the bound computation.  The
    cache key includes the tree format version, so construction changes
    invalidate tree-carrying entries.

    ``deadline`` (a :class:`repro.core.resilience.Deadline`) is
    forwarded to the scheduler but deliberately excluded from the cache
    key: a deadline that never fires doesn't change the schedule, and
    one that fires raises before anything is published — a deadline-
    truncated run can never poison the pool.  Degraded schedules (the
    resilience ladder's rungs 1–3) are likewise never published here.
    """
    from .scheduler import schedule_scop

    config = config or SchedulerConfig()
    cache = cache or global_cache()
    key = schedule_key(scop, config, engine, extra=kwargs)
    hit = cache.get(key)
    if hit is not None:
        if with_tree and getattr(hit, "_tree", None) is None:
            try:
                from .schedtree import schedule_tree
                schedule_tree(hit)          # attach + persist for next time
                cache.put(key, hit)
            except Exception:
                pass
        return hit
    sched = schedule_scop(scop, config, engine=engine, deadline=deadline,
                          **kwargs)
    if with_tree:
        try:
            from .schedtree import schedule_tree
            schedule_tree(sched)
        except Exception:
            pass                            # tree is an optimization only
    if not getattr(sched, "degraded", False):
        cache.put(key, sched)
    return sched


# ---------------------------------------------------------------------------
# autotuner persistence: (SCoP structure, search-space version) → winning
# kernel-specific configuration.  Reuses the same two-tier cache pool —
# entries are plain dicts, distinguished from Schedule pickles by key
# namespace.
# ---------------------------------------------------------------------------

def autotune_key(scop: Scop, space: Dict[str, Any]) -> str:
    """Digest for a tuned-config cache entry: the SCoP structure plus the
    autotuner's search-space descriptor (its version, cache-model spec
    and measurement settings — anything that can change the winner)."""
    payload = json.dumps(
        {"v": CACHE_VERSION, "kind": "autotune",
         "scop": scop_fingerprint(scop),
         "space": dict(sorted(space.items()))},
        sort_keys=True, separators=(",", ":"),
    )
    return hashlib.sha256(payload.encode()).hexdigest()


def schedule_fingerprint(sched) -> str:
    """Structural digest of a *computed* schedule (rows, band structure,
    parallelism, fallback) — two configurations whose schedules hash
    equal generate identical code for identical tile choices.  The
    autotuner uses this to deduplicate enumerated configurations: on a
    single-SCC kernel ``max``/``no``/``smart`` fusion all collapse to
    one candidate instead of three."""
    rows = {}
    for idx, rr in sorted(sched.rows.items()):
        rows[str(idx)] = [
            [r.kind, sorted(("|".join(map(str, k)), str(v))
                            for k, v in r.coeffs.items() if v)]
            for r in rr
        ]
    payload = json.dumps(
        {"rows": rows, "bands": list(sched.bands),
         "parallel": list(sched.parallel), "fallback": bool(sched.fallback),
         # codegen-visible annotations beyond the rows: vectorized
         # iterators and explicit sequential marks
         "vec": sorted((str(k), int(v)) for k, v in sched.vector_iter.items()),
         "seq": sorted(map(list, sched.seq_marked))},
        sort_keys=True, separators=(",", ":"),
    )
    return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# measurement pool: every autotuner *measurement* is persisted as a
# (kernel, config, features, seconds) triple in an append-only JSONL
# file next to the pickle pool.  The learned static ranker
# (:mod:`repro.core.ranker`) trains on these rows; like the rest of the
# cache, disk failures degrade silently to "no training data".
# ---------------------------------------------------------------------------

MEASUREMENTS_FILE = "measurements.jsonl"

#: size-triggered compaction threshold for the measurement pool — the
#: file is bounded at roughly this size plus one writer's batch
MEASUREMENTS_MAX_BYTES = 4 << 20


def _measurement_fingerprint(row) -> Optional[tuple]:
    """What makes two measurement rows 'the same point': one kernel ×
    candidate config under one search-space and feature version.
    Compaction keeps the newest row per fingerprint — a re-measurement
    supersedes its predecessor (machine state drifts; the ranker wants
    the current truth)."""
    try:
        return (str(row["kernel"]), str(row["label"]),
                row.get("v"), row.get("fv"))
    except (KeyError, TypeError):
        return None


@contextlib.contextmanager
def _pool_lock(cache_dir: str):
    """Advisory exclusive lock for the measurement pool, taken on a
    *sidecar* file (``measurements.jsonl.lock``) that is never
    replaced.  Locking the data file itself is unsound once compaction
    publishes via ``os.replace``: a waiter that finally acquires the
    flock holds the orphaned pre-replace inode, and anything it does
    there (append, rewrite) is silently lost or clobbers fresh
    appends.  The sidecar's inode is stable for the pool's lifetime,
    so one lock serializes appenders and compactors with no
    identity-re-check/retry dance.  Degrades to unlocked on platforms
    without ``fcntl`` (single ``write`` on O_APPEND still keeps
    individual batches atomic)."""
    f = open(os.path.join(cache_dir, MEASUREMENTS_FILE + ".lock"), "a")
    try:
        if fcntl is not None:
            try:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            except OSError:
                pass
        yield
    finally:
        f.close()                     # closing drops the flock


def compact_measurements(cache: ScheduleCache,
                         max_bytes: int = MEASUREMENTS_MAX_BYTES,
                         force: bool = False) -> bool:
    """Rewrite the pool keeping the newest row per fingerprint.

    No-op unless the file exceeds ``max_bytes`` (or ``force``).  The
    rewrite holds the pool's sidecar lock (see :func:`_pool_lock`),
    writes a temp file in the same directory, and publishes with
    ``os.replace`` — readers see the old file or the new one, never a
    partial state, and concurrent appenders (who take the same lock)
    land either before the rewrite (and are carried into it) or after
    it (into the fresh file); no append is ever stranded in the
    orphaned pre-compaction inode.  Rows whose fingerprint cannot be
    computed (foreign/corrupt) are preserved in order rather than
    dropped.  Returns True when a rewrite was published; disk trouble
    returns False and leaves the pool untouched."""
    if not cache.disk:
        return False
    path = os.path.join(cache.dir, MEASUREMENTS_FILE)
    try:
        fault_point("cache.write")
        with _pool_lock(cache.dir):
            with open(path, "a+") as f:
                size = os.fstat(f.fileno()).st_size
                if size <= max_bytes and not force:
                    return False
                f.seek(0)
                keep: Dict[Any, str] = {}
                extras = []           # unfingerprintable rows, in order
                for ln in f:
                    ln = ln.strip()
                    if not ln:
                        continue
                    try:
                        row = json.loads(ln)
                    except json.JSONDecodeError:
                        continue      # torn tail line from a dying writer
                    fp = _measurement_fingerprint(row)
                    if fp is None:
                        extras.append(ln)
                        continue
                    # del+reinsert keeps dict order = last-occurrence
                    # order, so the compacted file preserves the pool's
                    # recency ordering (load_measurements' tail window
                    # still sees the newest rows last)
                    keep.pop(fp, None)
                    keep[fp] = ln
            fd, tmp = tempfile.mkstemp(dir=cache.dir,
                                       prefix=".measurements-",
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as out:
                    for ln in extras:
                        out.write(ln + "\n")
                    for ln in keep.values():
                        out.write(ln + "\n")
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        return True
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception:
        return False


def record_measurements(cache: ScheduleCache, rows, *,
                        max_bytes: int = MEASUREMENTS_MAX_BYTES) -> None:
    """Append measurement triples (plain dicts) to the cache's pool.

    Safe under concurrent writers: batches append under the pool's
    sidecar lock (see :func:`_pool_lock`), which also serializes them
    against compaction's ``os.replace`` — a batch always lands in the
    live file, never the orphaned pre-compaction inode.  One ``write``
    call per batch on an O_APPEND descriptor additionally keeps lines
    atomic on POSIX even where ``flock`` is unavailable.  When the
    appended pool crosses ``max_bytes``, :func:`compact_measurements`
    bounds it (newest row per fingerprint).  Disk failures degrade to
    "rows not recorded" — the search result is unaffected."""
    if not rows or not cache.disk:
        return
    try:
        fault_point("cache.write")
        os.makedirs(cache.dir, exist_ok=True)
        blob = "".join(json.dumps(r, sort_keys=True) + "\n" for r in rows)
        path = os.path.join(cache.dir, MEASUREMENTS_FILE)
        with _pool_lock(cache.dir):
            with open(path, "a") as f:
                f.write(blob)
                f.flush()
                size = os.fstat(f.fileno()).st_size
        if size > max_bytes:
            compact_measurements(cache, max_bytes=max_bytes)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception:
        pass


def load_measurements(cache: ScheduleCache, space_version: Optional[int] = None,
                      limit: int = 20000,
                      tail_bytes: int = 8 << 20) -> list:
    """Recent persisted measurement rows (most recent ``limit``),
    optionally filtered to one search-space version.  The pool is
    append-only and sits on the compile hot path, so only the last
    ``tail_bytes`` of the file are read and parsed — an unboundedly
    grown pool costs a bounded seek+read, not an O(file) parse.
    Returns [] on any disk trouble."""
    if not cache.disk:
        return []
    out = []
    try:
        fault_point("cache.read")
        with open(os.path.join(cache.dir, MEASUREMENTS_FILE), "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            start = max(0, size - tail_bytes)
            f.seek(start)
            blob = f.read().decode("utf-8", errors="replace")
        lines = blob.splitlines()
        if start > 0 and lines:
            lines = lines[1:]         # drop the partial first line
        for ln in lines:
            ln = ln.strip()
            if not ln:
                continue
            try:
                row = json.loads(ln)
            except json.JSONDecodeError:
                continue              # torn tail line from a dying writer
            if space_version is not None and row.get("v") != space_version:
                continue
            out.append(row)
    except Exception:
        return []
    return out[-limit:]
