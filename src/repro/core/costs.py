"""Predefined cost functions (paper §III-A1).

Each cost function contributes (a) optional ILP variables+constraints,
(b) one or more lexicographic objective *stages*. The textual order in
the configuration gives the stage priority, exactly as in the paper
("the order of the variables is important because they are minimized in
lexicographic order").

Predefined: ``proximity`` (Pluto, Eq. 4), ``feautrier`` (maximize
strongly-satisfied deps), ``contiguity`` (Tensor-scheduler-inspired,
Eq. 5), ``bigLoopsFirst`` (largest-extent loops outermost).
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Sequence

from .affine import Affine
from .deps import Dependence
from .farkas import add_farkas_nonneg, project_farkas
from .ilp import ILPProblem
from .scop import Scop, Statement


def cached_farkas(prob: ILPProblem, cache, key: str, dep: Dependence,
                  build, prefix: str) -> None:
    """Add dep's Farkas-linearized constraint to ``prob``, memoized in
    ``cache`` (dict or None).  ``build() -> (coef_of_z, const_term)`` is
    only called on a miss.

    The cached value is the *projected* row set (multipliers exactly
    eliminated, see ``farkas.project_farkas``): dimension-independent,
    so dimension k+1 replays the rows computed at dimension k, and no
    multiplier variables ever reach the solver.  ``prefix`` is retained
    for interface stability only.  (An earlier revision evaluated naive
    Fourier–Motzkin here and rejected it — without Imbert's acceleration
    it densified the system and slowed HiGHS by an order of magnitude;
    the accelerated exact projection is what made the rational simplex
    backend competitive.)"""
    if cache is not None:
        ck = (key, dep.id)
        rows = cache.get(ck)
        if rows is None:
            coef, const = build()
            rows = cache[ck] = project_farkas(dep.cons, coef, const)
        for expr, kind in rows:
            prob.add(dict(expr), kind)
        return
    coef, const = build()
    add_farkas_nonneg(prob, dep.cons, coef, const)


def t_it(s: Statement, k: int) -> str:
    return f"T{s.index}_it_{k}"


def t_par(s: Statement, p: str) -> str:
    return f"T{s.index}_par_{p}"


def t_cst(s: Statement) -> str:
    return f"T{s.index}_cst"


def phi_coef_map(dep: Dependence, params: Sequence[str], negate: bool = False):
    """coef_of_z and const for φ_R(t) − φ_S(s), as affine exprs over the
    schedule-coefficient ILP variables. negate=True gives φ_S − φ_R."""
    sgn = Fraction(-1 if negate else 1)
    coef: Dict[str, Affine] = {}
    for k in range(dep.target.dim):
        coef[f"t{k}"] = {t_it(dep.target, k): sgn}
    for k in range(dep.source.dim):
        cur = coef.get(f"s{k}", {})
        cur[t_it(dep.source, k)] = cur.get(t_it(dep.source, k), Fraction(0)) - sgn
        coef[f"s{k}"] = cur
    for p in params:
        coef[p] = _merge({t_par(dep.target, p): sgn}, {t_par(dep.source, p): -sgn})
    const = _merge({t_cst(dep.target): sgn}, {t_cst(dep.source): -sgn})
    return coef, const


def _merge(a: Affine, b: Affine) -> Affine:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, Fraction(0)) + v
        if out[k] == 0:
            del out[k]
    return out


# ---------------------------------------------------------------------------
# proximity (Pluto bounding function): u·N + w − (φ_R − φ_S) ≥ 0
# ---------------------------------------------------------------------------

def setup_proximity(prob: ILPProblem, deps: Sequence[Dependence], params, dim: int,
                    cache=None):
    u_vars = [prob.ensure_var(f"u_{p}", lb=0, ub=None, integer=True) for p in params]
    w = prob.ensure_var("w", lb=0, ub=None, integer=True)
    for dep in deps:
        def build(dep=dep):
            coef, const = phi_coef_map(dep, params, negate=True)  # −(φ_R − φ_S)
            for p in params:
                coef[p] = _merge(coef.get(p, {}), {f"u_{p}": Fraction(1)})
            return coef, _merge(const, {w: Fraction(1)})
        cached_farkas(prob, cache, "proximity", dep, build, f"lp{dep.id}")
    stages: List[Affine] = []
    if u_vars:
        stages.append({u: Fraction(1) for u in u_vars})
    stages.append({w: Fraction(1)})
    return stages


# ---------------------------------------------------------------------------
# feautrier: maximize the number of strongly satisfied dependences
# ---------------------------------------------------------------------------

def setup_feautrier(prob: ILPProblem, deps: Sequence[Dependence], params, dim: int,
                    cache=None):
    es = []
    for dep in deps:
        e = prob.ensure_var(f"e_{dep.id}", lb=0, ub=1, integer=True)
        es.append(e)

        def build(dep=dep, e=e):
            coef, const = phi_coef_map(dep, params)
            return coef, _merge(const, {e: Fraction(-1)})   # φ_R − φ_S − e ≥ 0
        cached_farkas(prob, cache, "feautrier", dep, build, f"lf{dep.id}")
    if not es:
        return []
    return [{e: Fraction(-1) for e in es}]  # minimize −Σe = maximize satisfied


# ---------------------------------------------------------------------------
# contiguity (Eq. 5) and bigLoopsFirst
# ---------------------------------------------------------------------------

def contiguity_coeffs(stmt: Statement) -> List[int]:
    """Support coefficients c_{S,i}: contiguous (stride-1, last-subscript)
    iterators get the LARGEST c so they end up innermost (paper Listing 1
    example: accesses a[j][i] give c = (10, 1) over (i, j))."""
    d = stmt.dim
    score = [0] * d
    for k, it in enumerate(stmt.iters):
        for acc in stmt.accesses:
            if not acc.subscripts:
                continue
            last = acc.subscripts[-1]
            outer = acc.subscripts[:-1]
            c = last.get(it, Fraction(0))
            if c != 0 and abs(c) == 1 and not any(o.get(it) for o in outer):
                score[k] += 2
            elif c != 0:
                score[k] += 1
    order = sorted(range(d), key=lambda k: (score[k], k))
    c = [0] * d
    for rank_pos, k in enumerate(order):
        c[k] = 10 ** rank_pos
    return c


def bigloops_coeffs(stmt: Statement, scop: Scop) -> List[int]:
    """c_{S,i} prioritizing the largest iteration ranges (paper: BLF)."""

    env = {p: Fraction(v) for p, v in scop.params.items()}
    extents = []
    for k, it in enumerate(stmt.iters):
        lo = hi = None
        for expr, kind in stmt.domain:
            c = expr.get(it, Fraction(0))
            if c == 0 or kind != ">=0":
                continue
            # evaluate other iterators at 0 for a cheap extent estimate
            val = expr.get(1, Fraction(0))
            for kk, vv in expr.items():
                if kk in env:
                    val += vv * env[kk]
            bound = -val / c
            if c > 0:
                lo = bound if lo is None else max(lo, bound)
            else:
                hi = -bound if hi is None else min(hi, -bound)
        if lo is None or hi is None:
            extents.append(Fraction(10 ** 6))
        else:
            extents.append(hi - lo + 1)
    order = sorted(range(stmt.dim), key=lambda k: (-extents[k], k))
    c = [0] * stmt.dim
    for rank_pos, k in enumerate(order):
        c[k] = 10 ** rank_pos
    return c


def stage_from_coeffs(stmts: Sequence[Statement], coeffs: Dict[int, List[int]],
                      incomplete: Sequence[int]) -> Affine:
    obj: Affine = {}
    for s in stmts:
        if s.index not in incomplete:
            continue
        for k in range(s.dim):
            c = coeffs[s.index][k]
            if c:
                obj[t_it(s, k)] = obj.get(t_it(s, k), Fraction(0)) + Fraction(c)
    return obj


# ---------------------------------------------------------------------------
# per-dimension cost-function mixes (paper §III-E): named recipes the
# autotuner composes into kernel-specific configurations.  Each mix maps
# a scheduling dimension (or 'default') to (cost_functions, require_parallel)
# — the raw material for a DimConfig.  All mixes are static (no Python
# callback), so mixed configurations stay cacheable.
# ---------------------------------------------------------------------------

COST_MIXES: Dict[str, Dict[object, tuple]] = {
    # stride ordering: contiguity before proximity on every dim (the
    # tensor-style costs without its no-skewing constraint)
    "cp": {"default": (("contiguity", "proximity"), False)},
    # stride ordering reversed: proximity first, contiguity tie-break
    "pc": {"default": (("proximity", "contiguity"), False)},
    # contiguity steers only the outer two scheduling dims (one of which
    # is typically a scalar distribution dim), plain proximity below
    "c01": {0: (("contiguity", "proximity"), False),
            1: (("contiguity", "proximity"), False),
            "default": (("proximity",), False)},
    # largest-extent loops outermost, plain proximity below
    "blf0": {0: (("bigLoopsFirst", "proximity"), False),
             1: (("bigLoopsFirst", "proximity"), False),
             "default": (("proximity",), False)},
    # parallelism-demanding outer dims: static isl-style coincidence
    # (require_parallel with the scheduler's feautrier fallback), but
    # cacheable because there is no dynamic callback
    "par0": {0: (("proximity",), True),
             1: (("proximity",), True),
             "default": (("proximity",), False)},
}
