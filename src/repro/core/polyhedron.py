"""Polyhedron helpers: feasibility, affine optimization, Fourier–Motzkin.

A polyhedron is a list of (Affine, kind) constraints over named
variables, kind in {'>=0', '==0'}. Variables not mentioned in ``free``
are unbounded rationals. Feasibility and optimization go through the LP
layer (rational relaxation — conservative for dependence analysis, see
DESIGN.md §4).
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .affine import Affine, affine_scale, affine_sub
from .ilp import ILPProblem, Unbounded

Constraint = Tuple[Affine, str]


def _vars_of(cons: Sequence[Constraint]) -> List[str]:
    seen: List[str] = []
    for expr, _ in cons:
        for k in expr:
            if k != 1 and k not in seen:
                seen.append(k)
    return seen


def _build_lp(cons: Sequence[Constraint], extra_vars: Iterable[str] = ()) -> ILPProblem:
    p = ILPProblem()
    for v in list(_vars_of(cons)) + list(extra_vars):
        p.ensure_var(v, lb=None, integer=False)
    for expr, kind in cons:
        p.add(expr, kind)
    return p


def feasible(cons: Sequence[Constraint]) -> bool:
    """Rational feasibility (conservative over integer feasibility)."""
    return _build_lp(cons).feasible()


def minimum(cons: Sequence[Constraint], obj: Affine) -> Optional[Fraction]:
    """Rational min of obj over the polyhedron.

    Returns None if empty, -inf (float) if unbounded below.
    """
    p = _build_lp(cons, [k for k in obj if k != 1])
    try:
        r = p.solve_min({k: v for k, v in obj.items()})
    except Unbounded:
        return Fraction(-(10 ** 18))  # sentinel: unbounded below
    if r is None:
        return None
    return r[0]


def maximum(cons: Sequence[Constraint], obj: Affine) -> Optional[Fraction]:
    m = minimum(cons, {k: -v for k, v in obj.items()})
    if m is None:
        return None
    return -m


# ---------------------------------------------------------------------------
# Fourier–Motzkin elimination (used by codegen to derive loop bounds)
# ---------------------------------------------------------------------------

def fm_eliminate(cons: Sequence[Constraint], var: str) -> List[Constraint]:
    """Eliminate ``var`` from the system by Fourier–Motzkin.

    Equalities involving var are used as substitutions first.
    The result is the projection (rational); redundant rows are pruned
    cheaply (exact duplicates + trivially-true rows).
    """
    cons = [(dict(e), k) for e, k in cons]
    # substitution via an equality if available
    for i, (expr, kind) in enumerate(cons):
        if kind == "==0" and expr.get(var):
            c = expr[var]
            # var = -(expr - c*var)/c
            rest = {k: v for k, v in expr.items() if k != var}
            sub = affine_scale(rest, Fraction(-1) / c)
            out: List[Constraint] = []
            for j, (e2, k2) in enumerate(cons):
                if j == i:
                    continue
                if e2.get(var):
                    coef = e2[var]
                    e3 = {k: v for k, v in e2.items() if k != var}
                    for k3, v3 in sub.items():
                        e3[k3] = e3.get(k3, Fraction(0)) + coef * v3
                    e3 = {k: v for k, v in e3.items() if v != 0}
                    out.append((e3, k2))
                else:
                    out.append((e2, k2))
            return _prune(out)
    lowers, uppers, rest = [], [], []
    for expr, kind in cons:
        c = expr.get(var, Fraction(0))
        if kind == "==0" or c == 0:
            if c == 0:
                rest.append((expr, kind))
            continue
        if c > 0:
            lowers.append((expr, c))   # c*var + rest >= 0  →  var >= -rest/c
        else:
            uppers.append((expr, c))   # c*var + rest >= 0  →  var <= rest/(-c)
    out = list(rest)
    for le, lc in lowers:
        for ue, uc in uppers:
            # combine: (-uc)*le + lc*ue  eliminates var
            comb: Affine = {}
            for k, v in le.items():
                comb[k] = comb.get(k, Fraction(0)) + (-uc) * v
            for k, v in ue.items():
                comb[k] = comb.get(k, Fraction(0)) + lc * v
            comb.pop(var, None)
            comb = {k: v for k, v in comb.items() if v != 0}
            out.append((comb, ">=0"))
    return _prune(out)


def _prune(cons: List[Constraint]) -> List[Constraint]:
    out: List[Constraint] = []
    seen = set()
    for expr, kind in cons:
        expr = {k: v for k, v in expr.items() if v != 0}
        nonconst = {k: v for k, v in expr.items() if k != 1}
        if not nonconst:
            c = expr.get(1, Fraction(0))
            if (kind == ">=0" and c >= 0) or (kind == "==0" and c == 0):
                continue  # trivially true
            # trivially false → keep to signal emptiness
            out.append((expr, kind))
            continue
        key = (kind, tuple(sorted(((str(k), v) for k, v in expr.items()))))
        if key in seen:
            continue
        seen.add(key)
        out.append((expr, kind))
    return out


def bounds_of(cons: Sequence[Constraint], var: str, inner: Sequence[str]):
    """Return (lower_exprs, upper_exprs) for var after eliminating the
    ``inner`` variables. Bounds are affine in the remaining variables:
    lower:  var >= ceil(expr) ;  upper:  var <= floor(expr)
    Each returned as (affine_over_outer, denominator) with
    var >= expr/denom (lower) etc.
    """
    sys = list(cons)
    for v in inner:
        sys = fm_eliminate(sys, v)
    lowers, uppers = [], []
    for expr, kind in sys:
        c = expr.get(var, Fraction(0))
        kinds = [kind] if kind == ">=0" else [">=0", "<=0"]
        for kk in kinds:
            e = expr if kk == ">=0" else {k: -v for k, v in expr.items()}
            cc = e.get(var, Fraction(0))
            if cc == 0:
                continue
            rest = {k: -v / cc for k, v in e.items() if k != var}
            if cc > 0:
                lowers.append(rest)   # var >= rest
            else:
                uppers.append(rest)   # var <= rest
    return lowers, uppers
