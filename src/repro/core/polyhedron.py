"""Polyhedron helpers: feasibility, affine optimization, Fourier–Motzkin.

A polyhedron is a list of (Affine, kind) constraints over named
variables, kind in {'>=0', '==0'}. Variables not mentioned in ``free``
are unbounded rationals. Feasibility and optimization go through the LP
layer (rational relaxation — conservative for dependence analysis, see
DESIGN.md §4).
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .affine import Affine, affine_scale
from .ilp import ILPProblem, Unbounded
from .resilience import fault_point

Constraint = Tuple[Affine, str]


def _vars_of(cons: Sequence[Constraint]) -> List[str]:
    seen: List[str] = []
    for expr, _ in cons:
        for k in expr:
            if k != 1 and k not in seen:
                seen.append(k)
    return seen


def _build_lp(cons: Sequence[Constraint], extra_vars: Iterable[str] = ()) -> ILPProblem:
    # deliberately pinned to the float HiGHS engine: polyhedron queries
    # (dependence distances, satisfaction probes, redundancy pruning in
    # prune_redundant) only consume optimal *values* on rational
    # relaxations, where HiGHS is cheap and a tie between alternate
    # optimal vertices cannot change a schedule.  The exact ``lex``
    # engine is reserved for the scheduler's lexmin, where the vertex
    # itself is the answer.
    p = ILPProblem(engine="highs")
    for v in list(_vars_of(cons)) + list(extra_vars):
        p.ensure_var(v, lb=None, integer=False)
    for expr, kind in cons:
        p.add(expr, kind)
    return p


def feasible(cons: Sequence[Constraint]) -> bool:
    """Rational feasibility (conservative over integer feasibility)."""
    return _build_lp(cons).feasible()


def minimum(cons: Sequence[Constraint], obj: Affine) -> Optional[Fraction]:
    """Rational min of obj over the polyhedron.

    Returns None if empty, -inf (float) if unbounded below.
    """
    p = _build_lp(cons, [k for k in obj if k != 1])
    try:
        r = p.solve_min({k: v for k, v in obj.items()})
    except Unbounded:
        return Fraction(-(10 ** 18))  # sentinel: unbounded below
    if r is None:
        return None
    return r[0]


def maximum(cons: Sequence[Constraint], obj: Affine) -> Optional[Fraction]:
    m = minimum(cons, {k: -v for k, v in obj.items()})
    if m is None:
        return None
    return -m


class CompiledPolyhedron:
    """Reusable LP over a *fixed* constraint system.

    The scheduler optimizes many affine forms (per-row dependence
    distances, satisfaction probes) over the same dependence polyhedron
    at every scheduling dimension.  Building the LP once and swapping
    only the objective/extra rows amortizes the Fraction→float
    compilation across the whole run; results are identical to the
    module-level :func:`minimum`/:func:`maximum`/:func:`feasible`.
    """

    def __init__(self, cons: Sequence[Constraint], extra_vars: Iterable[str] = ()):
        self.prob = _build_lp(cons, extra_vars)
        self.prob._compile()
        self._subst = self._hull_substitution(cons)
        self._memo: Dict[tuple, Optional[Fraction]] = {}

    @staticmethod
    def _hull_substitution(cons: Sequence[Constraint]):
        """Pivot-variable substitution map from the rref of the equality
        rows (the polyhedron's affine hull): pivot var -> affine expr over
        the free variables.  Used to reduce objectives before solving —
        roughly half the scheduler's distance queries become constants
        (e.g. schedule rows equal on both dependence endpoints) and need
        no LP at all."""
        from .linalg_q import rref

        eqs = [e for e, k in cons if k == "==0"]
        if not eqs:
            return {}
        vars_ = sorted({v for e in eqs for v in e if v != 1})
        m = [[Fraction(e.get(v, 0)) for v in vars_] + [Fraction(e.get(1, 0))]
             for e in eqs]
        r, pivots = rref(m)
        subst: Dict[str, Affine] = {}
        for i, pc in enumerate(pivots):
            if pc >= len(vars_):
                continue   # pivot on the constant column: inconsistent row
            # row: x_pc + Σ_j r_ij x_j + r_ib == 0  →  x_pc = −Σ r_ij x_j − r_ib
            expr: Affine = {}
            for j, v in enumerate(vars_):
                if j != pc and r[i][j]:
                    expr[v] = -r[i][j]
            if r[i][len(vars_)]:
                expr[1] = -r[i][len(vars_)]
            subst[vars_[pc]] = expr
        return subst

    def reduce(self, obj: Affine) -> Affine:
        """Substitute the affine hull into ``obj`` (equal pointwise on the
        polyhedron)."""
        if not self._subst:
            return obj
        red: Affine = {}
        for k, c in obj.items():
            if k != 1 and k in self._subst:
                for k2, c2 in self._subst[k].items():
                    red[k2] = red.get(k2, Fraction(0)) + c * c2
            else:
                red[k] = red.get(k, Fraction(0)) + c
        return {k: v for k, v in red.items() if v != 0}

    def _ensure(self, obj: Affine) -> None:
        for k in obj:
            if k != 1:
                self.prob.ensure_var(k, lb=None, integer=False)

    def minimum(self, obj: Affine) -> Optional[Fraction]:
        """Exact rational min of obj; assumes the polyhedron is non-empty
        (dependence polyhedra are feasible by construction)."""
        red = self.reduce(obj)
        if not any(k != 1 for k in red):
            return red.get(1, Fraction(0))   # constant on the hull
        key = tuple(sorted((str(k), v) for k, v in red.items()))
        if key in self._memo:
            return self._memo[key]
        self._ensure(red)
        try:
            r = self.prob.solve_min(dict(red), want=())
        except Unbounded:
            self._memo[key] = out = Fraction(-(10 ** 18))  # unbounded below
            return out
        out = None if r is None else r[0]
        self._memo[key] = out
        return out

    def maximum(self, obj: Affine) -> Optional[Fraction]:
        m = self.minimum({k: -v for k, v in obj.items()})
        if m is None:
            return None
        return -m

    def feasible_with(self, extra: Sequence[Constraint] = ()) -> bool:
        """Feasibility of the base polyhedron ∩ ``extra`` rows; the extra
        rows are appended and rewound around a single solve."""
        mark = self.prob.push()
        try:
            for expr, kind in extra:
                for k in expr:
                    if k != 1:
                        self.prob.ensure_var(k, lb=None, integer=False)
                self.prob.add(expr, kind)
            return self.prob.feasible()
        finally:
            self.prob.pop(mark)


# ---------------------------------------------------------------------------
# Fourier–Motzkin elimination (used by codegen to derive loop bounds)
# ---------------------------------------------------------------------------

def fm_eliminate(cons: Sequence[Constraint], var: str) -> List[Constraint]:
    """Eliminate ``var`` from the system by Fourier–Motzkin.

    Equalities involving var are used as substitutions first.
    The result is the projection (rational); redundant rows are pruned
    cheaply (exact duplicates + trivially-true rows).
    """
    cons = [(dict(e), k) for e, k in cons]
    # substitution via an equality if available
    for i, (expr, kind) in enumerate(cons):
        if kind == "==0" and expr.get(var):
            c = expr[var]
            # var = -(expr - c*var)/c
            rest = {k: v for k, v in expr.items() if k != var}
            sub = affine_scale(rest, Fraction(-1) / c)
            out: List[Constraint] = []
            for j, (e2, k2) in enumerate(cons):
                if j == i:
                    continue
                if e2.get(var):
                    coef = e2[var]
                    e3 = {k: v for k, v in e2.items() if k != var}
                    for k3, v3 in sub.items():
                        e3[k3] = e3.get(k3, Fraction(0)) + coef * v3
                    e3 = {k: v for k, v in e3.items() if v != 0}
                    out.append((e3, k2))
                else:
                    out.append((e2, k2))
            return _prune(out)
    lowers, uppers, rest = [], [], []
    for expr, kind in cons:
        c = expr.get(var, Fraction(0))
        if kind == "==0" or c == 0:
            if c == 0:
                rest.append((expr, kind))
            continue
        if c > 0:
            lowers.append((expr, c))   # c*var + rest >= 0  →  var >= -rest/c
        else:
            uppers.append((expr, c))   # c*var + rest >= 0  →  var <= rest/(-c)
    out = list(rest)
    for le, lc in lowers:
        for ue, uc in uppers:
            # combine: (-uc)*le + lc*ue  eliminates var
            comb: Affine = {}
            for k, v in le.items():
                comb[k] = comb.get(k, Fraction(0)) + (-uc) * v
            for k, v in ue.items():
                comb[k] = comb.get(k, Fraction(0)) + lc * v
            comb.pop(var, None)
            comb = {k: v for k, v in comb.items() if v != 0}
            out.append((comb, ">=0"))
    return _prune(out)


def _normalize(expr: Affine, kind: str) -> Affine:
    """Scale a constraint row to a canonical form: integer coefficients
    with gcd 1 (and, for equalities, first nonzero coefficient positive).
    FM combinations produce scalar multiples of the same hyperplane
    constantly; normalization makes them hash-equal."""
    from math import gcd

    nonconst = sorted((k for k in expr if k != 1), key=str)
    if not nonconst:
        return dict(expr)
    den = 1
    for v in expr.values():
        den = den * v.denominator // gcd(den, v.denominator)
    num = 0
    for v in expr.values():
        num = gcd(num, abs(v.numerator * (den // v.denominator)))
    scale = Fraction(den, num or 1)
    if kind == "==0" and expr[nonconst[0]] < 0:
        scale = -scale
    return {k: v * scale for k, v in expr.items()}


def _prune(cons):
    """Cheap syntactic pruning: drop trivially-true rows, exact and
    scaled duplicates, and '>=0' rows dominated by a parallel row with a
    tighter constant (same normalized non-constant part: expr+c1 >= 0
    implies expr+c2 >= 0 whenever c2 >= c1).

    Rows may be ``(expr, kind)`` or ``(expr, kind, *extra)`` — extra
    fields (e.g. the ancestor sets of ``farkas``' accelerated FM) ride
    along unchanged, so every pruner in the repo shares this one
    implementation."""
    out: List[tuple] = []
    seen = set()
    best_const: Dict[tuple, int] = {}   # parallel-row key -> index in out
    for expr, kind, *extra in cons:
        expr = {k: v for k, v in expr.items() if v != 0}
        nonconst = {k: v for k, v in expr.items() if k != 1}
        if not nonconst:
            c = expr.get(1, Fraction(0))
            if (kind == ">=0" and c >= 0) or (kind == "==0" and c == 0):
                continue  # trivially true
            # trivially false → keep to signal emptiness
            out.append((expr, kind, *extra))
            continue
        expr = _normalize(expr, kind)
        key = (kind, tuple(sorted(((str(k), v) for k, v in expr.items()))))
        if key in seen:
            continue
        if kind == ">=0":
            pkey = tuple(sorted((str(k), v) for k, v in expr.items() if k != 1))
            prev = best_const.get(pkey)
            if prev is not None:
                if out[prev][0].get(1, Fraction(0)) <= expr.get(1, Fraction(0)):
                    continue          # an existing row is at least as tight
                out[prev] = (expr, kind, *extra)   # tighter: replace
                seen.add(key)
                continue
            best_const[pkey] = len(out)
        seen.add(key)
        out.append((expr, kind, *extra))
    return out


def prune_redundant(cons: Sequence[Constraint], context: Sequence[Constraint] = (),
                    max_lp_rows: int = 200) -> List[Constraint]:
    """LP-based redundancy elimination for '>=0' rows.

    A row r is removed when the remaining rows (plus ``context``, extra
    constraints known to hold — e.g. parameter bounds or concrete
    parameter values baked into the generated code) rationally imply it:
    min of r's expression over the rest is >= 0.  Removal is exact for
    integer scanning: any (integer) point satisfying the rest satisfies
    r.  This is what keeps Fourier–Motzkin projections — and the
    MINI/MAXI bound chains codegen emits from them — from blowing up on
    tiled/wavefronted nests.

    ``max_lp_rows`` bounds the work; beyond it the system is returned
    after syntactic pruning only.
    """
    rows = _prune(list(cons))
    ineq_idx = [i for i, (_, k) in enumerate(rows) if k == ">=0"]
    if len(ineq_idx) > max_lp_rows:
        return rows
    ctx = list(context)
    removed: Set[int] = set()
    # widest rows first: combination rows produced by FM have many terms
    # and are the likeliest to be redundant, and removing them first
    # shrinks later LP systems
    order = sorted(ineq_idx, key=lambda i: (-len(rows[i][0]),
                                            tuple(sorted(map(str, rows[i][0])))))
    for i in order:
        expr, _ = rows[i]
        rest = [rows[j] for j in range(len(rows)) if j != i and j not in removed]
        m = minimum(rest + ctx, expr)   # unbounded sentinel is negative
        if m is not None and m >= 0:
            removed.add(i)
    return [r for j, r in enumerate(rows) if j not in removed]


def bounds_of(cons: Sequence[Constraint], var: str, inner: Sequence[str],
              context: Sequence[Constraint] = (), lp_prune: int = 12):
    """Return (lower_exprs, upper_exprs) for var after eliminating the
    ``inner`` variables. Bounds are affine in the remaining variables:
    lower:  var >= ceil(expr) ;  upper:  var <= floor(expr)
    Each returned as (affine_over_outer, denominator) with
    var >= expr/denom (lower) etc.

    ``context`` rows (known-true at runtime: parameter bounds, concrete
    parameter values) feed LP redundancy pruning whenever an elimination
    leaves more than ``lp_prune`` rows — this is what keeps chained FM
    from exploding on tiled/wavefronted systems (``lp_prune=0``
    disables).
    """
    fault_point("fm.bounds")
    sys = list(cons)
    for v in inner:
        sys = fm_eliminate(sys, v)
        if lp_prune and len(sys) > lp_prune:
            sys = prune_redundant(sys, context)
    if lp_prune and len(sys) > lp_prune:
        sys = prune_redundant(sys, context)
    lowers, uppers = [], []
    for expr, kind in sys:
        kinds = [kind] if kind == ">=0" else [">=0", "<=0"]
        for kk in kinds:
            e = expr if kk == ">=0" else {k: -v for k, v in expr.items()}
            cc = e.get(var, Fraction(0))
            if cc == 0:
                continue
            rest = {k: -v / cc for k, v in e.items() if k != var}
            if cc > 0:
                lowers.append(rest)   # var >= rest
            else:
                uppers.append(rest)   # var <= rest
    return lowers, uppers
