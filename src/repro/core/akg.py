"""AKG bridge: tensor ops → SCoPs → PolyTOPS schedules → kernel plans.

This is how the paper's scheduler becomes a first-class feature of the
TPU framework (DESIGN.md §2): the loop order, band structure and
vectorized dimension chosen by PolyTOPS for an operator's SCoP are
translated into a :class:`KernelPlan` — grid-dimension order, BlockSpec
tile shapes and the lane-mapped innermost dim — consumed by the Pallas
kernels in ``repro.kernels``.

TPU adaptation: the vectorized iterator maps to the 128-lane VPU axis,
the next-inner to 8 sublanes; MXU-facing tiles snap to multiples of
(128, 128); tile sizes are chosen so the working set fits VMEM (~16 MiB
usable) — this replaces the paper's externally-provided NPU tile sizes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .config import SchedulerConfig, tensor_style
from .postproc import find_tilable_bands
from .schedcache import cached_schedule_scop
from .scheduler import Schedule, schedule_scop
from .scop import Scop

VMEM_BYTES = 16 * 2**20
LANE = 128
SUBLANE = 8


@dataclass(frozen=True)
class KernelPlan:
    """Loop-nest plan for a Pallas kernel."""
    loop_order: Tuple[str, ...]       # outer → inner iterator names
    vector_iter: Optional[str]        # lane-mapped innermost iterator
    tile: Dict[str, int]              # iterator -> tile size
    bands: Tuple[int, ...]            # band id per scheduled dim
    schedule_str: str = ""            # human-readable schedule (debug)


def _matmul_scop(m: int, n: int, k: int) -> Scop:
    s = Scop("pallas_matmul", params={"M": m, "N": n, "K": k})
    with s.loop("i", 0, "M"):
        with s.loop("j", 0, "N"):
            with s.loop("kk", 0, "K"):
                s.stmt("C[i,j] = C[i,j] + A[i,kk] * B[kk,j]")
    return s


def _order_from_schedule(sched: Schedule, stmt_idx: int = 0) -> List[str]:
    stmt = sched.scop.statements[stmt_idx]
    order = []
    for row in sched.rows[stmt.index]:
        if row.kind != "linear":
            continue
        itv = row.it_vector(stmt.dim)
        nz = [k for k, v in enumerate(itv) if v != 0]
        if len(nz) == 1 and stmt.iters[nz[0]] not in order:
            order.append(stmt.iters[nz[0]])
    for it in stmt.iters:     # safety: append anything unplaced
        if it not in order:
            order.append(it)
    return order


def _fit_tiles(order: List[str], dims: Dict[str, int], vector_iter: str,
               bytes_per_elem: int = 2, n_buffers: int = 3,
               stmt=None) -> Dict[str, int]:
    """Snap tiles to TPU-friendly sizes under a VMEM budget.

    The working set comes from the shared cache model
    (:func:`repro.core.cachemodel.stmt_access_groups`) when the SCoP
    statement is available: per-access tile footprints from the actual
    subscript strides, times ``n_buffers`` for double/triple buffering —
    the same estimator that sizes CPU cache tiles sizes VMEM tiles."""
    from .cachemodel import stmt_access_groups, working_set_bytes

    tile = {}
    for it in order:
        d = dims[it]
        if it == vector_iter:
            tile[it] = min(d, 512 if d % 512 == 0 else LANE * max(d // LANE, 1))
            tile[it] = max(min(tile[it], d), min(d, LANE))
        else:
            tile[it] = min(d, 128 if d >= 128 else d)
    groups = stmt_access_groups(stmt, order) if stmt is not None else None

    # shrink until the working set fits VMEM
    def wset():
        if groups is not None:
            sizes = [tile[i] for i in order]
            return n_buffers * working_set_bytes(groups, sizes, bytes_per_elem)
        t = [tile[i] for i in order]        # no access info: legacy guess
        prod2 = 1
        for a in t[-2:]:
            prod2 *= a
        return n_buffers * prod2 * bytes_per_elem * 4

    shrink_order = [it for it in order if it != vector_iter]
    while wset() > VMEM_BYTES and any(tile[i] > SUBLANE for i in shrink_order):
        for it in shrink_order:
            if tile[it] > SUBLANE:
                tile[it] //= 2
                break
    return tile


@functools.lru_cache(maxsize=64)
def plan_matmul(m: int, n: int, k: int,
                strategy: str = "tensor") -> KernelPlan:
    """PolyTOPS-planned matmul: tensor-style scheduling yields the
    cache/VMEM-friendly (i, k, j) order with j vectorized (lanes)."""
    scop = _matmul_scop(m, n, k)
    cfg = tensor_style()
    cfg.auto_vectorize = True
    # structural cache: repeat plans for the same (m, n, k) shape are a
    # lookup, persisted on disk across serving/benchmark processes
    sched = cached_schedule_scop(scop, cfg)
    order = _order_from_schedule(sched)
    vec = None
    stmt = scop.statements[0]
    vi = sched.vector_iter.get(0)
    if vi is not None:
        vec = stmt.iters[vi]
    else:
        vec = order[-1]
    tile = _fit_tiles(order, {"i": m, "kk": k, "j": n}, vec, stmt=stmt)
    bands = tuple(sched.bands)
    return KernelPlan(tuple(order), vec, tile, bands, sched.pretty())


@functools.lru_cache(maxsize=8)
def plan_attention(seq_q: int, seq_k: int, head_dim: int) -> KernelPlan:
    """Schedule the S = Q·Kᵀ core (q, k, d loops): contiguity puts d
    innermost (lanes) and yields the q-block × k-block band that the
    flash kernel tiles over."""
    s = Scop("attn_score", params={"Q": seq_q, "K": seq_k, "D": head_dim})
    with s.loop("q", 0, "Q"):
        with s.loop("kk", 0, "K"):
            with s.loop("d", 0, "D"):
                s.stmt("S[q,kk] = S[q,kk] + Qm[q,d] * Km[kk,d]")
    cfg = tensor_style()
    sched = cached_schedule_scop(s, cfg)
    order = _order_from_schedule(sched)
    tile = _fit_tiles(order, {"q": seq_q, "kk": seq_k, "d": head_dim}, "d",
                      stmt=s.statements[0])
    # flash blocking: q and k tiles bounded for the online-softmax state
    tile["q"] = min(tile.get("q", 128), 128)
    tile["kk"] = min(tile.get("kk", 128), 128)
    return KernelPlan(tuple(order), "d", tile, tuple(sched.bands),
                      sched.pretty())
