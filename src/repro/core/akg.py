"""AKG bridge: tensor ops → SCoPs → PolyTOPS schedule trees → kernel plans.

This is how the paper's scheduler becomes a first-class feature of the
TPU framework (DESIGN.md §2): the schedule tree produced by PolyTOPS for
an operator's SCoP (:mod:`repro.core.schedtree` — the same IR the numpy
and C emitters walk) is *lowered* into a :class:`KernelPlan` — grid
dimension order from the outer bands, the lane-mapped vector dim from
the ``vector`` mark (or the vectorize directive / innermost band),
BlockSpec tile shapes fitted to VMEM via the shared cache model —
consumed by the Pallas kernels in ``repro.kernels``.

:func:`lower_to_kernel_plan` is fully general: any scheduled SCoP's tree
maps to a plan.  ``plan_matmul`` / ``plan_attention`` /
``plan_mamba_scan`` are thin wrappers that build the operator SCoP,
schedule it (through the structural schedule cache, tree included in the
payload) and lower — plus at most a kernel-specific tile clamp (flash
attention's online-softmax state, the mamba VMEM-resident hidden state).

TPU adaptation: the vector iterator maps to the 128-lane VPU axis, the
next-inner to 8 sublanes; tiles snap to LANE/SUBLANE multiples; tile
sizes are chosen so the working set — from the statement's *real* access
groups (:func:`repro.core.cachemodel.stmt_access_groups`), times the
double/triple-buffering factor — fits VMEM (~16 MiB usable).  This
replaces the paper's externally-provided NPU tile sizes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from .config import tensor_style
from .resilience import provenance as _provenance, schedule_with_ladder
from .schedcache import global_cache
from .schedtree import ScheduleTree, schedule_tree, yvar
from .scop import Scop, Statement

VMEM_BYTES = 16 * 2**20
LANE = 128
SUBLANE = 8


@dataclass(frozen=True)
class KernelPlan:
    """Loop-nest plan for a Pallas kernel.

    ``degraded``/``fallback_level``/``degrade_reasons`` carry the
    degradation-ladder provenance of the schedule the plan was lowered
    from (see :mod:`repro.core.resilience`): a plan is still *correct*
    when degraded — every ladder rung is legal — but it may be lowered
    from a fallback schedule rather than the configured one, which a
    serving layer may want to log or re-plan later."""
    loop_order: Tuple[str, ...]       # outer → inner iterator names
    vector_iter: Optional[str]        # lane-mapped innermost iterator
    tile: Dict[str, int]              # iterator -> tile size
    bands: Tuple[int, ...]            # band id per scheduled dim
    schedule_str: str = ""            # human-readable schedule (debug)
    degraded: bool = False
    fallback_level: int = 0
    degrade_reasons: Tuple[str, ...] = ()


def _matmul_scop(m: int, n: int, k: int) -> Scop:
    s = Scop("pallas_matmul", params={"M": m, "N": n, "K": k})
    with s.loop("i", 0, "M"):
        with s.loop("j", 0, "N"):
            with s.loop("kk", 0, "K"):
                s.stmt("C[i,j] = C[i,j] + A[i,kk] * B[kk,j]")
    return s


def _iter_extents(scop: Scop, stmt: Statement) -> Dict[str, int]:
    """Concrete trip count per statement iterator (parameter values baked
    in) — the dimension sizes the VMEM tile fitter works against."""
    from .cachemodel import stmt_iter_ranges

    return {it: (max(1, int(rng[1] - rng[0]) + 1) if rng is not None else 1)
            for it, rng in stmt_iter_ranges(scop, stmt).items()}


def _fit_tiles(order: List[str], dims: Dict[str, int], vector_iter: str,
               stmt: Statement, bytes_per_elem: int = 2,
               n_buffers: int = 3,
               fixed: Optional[Dict[str, int]] = None) -> Dict[str, int]:
    """Snap tiles to TPU-friendly sizes under a VMEM budget.

    The working set always comes from the shared cache model
    (:func:`repro.core.cachemodel.stmt_access_groups`): per-access tile
    footprints from the statement's actual subscript strides, times
    ``n_buffers`` for double/triple buffering — the same estimator that
    sizes CPU cache tiles sizes VMEM tiles.  No heuristic fallback: the
    statement's real access groups are required.

    ``fixed`` pins dims to a given tile (e.g. a VMEM-resident state dim
    that must stay whole); pinned dims are exempt from shrinking, so the
    others shrink against the true footprint."""
    from .cachemodel import stmt_access_groups, working_set_bytes

    fixed = fixed or {}
    tile = {}
    for it in order:
        d = dims[it]
        if it in fixed:
            tile[it] = min(fixed[it], d)
        elif it == vector_iter:
            tile[it] = min(d, 512 if d % 512 == 0 else LANE * max(d // LANE, 1))
            tile[it] = max(min(tile[it], d), min(d, LANE))
        else:
            tile[it] = min(d, 128 if d >= 128 else d)
    groups = stmt_access_groups(stmt, order)

    # shrink until the working set fits VMEM
    def wset():
        sizes = [tile[i] for i in order]
        return n_buffers * working_set_bytes(groups, sizes, bytes_per_elem)

    shrink_order = [it for it in order if it != vector_iter and it not in fixed]
    while wset() > VMEM_BYTES and any(tile[i] > SUBLANE for i in shrink_order):
        for it in shrink_order:
            if tile[it] > SUBLANE:
                tile[it] //= 2
                break
    return tile


def lower_to_kernel_plan(tree: ScheduleTree, stmt_idx: Optional[int] = None,
                         *, bytes_per_elem: int = 2, n_buffers: int = 3,
                         fixed_tiles: Optional[Dict[str, int]] = None,
                         sched=None) -> KernelPlan:
    """Map any scheduled SCoP's schedule tree to a :class:`KernelPlan`.

    * **grid order** — outer→inner point bands of the tree (tile/wave
      counter bands are post-processing artifacts and skipped), each
      mapped back to the statement iterator it scans through the tree's
      iterator substitution;
    * **vector dim** — the band carrying the ``vector`` mark when one
      exists, else the schedule's vectorize directive, else the
      innermost loop (contiguity put it there);
    * **tiles** — lane/sublane-snapped sizes fitted to VMEM via the
      shared cache model (:func:`_fit_tiles`).

    ``stmt_idx`` defaults to the deepest statement (scalar-init
    statements have no loop nest to map to a grid); a zero-dimensional
    choice raises ``ValueError`` so rankers can drop the candidate.

    ``sched`` (the Schedule the tree was built from) supplies the
    degradation-ladder provenance stamped on the plan; omitted, the
    plan reports a clean, non-degraded lowering.
    """
    scop = tree.scop
    if stmt_idx is None:
        stmt_idx = max(range(len(scop.statements)),
                       key=lambda i: (scop.statements[i].dim, -i))
    stmt = scop.statements[stmt_idx]
    if stmt.dim == 0:
        raise ValueError(
            f"statement S{stmt.index} has no loop dimensions to lower")
    sub = tree.subst.get(stmt.index, {})
    order: List[str] = []
    vec: Optional[str] = None
    for band in tree.bands():
        if stmt.index not in band.stmts or band.role:
            continue
        y = yvar(band.dim)
        cands = [it for it in stmt.iters if sub.get(it, {}).get(y)]
        if len(cands) == 1 and cands[0] not in order:
            order.append(cands[0])
            if band.vector and vec is None:
                vec = cands[0]
    for it in stmt.iters:     # safety: append anything unplaced
        if it not in order:
            order.append(it)
    if vec is None:
        vi = tree.vector_iter.get(stmt.index)
        vec = stmt.iters[vi] if vi is not None else order[-1]
    dims = _iter_extents(scop, stmt)
    tile = _fit_tiles(order, dims, vec, stmt,
                      bytes_per_elem=bytes_per_elem, n_buffers=n_buffers,
                      fixed=fixed_tiles)
    prov = _provenance(sched) if sched is not None else None
    return KernelPlan(tuple(order), vec, tile, tuple(tree.sched_bands),
                      tree.pretty,
                      degraded=bool(prov["degraded"]) if prov else False,
                      fallback_level=prov["fallback_level"] if prov else 0,
                      degrade_reasons=tuple(prov["reasons"]) if prov else ())


def _remote_plan(kind: str, *args, **kwargs) -> Optional[KernelPlan]:
    """Route a kernel plan through a running schedd daemon, if any.

    Returns None (plan locally) unless ``POLYTOPS_SCHEDD_SOCK`` points
    at a live daemon — and never from inside the daemon itself or a
    client's fallback path (:mod:`schedclient` guards both).  Remote
    failures of any kind also return None: the daemon is an amortizer,
    never a point of failure for planning."""
    from .schedclient import maybe_remote_plan

    plan = maybe_remote_plan(kind, *args, **kwargs)
    return plan if isinstance(plan, KernelPlan) else None


#: default in-process memo capacity per planner.  Ragged serving shapes
#: produce one (seq_q, seq_k, head_dim) triple per distinct chunk×page
#: geometry, so attention needs far more than the historical 8 entries
#: (which thrashed: every continuous-batching tick re-planned).
#: Override per planner with ``POLYTOPS_PLAN_MEMO_<NAME>`` or globally
#: with ``POLYTOPS_PLAN_MEMO``.
PLAN_MEMO_DEFAULTS: Dict[str, int] = {
    "matmul": 64, "attention": 64, "mamba_scan": 16, "scan_gate": 16,
}

#: per-planner :class:`~repro.core.schedcache.CacheStats` — hits/misses/
#: evicted of the in-process plan memos, inspectable via
#: :func:`plan_memo_stats` (serve/bench surface them next to the
#: schedule-cache stats).
_PLAN_MEMO_STATS: Dict[str, "object"] = {}


def plan_memo_size(name: str) -> int:
    """Resolved memo capacity for planner ``name`` (env-overridable)."""
    import os
    raw = (os.environ.get(f"POLYTOPS_PLAN_MEMO_{name.upper()}")
           or os.environ.get("POLYTOPS_PLAN_MEMO"))
    if raw:
        try:
            return max(1, int(raw))
        except ValueError:
            pass
    return PLAN_MEMO_DEFAULTS.get(name, 16)


def plan_memo_stats() -> Dict[str, Dict[str, object]]:
    """``{planner: CacheStats.as_dict()}`` for every registered memo."""
    return {name: st.as_dict() for name, st in _PLAN_MEMO_STATS.items()}


def _plan_memo(name: str):
    """Like ``functools.lru_cache`` but degraded plans are returned
    without being pinned: a plan lowered from a fault- or deadline-
    degraded schedule must not be served for the rest of the process —
    the next call re-plans and caches the clean result once the
    transient clears (the in-memory twin of schedcache's rule that
    degraded schedules are never published).

    Capacity is resolved per call via :func:`plan_memo_size`, so a
    serving process can widen a thrashing memo with one env var; every
    hit/miss/eviction is counted in the planner's
    :class:`~repro.core.schedcache.CacheStats`."""
    from .schedcache import CacheStats

    stats = _PLAN_MEMO_STATS.setdefault(name, CacheStats())

    def deco(fn):
        memo: Dict[tuple, KernelPlan] = {}

        @functools.wraps(fn)
        def wrapper(*args):
            hit = memo.get(args)
            if hit is not None:
                stats.hits += 1
                return hit
            stats.misses += 1
            plan = fn(*args)
            if not plan.degraded:
                while len(memo) >= plan_memo_size(name):  # FIFO, as lru
                    memo.pop(next(iter(memo)))
                    stats.evicted += 1
                memo[args] = plan
            return plan

        wrapper.cache_clear = memo.clear
        wrapper.stats = stats
        return wrapper
    return deco


@_plan_memo("matmul")
def plan_matmul(m: int, n: int, k: int,
                strategy: str = "tensor") -> KernelPlan:
    """PolyTOPS-planned matmul: tensor-style scheduling yields the
    cache/VMEM-friendly (i, k, j) order with j vectorized (lanes)."""
    remote = _remote_plan("matmul", m, n, k, strategy)
    if remote is not None:
        return remote
    scop = _matmul_scop(m, n, k)
    cfg = tensor_style()
    cfg.auto_vectorize = True
    # structural cache: repeat plans for the same (m, n, k) shape are a
    # lookup, persisted on disk across serving/benchmark processes —
    # with the schedule tree riding along in the payload.  The ladder
    # makes planning total: a fault degrades the schedule (provenance on
    # the plan) instead of failing the kernel build.
    sched = schedule_with_ladder(scop, cfg, cache=global_cache(),
                                 with_tree=True)
    return lower_to_kernel_plan(schedule_tree(sched), sched=sched)


@_plan_memo("attention")
def plan_attention(seq_q: int, seq_k: int, head_dim: int) -> KernelPlan:
    """Schedule the S = Q·Kᵀ core (q, k, d loops): contiguity puts d
    innermost (lanes) and yields the q-block × k-block band that the
    flash kernel tiles over."""
    remote = _remote_plan("attention", seq_q, seq_k, head_dim)
    if remote is not None:
        return remote
    s = Scop("attn_score", params={"Q": seq_q, "K": seq_k, "D": head_dim})
    with s.loop("q", 0, "Q"):
        with s.loop("kk", 0, "K"):
            with s.loop("d", 0, "D"):
                s.stmt("S[q,kk] = S[q,kk] + Qm[q,d] * Km[kk,d]")
    cfg = tensor_style()
    sched = schedule_with_ladder(s, cfg, cache=global_cache(),
                                 with_tree=True)
    plan = lower_to_kernel_plan(schedule_tree(sched), sched=sched)
    # flash blocking: q and k tiles bounded for the online-softmax state
    tile = dict(plan.tile)
    tile["q"] = min(tile.get("q", 128), 128)
    tile["kk"] = min(tile.get("kk", 128), 128)
    return replace(plan, tile=tile)


@_plan_memo("mamba_scan")
def plan_mamba_scan(seq: int, d_inner: int, state: int) -> KernelPlan:
    """Selective-scan (Mamba-1) recurrence h_t = a_t ⊙ h_{t-1} + b_t with
    y_t = h_t · c_t: the scheduler discovers t sequential-outermost (the
    recurrence dependence) with the d/state dims parallel inside, and the
    lowering turns that into the kernel's chunked grid — chunk size from
    the t tile, d-block from the d tile."""
    remote = _remote_plan("mamba_scan", seq, d_inner, state)
    if remote is not None:
        return remote
    s = Scop("mamba_scan", params={"T": seq, "D": d_inner, "S": state})
    with s.loop("t", 0, "T"):
        with s.loop("d", 0, "D"):
            with s.loop("n", 0, "S"):
                s.stmt("H[d,n] = A[t,d,n] * H[d,n] + B[t,d,n]")
                s.stmt("Y[t,d] = Y[t,d] + H[d,n] * Cs[t,n]")
    cfg = tensor_style()
    sched = schedule_with_ladder(s, cfg, cache=global_cache(),
                                 with_tree=True)
    # kernel constraint: the hidden state (d_block × state) is VMEM-
    # resident scratch across chunks — the state dim stays whole, pinned
    # *inside* the fit so t/d shrink against the true footprint
    return lower_to_kernel_plan(schedule_tree(sched), stmt_idx=0,
                                bytes_per_elem=4, n_buffers=2,
                                fixed_tiles={"n": state}, sched=sched)


def _scan_gate_scop(seq: int, d_inner: int, state: int) -> Scop:
    """Fused Mamba tail: recurrence + C-contraction (3-deep) and the
    skip+gate epilogue (2-deep) share one t/d nest, so the scheduler
    sees the fusion and tiles t/d for the combined working set."""
    s = Scop("scan_gate", params={"T": seq, "D": d_inner, "S": state})
    with s.loop("t", 0, "T"):
        with s.loop("d", 0, "D"):
            with s.loop("n", 0, "S"):
                s.stmt("H[d,n] = A[t,d,n] * H[d,n] + B[t,d,n]")
                s.stmt("Y[t,d] = Y[t,d] + H[d,n] * Cs[t,n]")
            s.stmt("O[t,d] = (Y[t,d] + X[t,d] * Dk[d]) * G[t,d]")
    return s


@_plan_memo("scan_gate")
def plan_scan_gate(seq: int, d_inner: int, state: int) -> KernelPlan:
    """Plan the fused scan+skip+gate kernel (``repro.kernels.scan_gate``).

    Unlike the single-schedule planners this one is *autotuned*: the
    fused SCoP's schedule bases are enumerated and statically ranked by
    :func:`repro.core.autotune.rank_pallas_plans` (the PolyTOPS
    reconfigurability story — the cost model picks among legal
    schedules), and the best lowerable candidate's t/d tiles become the
    kernel's chunk/d_block.  Falls back to the ladder path on any
    autotune failure so planning stays total."""
    remote = _remote_plan("scan_gate", seq, d_inner, state)
    if remote is not None:
        return remote
    scop = _scan_gate_scop(seq, d_inner, state)
    plan: Optional[KernelPlan] = None
    try:
        from .autotune import rank_pallas_plans

        cands = rank_pallas_plans(scop, top_k=4, cache=global_cache())
        for cand in cands:
            if cand.plan is not None and "t" in cand.plan.tile \
                    and "d" in cand.plan.tile:
                plan = cand.plan
                break
    except Exception:
        plan = None
    if plan is None:
        cfg = tensor_style()
        sched = schedule_with_ladder(scop, cfg, cache=global_cache(),
                                     with_tree=True)
        plan = lower_to_kernel_plan(schedule_tree(sched), stmt_idx=0,
                                    bytes_per_elem=4, n_buffers=2,
                                    fixed_tiles={"n": state}, sched=sched)
    # kernel constraint (same as mamba_scan): the (d_block × state)
    # hidden state is VMEM-resident across chunks — state stays whole.
    tile = dict(plan.tile)
    tile["n"] = state
    return replace(plan, tile=tile)
