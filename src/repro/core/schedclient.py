"""Scheduling-service client: wire protocol + resilient fallback client.

``repro.launch.schedd`` turns the hardened scheduling pipeline (PR 6's
ladder, deadlines and crash-safe caches) into a long-lived Unix-socket
*service* so concurrent compiles from many client processes amortize one
scheduler instead of repeating it.  This module is everything a client
(or the daemon itself) needs to speak to it:

* **Wire protocol** — length-prefixed pickle frames
  (``MAGIC | uint32 length | pickle``) over a Unix stream socket.  Each
  connection opens with a version handshake carrying
  ``PROTOCOL_VERSION`` plus the three cache-compatibility versions
  (``schedcache.CACHE_VERSION``, ``schedtree.TREE_VERSION``,
  ``autotune.SPACE_VERSION``) — a stale peer on either side is rejected
  with a typed ``version_skew`` response before any request is served,
  so a half-upgraded machine can never exchange incompatible Schedule
  pickles.  Pickle over the wire is safe here for the same reason the
  on-disk schedule cache is: the socket lives in a user-owned directory
  (mode 0o600) and both ends are the same codebase on the same host.

* **Typed errors** — every way a request can fail maps to one exception
  class (:class:`Overloaded`, :class:`VersionSkew`,
  :class:`ProtocolError`, :class:`DaemonUnavailable`,
  :class:`RemoteError`), mirroring the daemon's wire-level error kinds.

* **The resilient client** — :class:`SchedClient` wraps every request in
  bounded retry-with-backoff and a circuit breaker, propagates the
  caller's :class:`~repro.core.resilience.Deadline` onto the wire
  (``deadline_s`` = remaining budget; the daemon resumes it server-side)
  and clips the socket timeout to it, and **falls back in-process** when
  the daemon is down (socket ENOENT / connection refused), overloaded
  (typed ``Overloaded`` load-shedding responses), version-skewed, or
  misbehaving: ``schedule`` falls back to the degradation ladder over
  ``cached_schedule_scop``, ``autotune`` to the local tuner, ``plan`` to
  the local ``akg`` planners.  The public API therefore *never* raises
  for daemon trouble — the worst case is the same in-process behaviour
  the codebase had before the daemon existed, with the fallback counted
  in :class:`ClientStats`.

The module-level :func:`maybe_client` / :func:`maybe_remote_plan`
helpers are the integration seam: ``akg``'s plan functions and
``launch/serve.py`` route through the daemon exactly when
``$POLYTOPS_SCHEDD_SOCK`` names a socket, and never from inside the
daemon's own process (:func:`mark_server_process` guards recursion).
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import threading
import time
from contextlib import contextmanager
from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

from .resilience import Deadline

#: bump on any incompatible change to the frame format or message shapes
PROTOCOL_VERSION = 1
MAGIC = b"PTSD"
_HEADER = struct.Struct(">I")
HEADER_LEN = len(MAGIC) + _HEADER.size
#: hard cap on a single frame — a garbage length prefix must not make
#: either side try to allocate gigabytes
MAX_FRAME_BYTES = 64 << 20

#: environment variable naming the daemon socket; unset → no daemon
SOCKET_ENV = "POLYTOPS_SCHEDD_SOCK"


def wire_versions() -> Dict[str, int]:
    """The four versions exchanged in the handshake.  Imported lazily:
    the client is reachable from ``akg`` and must stay cheap to load."""
    from .autotune import SPACE_VERSION
    from .schedcache import CACHE_VERSION
    from .schedtree import TREE_VERSION

    return {"proto": PROTOCOL_VERSION, "cache": CACHE_VERSION,
            "tree": TREE_VERSION, "space": SPACE_VERSION}


def version_skew(theirs: Dict[str, Any]) -> Optional[str]:
    """Human-readable mismatch description, or None when compatible."""
    ours = wire_versions()
    bad = [f"{k}: ours={ours[k]} theirs={theirs.get(k)!r}"
           for k in ours if theirs.get(k) != ours[k]]
    return "; ".join(bad) or None


# ---------------------------------------------------------------------------
# typed errors
# ---------------------------------------------------------------------------


class SchedClientError(RuntimeError):
    """Base of every typed daemon-communication error."""


class DaemonUnavailable(SchedClientError):
    """No daemon: socket missing, connection refused/reset, timeout."""


class ProtocolError(SchedClientError):
    """Malformed wire data: bad magic, truncated frame, unpicklable
    payload, or a ``bad_frame``/``bad_request`` response."""


class Overloaded(SchedClientError):
    """The daemon load-shed this request (typed ``overloaded`` reply)."""


class VersionSkew(SchedClientError):
    """Handshake rejected: the peer runs incompatible cache/tree/space
    versions.  Not transient — the breaker opens immediately."""


class RemoteError(SchedClientError):
    """The daemon failed serving the request (typed ``internal`` /
    ``deadline`` reply); carries the wire error kind."""

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"daemon error [{kind}]"
                         + (f": {detail}" if detail else ""))
        self.kind = kind
        self.detail = detail


class WorkerCrashed(RemoteError):
    """A daemon pool worker died (or wedged) computing this request,
    twice — the daemon already retried once on a fresh worker.  The
    daemon itself is healthy; the request is the likely poison, so the
    client falls back in-process rather than hammering the pool."""

    def __init__(self, detail: str = ""):
        super().__init__("worker_crashed",
                         detail or "pool worker died computing the request")


def response_error(resp: Dict[str, Any]) -> SchedClientError:
    """Map a ``{"ok": False, ...}`` response to its typed exception."""
    kind = str(resp.get("error", "internal"))
    detail = str(resp.get("detail", ""))
    if kind == "overloaded":
        return Overloaded(detail or "daemon load-shed the request")
    if kind == "version_skew":
        return VersionSkew(detail or "incompatible peer versions")
    if kind in ("bad_frame", "bad_request"):
        return ProtocolError(f"{kind}: {detail}")
    if kind == "worker_crashed":
        return WorkerCrashed(detail)
    return RemoteError(kind, detail)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def encode_frame(obj: Any) -> bytes:
    body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(body)} B")
    return MAGIC + _HEADER.pack(len(body)) + body


def send_frame(sock: socket.socket, obj: Any) -> None:
    sock.sendall(encode_frame(obj))


def _recv_exact(sock: socket.socket, n: int, *, eof_ok: bool) -> Optional[bytes]:
    """Exactly ``n`` bytes, or None on clean EOF at a frame boundary
    (``eof_ok``).  EOF mid-read is always a truncated frame."""
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf and eof_ok:
                return None
            raise ProtocolError(
                f"truncated frame: got {len(buf)} of {n} bytes before EOF")
        buf += chunk
    return buf


def recv_frame(sock: socket.socket, *, eof_ok: bool = False,
               max_bytes: int = MAX_FRAME_BYTES) -> Any:
    """One decoded frame; None on clean EOF when ``eof_ok``.  Raises
    :class:`ProtocolError` on garbage (bad magic, oversized length,
    truncation, unpicklable body) — never anything untyped."""
    head = _recv_exact(sock, HEADER_LEN, eof_ok=eof_ok)
    if head is None:
        return None
    if head[:len(MAGIC)] != MAGIC:
        raise ProtocolError(f"bad magic {head[:len(MAGIC)]!r}")
    (length,) = _HEADER.unpack(head[len(MAGIC):])
    if length > max_bytes:
        raise ProtocolError(f"frame length {length} exceeds {max_bytes} cap")
    body = _recv_exact(sock, length, eof_ok=False)
    try:
        return pickle.loads(body)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:
        raise ProtocolError(f"unpicklable frame body: "
                            f"{type(e).__name__}: {e}") from e


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Consecutive-failure breaker with a single half-open probe.

    ``threshold`` whole-call failures open the circuit for ``reset_s``;
    after that one probe call is let through — success closes the
    circuit, failure re-opens it for another ``reset_s``.  While open,
    :meth:`allow` returns False and the client skips the daemon
    entirely (straight to the in-process fallback) — a dead daemon
    costs one failed ``connect`` per reset window, not per request."""

    def __init__(self, threshold: int = 3, reset_s: float = 5.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.reset_s = reset_s
        self._clock = clock
        self._lock = threading.Lock()
        self.failures = 0
        self.opens = 0
        self._opened_at: Optional[float] = None
        self._probing = False

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            return "half-open" if self._probing else "open"

    def allow(self) -> bool:
        with self._lock:
            if self._opened_at is None:
                return True
            if self._probing:
                return False
            if self._clock() - self._opened_at >= self.reset_s:
                self._probing = True    # one probe through
                return True
            return False

    def success(self) -> None:
        with self._lock:
            self.failures = 0
            self._opened_at = None
            self._probing = False

    def failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self._probing or self.failures >= self.threshold:
                self._trip_locked()

    def trip(self) -> None:
        """Open immediately (version skew: retrying cannot help)."""
        with self._lock:
            self._trip_locked()

    def _trip_locked(self) -> None:
        if self._opened_at is None or self._probing:
            self.opens += 1
        self._opened_at = self._clock()
        self._probing = False


# ---------------------------------------------------------------------------
# the client
# ---------------------------------------------------------------------------


@dataclass
class ClientStats:
    """Every client-side outcome, counted (same spirit as CacheStats)."""
    remote_ok: int = 0          # requests answered by the daemon
    remote_errors: int = 0      # failed attempts (before retry/fallback)
    retries: int = 0
    fallbacks: int = 0          # requests served by the in-process path
    overloaded: int = 0         # typed load-shed replies received
    version_skew: int = 0
    breaker_skips: int = 0      # requests that never tried the daemon

    def as_dict(self) -> Dict[str, int]:
        return asdict(self)


class SchedClient:
    """Resilient client for the ``repro.launch.schedd`` daemon.

    The public entry points (:meth:`schedule`, :meth:`autotune`,
    :meth:`plan`) are *total*: any daemon trouble — down, overloaded,
    version-skewed, garbage on the wire, deadline exhausted before the
    request could even be sent — degrades to the in-process path and is
    counted in :attr:`stats`.  :meth:`remote_plan`, :meth:`ping`,
    :meth:`daemon_stats` and :meth:`shutdown` raise typed errors
    instead, for callers that need to observe the daemon itself.

    ``cache`` names the :class:`~repro.core.schedcache.ScheduleCache`
    the fallback path uses (default: the process-global one), so tests
    and the chaos harness can isolate fallback state from the daemon's
    pool.  ``versions`` overrides the handshake versions (chaos: a
    deliberately stale peer).
    """

    def __init__(self, sock_path: Optional[str] = None, *,
                 connect_timeout: float = 1.0, request_timeout: float = 120.0,
                 retries: int = 1, backoff_s: float = 0.05,
                 breaker_threshold: int = 3, breaker_reset_s: float = 5.0,
                 cache=None, versions: Optional[Dict[str, int]] = None):
        self.sock_path = sock_path or daemon_socket_path()
        self.connect_timeout = connect_timeout
        self.request_timeout = request_timeout
        self.retries = retries
        self.backoff_s = backoff_s
        self.cache = cache
        self._versions = versions
        self.breaker = CircuitBreaker(breaker_threshold, breaker_reset_s)
        self.stats = ClientStats()

    # -- low-level ---------------------------------------------------------

    def _hello(self) -> Dict[str, Any]:
        return {"op": "hello", **(self._versions or wire_versions())}

    def _request(self, payload: Dict[str, Any],
                 timeout: float) -> Dict[str, Any]:
        """One connection, one handshake, one request/response."""
        if not self.sock_path:
            raise DaemonUnavailable("no daemon socket configured")
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            sock.settimeout(min(self.connect_timeout, timeout))
            try:
                sock.connect(self.sock_path)
            except OSError as e:
                raise DaemonUnavailable(
                    f"connect {self.sock_path!r}: {e}") from e
            sock.settimeout(timeout)
            try:
                send_frame(sock, self._hello())
                hello = recv_frame(sock)
                if hello is None:
                    raise ProtocolError("daemon closed during handshake")
                if not hello.get("ok"):
                    raise response_error(hello)
                send_frame(sock, payload)
                resp = recv_frame(sock)
                if resp is None:
                    raise ProtocolError("daemon closed mid-request")
                if not resp.get("ok"):
                    raise response_error(resp)
                return resp
            except socket.timeout as e:
                raise DaemonUnavailable(
                    f"daemon timed out after {timeout:.3f}s") from e
            except (BrokenPipeError, ConnectionError) as e:
                raise DaemonUnavailable(f"connection died: {e}") from e
        finally:
            try:
                sock.close()
            except OSError:
                pass

    def _call(self, payload: Dict[str, Any],
              deadline: Optional[Deadline] = None) -> Dict[str, Any]:
        """Breaker + bounded retry-with-backoff around :meth:`_request`.
        Raises the last typed error when the daemon could not serve the
        request; the public API turns that into the local fallback."""
        if not self.breaker.allow():
            self.stats.breaker_skips += 1
            raise DaemonUnavailable("circuit breaker open")
        delay = self.backoff_s
        last: Optional[SchedClientError] = None
        for attempt in range(self.retries + 1):
            timeout = self.request_timeout
            if deadline is not None and deadline.budget_s is not None:
                rem = deadline.remaining()
                if rem <= 0:
                    self.breaker.failure()
                    raise DaemonUnavailable(
                        "deadline exhausted before the request was sent")
                timeout = min(timeout, max(rem, 1e-3))
                payload = dict(payload, deadline_s=rem)
            try:
                resp = self._request(payload, timeout)
                self.breaker.success()
                self.stats.remote_ok += 1
                return resp
            except VersionSkew:
                # not transient: no retry, breaker opens immediately so
                # every later request goes straight to the fallback
                self.stats.version_skew += 1
                self.stats.remote_errors += 1
                self.breaker.trip()
                raise
            except Overloaded as e:
                self.stats.overloaded += 1
                self.stats.remote_errors += 1
                last = e
            except (DaemonUnavailable, ProtocolError, RemoteError) as e:
                self.stats.remote_errors += 1
                last = e
            if attempt < self.retries:
                self.stats.retries += 1
                nap = delay
                if deadline is not None and deadline.budget_s is not None:
                    nap = min(nap, max(deadline.remaining(), 0.0))
                time.sleep(nap)
                delay *= 2
        self.breaker.failure()
        assert last is not None
        raise last

    def _fallback_cache(self):
        from .schedcache import global_cache
        return self.cache if self.cache is not None else global_cache()

    # -- public API --------------------------------------------------------

    def schedule(self, scop, config=None, engine: str = "lex",
                 with_tree: bool = False,
                 deadline: Optional[Deadline] = None, **extra):
        """Schedule ``scop`` through the daemon, falling back to the
        in-process degradation ladder over ``cached_schedule_scop`` —
        total, like everything the ladder serves."""
        payload = {"op": "schedule", "scop": scop, "config": config,
                   "engine": engine, "with_tree": bool(with_tree),
                   "extra": dict(extra)}
        try:
            return self._call(payload, deadline)["result"]
        except (SchedClientError, OSError):
            self.stats.fallbacks += 1
            from .resilience import schedule_with_ladder
            return schedule_with_ladder(
                scop, config, engine=engine, deadline=deadline,
                cache=self._fallback_cache(), with_tree=with_tree, **extra)

    def autotune(self, scop, *, deadline: Optional[Deadline] = None,
                 **kwargs):
        """Kernel-specific autotuning through the daemon (one shared
        winner store + measurement pool), falling back to the local
        tuner on daemon trouble."""
        payload = {"op": "autotune", "scop": scop, "kwargs": dict(kwargs)}
        try:
            return self._call(payload, deadline)["result"]
        except (SchedClientError, OSError):
            self.stats.fallbacks += 1
            from .autotune import autotune as local_autotune
            return local_autotune(scop, deadline=deadline,
                                  cache=self.cache, **kwargs)

    def remote_plan(self, kind: str, *args,
                    deadline: Optional[Deadline] = None, **kwargs):
        """A kernel plan from the daemon, raising typed errors on any
        failure — the ``akg`` hook treats a raise as 'plan locally'."""
        payload = {"op": "plan", "kind": kind, "args": list(args),
                   "kwargs": dict(kwargs)}
        return self._call(payload, deadline)["result"]

    def plan(self, kind: str, *args, **kwargs):
        """A kernel plan, falling back to the local ``akg`` planners."""
        try:
            return self.remote_plan(kind, *args, **kwargs)
        except (SchedClientError, OSError):
            self.stats.fallbacks += 1
            with local_only():
                return _local_plan(kind, *args, **kwargs)

    def ping(self, timeout: float = 2.0) -> Dict[str, Any]:
        return self._request({"op": "ping"}, timeout)

    def daemon_stats(self, timeout: float = 5.0) -> Dict[str, Any]:
        return self._request({"op": "stats"}, timeout)["result"]

    def shutdown(self, timeout: float = 5.0) -> None:
        """Ask the daemon to exit cleanly (bench/test teardown)."""
        try:
            self._request({"op": "shutdown"}, timeout)
        except (DaemonUnavailable, ProtocolError):
            pass          # already gone / died while answering


# ---------------------------------------------------------------------------
# integration seam: env-configured singleton + the akg plan hook
# ---------------------------------------------------------------------------

_SERVER_PROCESS = False
_LOCAL_ONLY = threading.local()
_DEFAULT: Optional[SchedClient] = None
_DEFAULT_LOCK = threading.Lock()


def mark_server_process() -> None:
    """Called by the daemon at startup: its own plan/schedule work must
    never route back through a client (recursion guard)."""
    global _SERVER_PROCESS
    _SERVER_PROCESS = True


@contextmanager
def local_only():
    """Force in-process planning inside the block — used by the client's
    own fallback so ``akg``'s remote hook cannot re-enter the daemon."""
    prev = getattr(_LOCAL_ONLY, "active", False)
    _LOCAL_ONLY.active = True
    try:
        yield
    finally:
        _LOCAL_ONLY.active = prev


def daemon_socket_path() -> Optional[str]:
    return os.environ.get(SOCKET_ENV) or None


def maybe_client() -> Optional[SchedClient]:
    """The process-wide client when ``$POLYTOPS_SCHEDD_SOCK`` is set,
    else None.  Always None inside the daemon's own process."""
    if _SERVER_PROCESS:
        return None
    path = daemon_socket_path()
    if not path:
        return None
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None or _DEFAULT.sock_path != path:
            _DEFAULT = SchedClient(path)
        return _DEFAULT


def maybe_remote_plan(kind: str, *args, **kwargs):
    """The ``akg`` hook: a daemon-planned kernel when one is configured
    and reachable, else None (caller plans in-process).  Never raises —
    the breaker makes repeated failures cost one check, not one
    connect, per request."""
    if getattr(_LOCAL_ONLY, "active", False):
        return None
    client = maybe_client()
    if client is None:
        return None
    try:
        return client.remote_plan(kind, *args, **kwargs)
    except (SchedClientError, OSError):
        return None


def _local_plan(kind: str, *args, **kwargs):
    from . import akg

    planners = {"matmul": akg.plan_matmul, "attention": akg.plan_attention,
                "mamba_scan": akg.plan_mamba_scan}
    if kind not in planners:
        raise ValueError(f"unknown plan kind {kind!r}; "
                         f"known: {', '.join(sorted(planners))}")
    return planners[kind](*args, **kwargs)
