"""Scheduling-service client: resilient fallback client over the shared
wire layer.

``repro.launch.schedd`` turns the hardened scheduling pipeline (PR 6's
ladder, deadlines and crash-safe caches) into a long-lived *service* so
concurrent compiles from many client processes amortize one scheduler
instead of repeating it.  This module is the client side; the frame
protocol, handshake, and typed error family live in
:mod:`repro.core.wire` (shared with the daemon) and are re-exported
here for compatibility.

* **Wire protocol + trust boundary** — length-prefixed frames
  (``MAGIC | uint32 length | body``), JSON for the handshake, pickle
  for requests/responses.  Each connection opens with a version
  handshake carrying ``PROTOCOL_VERSION`` plus the three
  cache-compatibility versions — a stale peer on either side is
  rejected with a typed ``version_skew`` before any request is served.
  Pickle over the wire is only safe against peers who could already
  run code as us, so each transport pins that down differently: the
  **Unix socket** lives in a user-owned 0o600 directory, so any peer
  that can connect can already write our cache files; the **TCP
  transport** requires a shared key (``$POLYTOPS_SCHEDD_KEY`` /
  ``--keyfile``) proven by an HMAC-SHA256 challenge–response inside
  the hello, after which every frame carries a per-direction
  sequence-numbered MAC that is verified *before* the body is
  unpickled.  Handshake frames are JSON with a small pre-auth size
  cap, so an unauthenticated TCP peer can never reach ``pickle.loads``
  or make the daemon buffer a 64 MiB frame.

* **Typed errors** — every way a request can fail maps to one
  exception class (:class:`Overloaded`, :class:`VersionSkew`,
  :class:`ProtocolError`, :class:`DaemonUnavailable`,
  :class:`AuthFailed`, :class:`RemoteError`), mirroring the daemon's
  wire-level error kinds.

* **The resilient client** — :class:`SchedClient` wraps every request
  in bounded retry-with-backoff and a circuit breaker, propagates the
  caller's :class:`~repro.core.resilience.Deadline` onto the wire
  (``deadline_s`` = remaining budget; the daemon resumes it
  server-side) and clips the socket timeout to it, **reuses pooled
  connections** (the handshake runs once per connection, not once per
  request — two round-trips saved per call over TCP; a stale pooled
  connection is redialed transparently once), and **falls back
  in-process** when the daemon is down, overloaded, version-skewed,
  auth-rejected, or misbehaving: ``schedule`` falls back to the
  degradation ladder over ``cached_schedule_scop``, ``autotune`` to
  the local tuner, ``plan`` to the local ``akg`` planners.  The public
  API therefore *never* raises for daemon trouble — the worst case is
  the same in-process behaviour the codebase had before the daemon
  existed, with the fallback counted in :class:`ClientStats`.

The module-level :func:`maybe_client` / :func:`maybe_remote_plan`
helpers are the integration seam: ``akg``'s plan functions and
``launch/serve.py`` route through the daemon exactly when
``$POLYTOPS_SCHEDD_ADDR`` (a ``host:port`` or socket path) or
``$POLYTOPS_SCHEDD_SOCK`` names one, and never from inside the
daemon's own process (:func:`mark_server_process` guards recursion).
"""
from __future__ import annotations

import os
import socket
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Union

from .resilience import Deadline
from .wire import (  # noqa: F401  (re-exported compatibility surface)
    ADDR_ENV,
    HEADER_LEN,
    KEY_ENV,
    MAC_LEN,
    MAGIC,
    MAX_FRAME_BYTES,
    PRE_AUTH_MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
    SOCKET_ENV,
    AuthFailed,
    DaemonUnavailable,
    Overloaded,
    ProtocolError,
    RemoteError,
    SchedClientError,
    Session,
    VersionSkew,
    WorkerCrashed,
    _HEADER,
    client_handshake,
    encode_frame,
    is_tcp_address,
    load_key,
    normalize_key,
    parse_address,
    recv_frame,
    response_error,
    send_frame,
    version_skew,
    wire_versions,
)
from .wire import _recv_exact  # noqa: F401  (test surface)

#: retrying is pointless unless at least this much deadline budget
#: remains *after* the backoff nap — below it, the retried request
#: would be dead on arrival
MIN_RETRY_BUDGET_S = 0.05


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Consecutive-failure breaker with a single half-open probe.

    ``threshold`` whole-call failures open the circuit for ``reset_s``;
    after that one probe call is let through — success closes the
    circuit, failure re-opens it for another ``reset_s``.  While open,
    :meth:`allow` returns False and the client skips the daemon
    entirely (straight to the in-process fallback) — a dead daemon
    costs one failed ``connect`` per reset window, not per request."""

    def __init__(self, threshold: int = 3, reset_s: float = 5.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.reset_s = reset_s
        self._clock = clock
        self._lock = threading.Lock()
        self.failures = 0
        self.opens = 0
        self._opened_at: Optional[float] = None
        self._probing = False

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            return "half-open" if self._probing else "open"

    def allow(self) -> bool:
        with self._lock:
            if self._opened_at is None:
                return True
            if self._probing:
                return False
            if self._clock() - self._opened_at >= self.reset_s:
                self._probing = True    # one probe through
                return True
            return False

    def success(self) -> None:
        with self._lock:
            self.failures = 0
            self._opened_at = None
            self._probing = False

    def failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self._probing or self.failures >= self.threshold:
                self._trip_locked()

    def trip(self) -> None:
        """Open immediately (version skew / auth: retrying cannot help)."""
        with self._lock:
            self._trip_locked()

    def _trip_locked(self) -> None:
        if self._opened_at is None or self._probing:
            self.opens += 1
        self._opened_at = self._clock()
        self._probing = False


# ---------------------------------------------------------------------------
# the client
# ---------------------------------------------------------------------------


class ClientStats:
    """Every client-side outcome, counted (same spirit as CacheStats).

    A :class:`SchedClient` is shared across daemon/connection threads,
    so increments go through :meth:`incr` under a lock — a plain
    ``+=`` on a shared counter loses updates under contention."""

    FIELDS = ("remote_ok", "remote_errors", "retries", "fallbacks",
              "overloaded", "version_skew", "auth_failed",
              "breaker_skips", "dials", "reuses")

    remote_ok: int          # requests answered by the daemon
    remote_errors: int      # failed attempts (before retry/fallback)
    retries: int
    fallbacks: int          # requests served by the in-process path
    overloaded: int         # typed load-shed replies received
    version_skew: int
    auth_failed: int        # typed auth rejections (TCP)
    breaker_skips: int      # requests that never tried the daemon
    dials: int              # fresh connections opened (handshakes run)
    reuses: int             # requests served over a pooled connection

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for f in self.FIELDS:
            setattr(self, f, 0)

    def incr(self, field: str, n: int = 1) -> None:
        assert field in self.FIELDS, field
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def as_dict(self) -> Dict[str, int]:
        with self._lock:
            return {f: getattr(self, f) for f in self.FIELDS}


class _PooledConn:
    """A live, handshaken connection parked for reuse."""

    __slots__ = ("sock", "session")

    def __init__(self, sock: socket.socket, session: Optional[Session]):
        self.sock = sock
        self.session = session

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class SchedClient:
    """Resilient client for the ``repro.launch.schedd`` daemon.

    The public entry points (:meth:`schedule`, :meth:`autotune`,
    :meth:`plan`) are *total*: any daemon trouble — down, overloaded,
    version-skewed, auth-rejected, garbage on the wire, deadline
    exhausted before the request could even be sent — degrades to the
    in-process path and is counted in :attr:`stats`.
    :meth:`remote_plan`, :meth:`ping`, :meth:`daemon_stats` and
    :meth:`shutdown` raise typed errors instead, for callers that need
    to observe the daemon itself.

    ``address`` is either a Unix socket path or ``host:port``; a TCP
    address requires the shared key (``key=`` or
    ``$POLYTOPS_SCHEDD_KEY``).  Connections are pooled per client: the
    version/auth handshake runs once per connection and requests reuse
    it until EOF/timeout, when the next request redials.

    ``cache`` names the :class:`~repro.core.schedcache.ScheduleCache`
    the fallback path uses (default: the process-global one), so tests
    and the chaos harness can isolate fallback state from the daemon's
    pool.  ``versions`` overrides the handshake versions (chaos: a
    deliberately stale peer).
    """

    #: pooled idle connections kept per client
    POOL_SIZE = 4

    def __init__(self, sock_path: Optional[str] = None, *,
                 connect_timeout: float = 1.0, request_timeout: float = 120.0,
                 retries: int = 1, backoff_s: float = 0.05,
                 breaker_threshold: int = 3, breaker_reset_s: float = 5.0,
                 cache=None, versions: Optional[Dict[str, int]] = None,
                 key: Union[str, bytes, None] = None):
        self.sock_path = sock_path or daemon_address()
        self.connect_timeout = connect_timeout
        self.request_timeout = request_timeout
        self.retries = retries
        self.backoff_s = backoff_s
        self.cache = cache
        self._versions = versions
        self.key = normalize_key(key) if key is not None else load_key()
        self.breaker = CircuitBreaker(breaker_threshold, breaker_reset_s)
        self.stats = ClientStats()
        self._pool_lock = threading.Lock()
        self._idle: List[_PooledConn] = []

    # -- connection pool ---------------------------------------------------

    def _dial(self, timeout: float) -> _PooledConn:
        """A fresh connected + handshaken connection."""
        assert self.sock_path
        kind, target = parse_address(self.sock_path)
        if kind == "tcp":
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        else:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            sock.settimeout(min(self.connect_timeout, timeout))
            try:
                sock.connect(target)
            except OSError as e:
                raise DaemonUnavailable(
                    f"connect {self.sock_path!r}: {e}") from e
            sock.settimeout(timeout)
            try:
                hello = {"op": "hello",
                         **(self._versions or wire_versions())}
                _, session = client_handshake(sock, hello, key=self.key)
            except socket.timeout as e:
                raise DaemonUnavailable(
                    f"daemon timed out after {timeout:.3f}s") from e
            except (BrokenPipeError, ConnectionError) as e:
                raise DaemonUnavailable(f"connection died: {e}") from e
            self.stats.incr("dials")
            return _PooledConn(sock, session)
        except BaseException:
            try:
                sock.close()
            except OSError:
                pass
            raise

    def _checkout(self) -> Optional[_PooledConn]:
        with self._pool_lock:
            return self._idle.pop() if self._idle else None

    def _checkin(self, conn: _PooledConn) -> None:
        with self._pool_lock:
            if len(self._idle) < self.POOL_SIZE:
                self._idle.append(conn)
                return
        conn.close()

    def close(self) -> None:
        """Drop every pooled connection (test/bench teardown)."""
        with self._pool_lock:
            idle, self._idle = self._idle, []
        for c in idle:
            c.close()

    # -- low-level ---------------------------------------------------------

    def _roundtrip(self, conn: _PooledConn, payload: Dict[str, Any],
                   timeout: float) -> Dict[str, Any]:
        conn.sock.settimeout(timeout)
        send_frame(conn.sock, payload, session=conn.session)
        resp = recv_frame(conn.sock, session=conn.session)
        if resp is None:
            raise ProtocolError("daemon closed mid-request")
        if not resp.get("ok"):
            raise response_error(resp)
        return resp

    def _request(self, payload: Dict[str, Any],
                 timeout: float) -> Dict[str, Any]:
        """One request/response over a pooled connection.

        A pooled connection may have been closed by the daemon while
        idle (conn-timeout, restart) — since requests are idempotent,
        a *reused* connection that dies before yielding a response is
        retried once on a fresh dial; errors on a fresh connection
        propagate (the daemon is actually unhealthy)."""
        if not self.sock_path:
            raise DaemonUnavailable("no daemon socket configured")
        conn = self._checkout()
        reused = conn is not None
        if conn is None:
            conn = self._dial(timeout)
        else:
            self.stats.incr("reuses")
        try:
            resp = self._roundtrip(conn, payload, timeout)
        except (socket.timeout, OSError, ProtocolError, AuthFailed) as e:
            conn.close()
            if not reused:
                raise self._typed_transport_error(e) from e
            # stale pooled connection — one transparent redial
            conn = self._dial(timeout)
            try:
                resp = self._roundtrip(conn, payload, timeout)
            except (socket.timeout, OSError, ProtocolError,
                    AuthFailed) as e2:
                conn.close()
                raise self._typed_transport_error(e2) from e2
        except BaseException:
            conn.close()      # typed daemon reply — connection is fine,
            raise             # but don't pool mid-error state
        self._checkin(conn)
        return resp

    @staticmethod
    def _typed_transport_error(e: BaseException) -> SchedClientError:
        """Map a transport-layer exception to the typed error family."""
        if isinstance(e, SchedClientError):
            return e
        if isinstance(e, socket.timeout):
            return DaemonUnavailable(f"daemon timed out: {e}")
        return DaemonUnavailable(f"connection died: {e}")

    def _call(self, payload: Dict[str, Any],
              deadline: Optional[Deadline] = None) -> Dict[str, Any]:
        """Breaker + bounded retry-with-backoff around :meth:`_request`.
        Raises the last typed error when the daemon could not serve the
        request; the public API turns that into the local fallback."""
        if not self.breaker.allow():
            self.stats.incr("breaker_skips")
            raise DaemonUnavailable("circuit breaker open")
        delay = self.backoff_s
        last: Optional[SchedClientError] = None
        for attempt in range(self.retries + 1):
            timeout = self.request_timeout
            if deadline is not None and deadline.budget_s is not None:
                rem = deadline.remaining()
                if rem <= 0:
                    self.breaker.failure()
                    raise DaemonUnavailable(
                        "deadline exhausted before the request was sent")
                timeout = min(timeout, max(rem, 1e-3))
                payload = dict(payload, deadline_s=rem)
            try:
                resp = self._request(payload, timeout)
                self.breaker.success()
                self.stats.incr("remote_ok")
                return resp
            except VersionSkew:
                # not transient: no retry, breaker opens immediately so
                # every later request goes straight to the fallback
                self.stats.incr("version_skew")
                self.stats.incr("remote_errors")
                self.breaker.trip()
                raise
            except AuthFailed:
                # wrong/missing key cannot fix itself between retries
                self.stats.incr("auth_failed")
                self.stats.incr("remote_errors")
                self.breaker.trip()
                raise
            except Overloaded as e:
                self.stats.incr("overloaded")
                self.stats.incr("remote_errors")
                last = e
            except (DaemonUnavailable, ProtocolError, RemoteError) as e:
                self.stats.incr("remote_errors")
                last = e
            if attempt < self.retries:
                nap = delay
                if deadline is not None and deadline.budget_s is not None:
                    # a retry is only worth napping for if enough budget
                    # remains to actually serve it afterwards — otherwise
                    # the retried request would be DOA and we'd just be
                    # double-counting a breaker failure
                    if deadline.remaining() <= nap + MIN_RETRY_BUDGET_S:
                        break
                self.stats.incr("retries")
                time.sleep(nap)
                delay *= 2
        self.breaker.failure()
        assert last is not None
        raise last

    def _fallback_cache(self):
        from .schedcache import global_cache
        return self.cache if self.cache is not None else global_cache()

    # -- public API --------------------------------------------------------

    def schedule(self, scop, config=None, engine: str = "lex",
                 with_tree: bool = False,
                 deadline: Optional[Deadline] = None, **extra):
        """Schedule ``scop`` through the daemon, falling back to the
        in-process degradation ladder over ``cached_schedule_scop`` —
        total, like everything the ladder serves."""
        payload = {"op": "schedule", "scop": scop, "config": config,
                   "engine": engine, "with_tree": bool(with_tree),
                   "extra": dict(extra)}
        try:
            return self._call(payload, deadline)["result"]
        except (SchedClientError, OSError):
            self.stats.incr("fallbacks")
            from .resilience import schedule_with_ladder
            return schedule_with_ladder(
                scop, config, engine=engine, deadline=deadline,
                cache=self._fallback_cache(), with_tree=with_tree, **extra)

    def autotune(self, scop, *, deadline: Optional[Deadline] = None,
                 **kwargs):
        """Kernel-specific autotuning through the daemon (one shared
        winner store + measurement pool), falling back to the local
        tuner on daemon trouble."""
        payload = {"op": "autotune", "scop": scop, "kwargs": dict(kwargs)}
        try:
            return self._call(payload, deadline)["result"]
        except (SchedClientError, OSError):
            self.stats.incr("fallbacks")
            from .autotune import autotune as local_autotune
            return local_autotune(scop, deadline=deadline,
                                  cache=self.cache, **kwargs)

    def remote_plan(self, kind: str, *args,
                    deadline: Optional[Deadline] = None, **kwargs):
        """A kernel plan from the daemon, raising typed errors on any
        failure — the ``akg`` hook treats a raise as 'plan locally'."""
        payload = {"op": "plan", "kind": kind, "args": list(args),
                   "kwargs": dict(kwargs)}
        return self._call(payload, deadline)["result"]

    def plan(self, kind: str, *args, **kwargs):
        """A kernel plan, falling back to the local ``akg`` planners."""
        try:
            return self.remote_plan(kind, *args, **kwargs)
        except (SchedClientError, OSError):
            self.stats.incr("fallbacks")
            with local_only():
                return _local_plan(kind, *args, **kwargs)

    def ping(self, timeout: float = 2.0) -> Dict[str, Any]:
        return self._request({"op": "ping"}, timeout)

    def daemon_stats(self, timeout: float = 5.0) -> Dict[str, Any]:
        return self._request({"op": "stats"}, timeout)["result"]

    def shutdown(self, timeout: float = 5.0) -> None:
        """Ask the daemon to exit cleanly (bench/test teardown)."""
        try:
            self._request({"op": "shutdown"}, timeout)
        except (DaemonUnavailable, ProtocolError):
            pass          # already gone / died while answering
        finally:
            self.close()


# ---------------------------------------------------------------------------
# integration seam: env-configured singleton + the akg plan hook
# ---------------------------------------------------------------------------

_SERVER_PROCESS = False
_LOCAL_ONLY = threading.local()
_DEFAULT: Optional[SchedClient] = None
_DEFAULT_LOCK = threading.Lock()


def mark_server_process() -> None:
    """Called by the daemon at startup: its own plan/schedule work must
    never route back through a client (recursion guard)."""
    global _SERVER_PROCESS
    _SERVER_PROCESS = True


@contextmanager
def local_only():
    """Force in-process planning inside the block — used by the client's
    own fallback so ``akg``'s remote hook cannot re-enter the daemon."""
    prev = getattr(_LOCAL_ONLY, "active", False)
    _LOCAL_ONLY.active = True
    try:
        yield
    finally:
        _LOCAL_ONLY.active = prev


def daemon_socket_path() -> Optional[str]:
    return os.environ.get(SOCKET_ENV) or None


def daemon_address() -> Optional[str]:
    """The configured daemon address: ``$POLYTOPS_SCHEDD_ADDR`` (socket
    path or ``host:port``) wins over ``$POLYTOPS_SCHEDD_SOCK``."""
    return os.environ.get(ADDR_ENV) or daemon_socket_path()


def maybe_client() -> Optional[SchedClient]:
    """The process-wide client when ``$POLYTOPS_SCHEDD_ADDR`` or
    ``$POLYTOPS_SCHEDD_SOCK`` is set, else None.  Always None inside
    the daemon's own process."""
    if _SERVER_PROCESS:
        return None
    path = daemon_address()
    if not path:
        return None
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None or _DEFAULT.sock_path != path:
            _DEFAULT = SchedClient(path)
        return _DEFAULT


def maybe_remote_plan(kind: str, *args, **kwargs):
    """The ``akg`` hook: a daemon-planned kernel when one is configured
    and reachable, else None (caller plans in-process).  Never raises —
    the breaker makes repeated failures cost one check, not one
    connect, per request."""
    if getattr(_LOCAL_ONLY, "active", False):
        return None
    client = maybe_client()
    if client is None:
        return None
    try:
        return client.remote_plan(kind, *args, **kwargs)
    except (SchedClientError, OSError):
        return None


def _local_plan(kind: str, *args, **kwargs):
    from . import akg

    planners = {"matmul": akg.plan_matmul, "attention": akg.plan_attention,
                "mamba_scan": akg.plan_mamba_scan,
                "scan_gate": akg.plan_scan_gate}
    if kind not in planners:
        raise ValueError(f"unknown plan kind {kind!r}; "
                         f"known: {', '.join(sorted(planners))}")
    return planners[kind](*args, **kwargs)


__all__ = [  # the compatibility surface tests and the daemon import
    "ADDR_ENV", "KEY_ENV", "MAGIC", "MAX_FRAME_BYTES", "HEADER_LEN",
    "MAC_LEN", "PRE_AUTH_MAX_FRAME_BYTES", "PROTOCOL_VERSION",
    "SOCKET_ENV", "AuthFailed", "CircuitBreaker", "ClientStats",
    "DaemonUnavailable", "Overloaded", "ProtocolError", "RemoteError",
    "SchedClient", "SchedClientError", "Session", "VersionSkew",
    "WorkerCrashed", "client_handshake", "daemon_address",
    "daemon_socket_path", "encode_frame", "is_tcp_address", "load_key",
    "local_only", "mark_server_process", "maybe_client",
    "maybe_remote_plan", "normalize_key", "parse_address", "recv_frame",
    "response_error", "send_frame", "version_skew", "wire_versions",
]
