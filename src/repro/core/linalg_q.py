"""Exact rational linear algebra over fractions.Fraction.

The polyhedral scheduler needs exact arithmetic: rank computations for
the progression constraint (Eq. 3 of the paper), orthogonal complements,
nullspaces, and small inverses. Everything here is dense and tiny
(matrices are at most ~tens of rows), so plain lists of Fractions are
fine and keep the implementation dependency-free and exact.
"""
from __future__ import annotations

from fractions import Fraction
from math import gcd
from typing import List, Sequence

Mat = List[List[Fraction]]
Vec = List[Fraction]


def mat(rows: Sequence[Sequence]) -> Mat:
    return [[Fraction(x) for x in r] for r in rows]


def zeros(m: int, n: int) -> Mat:
    return [[Fraction(0)] * n for _ in range(m)]


def eye(n: int) -> Mat:
    out = zeros(n, n)
    for i in range(n):
        out[i][i] = Fraction(1)
    return out


def matmul(a: Mat, b: Mat) -> Mat:
    n, k, m = len(a), len(b), len(b[0]) if b else 0
    out = zeros(n, m)
    for i in range(n):
        ai = a[i]
        for j in range(m):
            s = Fraction(0)
            for t in range(k):
                if ai[t]:
                    s += ai[t] * b[t][j]
            out[i][j] = s
    return out


def transpose(a: Mat) -> Mat:
    if not a:
        return []
    return [list(col) for col in zip(*a)]


def rref(a: Mat) -> tuple[Mat, list[int]]:
    """Reduced row echelon form; returns (rref_matrix, pivot_columns)."""
    m = [row[:] for row in a]
    rows = len(m)
    cols = len(m[0]) if rows else 0
    pivots: list[int] = []
    r = 0
    for c in range(cols):
        if r >= rows:
            break
        # find pivot
        piv = None
        for i in range(r, rows):
            if m[i][c] != 0:
                piv = i
                break
        if piv is None:
            continue
        m[r], m[piv] = m[piv], m[r]
        pv = m[r][c]
        m[r] = [x / pv for x in m[r]]
        for i in range(rows):
            if i != r and m[i][c] != 0:
                f = m[i][c]
                m[i] = [x - f * y for x, y in zip(m[i], m[r])]
        pivots.append(c)
        r += 1
    return m, pivots


def rank(a: Mat) -> int:
    if not a:
        return 0
    _, pivots = rref(a)
    return len(pivots)


def nullspace(a: Mat) -> Mat:
    """Basis (rows) of the right nullspace of a."""
    if not a:
        return []
    r, pivots = rref(a)
    cols = len(a[0])
    free = [c for c in range(cols) if c not in pivots]
    basis: Mat = []
    for fc in free:
        v = [Fraction(0)] * cols
        v[fc] = Fraction(1)
        for i, pc in enumerate(pivots):
            v[pc] = -r[i][fc]
        basis.append(v)
    return basis


def inverse(a: Mat) -> Mat:
    n = len(a)
    aug = [a[i][:] + eye(n)[i] for i in range(n)]
    r, pivots = rref(aug)
    if pivots[:n] != list(range(n)):
        raise ValueError("matrix not invertible")
    return [row[n:] for row in r]


def det(a: Mat) -> Fraction:
    n = len(a)
    m = [row[:] for row in a]
    d = Fraction(1)
    for c in range(n):
        piv = None
        for i in range(c, n):
            if m[i][c] != 0:
                piv = i
                break
        if piv is None:
            return Fraction(0)
        if piv != c:
            m[c], m[piv] = m[piv], m[c]
            d = -d
        d *= m[c][c]
        pv = m[c][c]
        for i in range(c + 1, n):
            if m[i][c] != 0:
                f = m[i][c] / pv
                m[i] = [x - f * y for x, y in zip(m[i], m[c])]
    return d


def row_basis(h: Mat) -> Mat:
    """Linearly independent subset of rows (rref pivot rows, int-scaled)."""
    if not h:
        return []
    r, pivots = rref(h)
    return batch_scale_to_int(r[: len(pivots)])


def orth_complement_rows(h: Mat, n: int) -> Mat:
    """H⊥ = I − Hᵀ(HHᵀ)⁻¹H for row-space H (paper Eq. 3 support).

    ``h`` holds previously found schedule rows (each of length n). Returns
    the projector onto the orthogonal complement of their row space, with
    each row scaled to coprime integers (LP-friendly). H is reduced to a
    row basis first so zero/dependent rows never make HHᵀ singular.
    """
    h = row_basis(h)
    if not h:
        return eye(n)
    hht = matmul(h, transpose(h))
    proj = matmul(matmul(transpose(h), inverse(hht)), h)
    comp = eye(n)
    for i in range(n):
        for j in range(n):
            comp[i][j] -= proj[i][j]
    return batch_scale_to_int(
        [row for row in comp if any(x != 0 for x in row)])


def orth_complement_basis(h: Mat, n: int) -> Mat:
    """A row *basis* of the orthogonal complement (rref pivot rows of the
    projector, integer-scaled). Using a basis instead of all projector
    rows avoids the degenerate case where two rows are negatives of each
    other and the paper's Σᵢ H⊥ᵢ·h ≥ 1 constraint becomes infeasible."""
    rows = orth_complement_rows(h, n)
    if not rows:
        return []
    r, pivots = rref(rows)
    return batch_scale_to_int(r[: len(pivots)])


def scale_to_int(row: Vec) -> Vec:
    """Scale a rational row to the smallest integer row (same direction)."""
    denoms = [x.denominator for x in row]
    l = 1
    for d in denoms:
        l = l * d // gcd(l, d)
    ints = [int(x * l) for x in row]
    g = 0
    for v in ints:
        g = gcd(g, abs(v))
    if g > 1:
        ints = [v // g for v in ints]
    return [Fraction(v) for v in ints]


def batch_scale_to_int(rows: Mat) -> Mat:
    """:func:`scale_to_int` over many rows — the single entry point the
    basis/projector helpers funnel through (a vectorized implementation
    would slot in here)."""
    return [scale_to_int(r) for r in rows]


def rationals_to_int_row(vals: Sequence[Fraction]) -> tuple[List[int], int]:
    """Scale a rational row to ``(integer_row, den)`` with
    ``integer_row[i] / den == vals[i]`` and ``den`` the lcm of the
    denominators (1 for already-integer rows — the common case for
    normalized constraint systems, returned without any multiplication).
    This is the Fraction→integer boundary of the exact simplex tableau
    (``repro.core.lexsimplex``): every constraint row and objective
    crosses through here exactly once."""
    den = 1
    for v in vals:
        d = v.denominator
        if d != 1:
            den = den * d // gcd(den, d)
    if den == 1:
        return [v.numerator for v in vals], 1
    return [int(v * den) for v in vals], den


def fractions_to_float_array(vals: Sequence[Fraction]):
    """Batched exact→float conversion (numpy float64 array).

    Fast path: when every value fits int64 as numerator/denominator
    pairs, the division runs vectorized in numpy instead of calling
    ``Fraction.__float__`` per element — this is the Fraction→numeric
    boundary the compiled ILP layer crosses for every constraint row.
    Falls back to per-element conversion for huge rationals."""
    import numpy as np

    try:
        num = np.array([v.numerator for v in vals], dtype=np.int64)
        den = np.array([v.denominator for v in vals], dtype=np.int64)
        return num / den
    except (OverflowError, TypeError):
        return np.array([float(v) for v in vals], dtype=np.float64)


def hnf_row(a: List[List[int]]) -> tuple[List[List[int]], List[List[int]]]:
    """Row-style Hermite Normal Form: returns (H, U) with U·A = H, U unimodular.

    Used by codegen to detect strides of non-unimodular schedule maps.
    """
    m = [row[:] for row in a]
    rows = len(m)
    cols = len(m[0]) if rows else 0
    u = [[1 if i == j else 0 for j in range(rows)] for i in range(rows)]
    r = 0
    for c in range(cols):
        if r >= rows:
            break
        # euclidean elimination below the pivot
        while True:
            nz = [i for i in range(r, rows) if m[i][c] != 0]
            if not nz:
                break
            piv = min(nz, key=lambda i: abs(m[i][c]))
            m[r], m[piv] = m[piv], m[r]
            u[r], u[piv] = u[piv], u[r]
            done = True
            for i in range(r + 1, rows):
                if m[i][c] != 0:
                    q = m[i][c] // m[r][c]
                    m[i] = [x - q * y for x, y in zip(m[i], m[r])]
                    u[i] = [x - q * y for x, y in zip(u[i], u[r])]
                    if m[i][c] != 0:
                        done = False
            if done:
                break
        if m[r][c] != 0:
            if m[r][c] < 0:
                m[r] = [-x for x in m[r]]
                u[r] = [-x for x in u[r]]
            # reduce above
            for i in range(r):
                if m[i][c] % m[r][c] != 0 or m[i][c] != 0:
                    q = m[i][c] // m[r][c]
                    if q:
                        m[i] = [x - q * y for x, y in zip(m[i], m[r])]
                        u[i] = [x - q * y for x, y in zip(u[i], u[r])]
            r += 1
    return m, u
