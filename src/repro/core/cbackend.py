"""C backend: emit a self-contained PolyBench-style C program for a
schedule (kernel + deterministic init + timing + checksum).

The Python/numpy backend (codegen.py) is the correctness oracle; this
backend is the *measurement* path for the paper's CPU experiments
(§IV-B/C/D): gcc -O3 -march=native applies real SIMD vectorization and
real cache behaviour. Parallel dims get ``#pragma omp parallel for`` and
vectorizable innermost dims ``#pragma omp simd`` (this container has one
core, so omp-parallel speedups are structural — documented in
EXPERIMENTS.md; SIMD + locality effects are real).

Concrete parameter values are baked in as compile-time constants,
exactly like PolyBench reference harnesses.
"""
from __future__ import annotations

import math
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .affine import Affine
from .codegen import (CodeGenerator, ScanStmt, _affine_src, _substitute_body,
                      _yvar)
from .polyhedron import maximum, minimum
from .scheduler import Schedule
from .scop import Scop, _ACCESS, _split_subscripts


def _ceild_c(num: str, den: int) -> str:
    return num if den == 1 else f"ceild({num}, {den})"


def _floord_c(num: str, den: int) -> str:
    return num if den == 1 else f"floord({num}, {den})"


def _fold(fn: str, terms: List[str]) -> str:
    out = terms[0]
    for t in terms[1:]:
        out = f"{fn}({out}, {t})"
    return out


def array_extents(scop: Scop) -> Dict[str, List[int]]:
    """Numeric extent of each array dim = 1 + max subscript value over all
    accesses (with the SCoP's concrete parameter values)."""
    ctx = [({p: Fraction(1), 1: Fraction(-v)}, "==0") for p, v in scop.params.items()]
    ext: Dict[str, List[int]] = {a: [0] * r for a, r in scop.arrays.items()}
    for s in scop.statements:
        cons = list(s.domain) + ctx
        for acc in s.accesses:
            for d, sub in enumerate(acc.subscripts):
                hi = maximum(cons, sub)
                lo = minimum(cons, sub)
                if hi is None:   # empty domain
                    continue
                if lo is not None and lo < 0:
                    raise ValueError(f"negative subscript for {acc.array} in S{s.index}")
                ext[acc.array][d] = max(ext[acc.array][d], int(hi) + 1)
    return ext


class CCodeGenerator(CodeGenerator):
    def __init__(self, sched: Schedule, scan: Optional[List[ScanStmt]] = None,
                 scalars: Optional[Dict[str, float]] = None,
                 omp: bool = True, repeats: int = 1,
                 func_name: Optional[str] = None):
        super().__init__(sched, scan=scan, vectorize=False, func_name=func_name)
        self.scalars = dict(scalars or {})
        self.omp = omp
        self.repeats = repeats
        self._parallel_emitted = False

    # -- program ----------------------------------------------------------
    def generate(self) -> str:
        scop = self.scop
        self.lines = []
        self.indent = 0
        self._parallel_emitted = False
        ext = array_extents(scop)
        e = self._emit
        e("#include <stdio.h>")
        e("#include <stdlib.h>")
        e("#include <math.h>")
        e("#include <time.h>")
        e("#define floord(n,d) (((n)<0) ? -((-(n)+(d)-1)/(d)) : (n)/(d))")
        e("#define ceild(n,d)  (((n)<0) ? -((-(n))/(d)) : ((n)+(d)-1)/(d))")
        e("#define MINI(a,b)   (((a)<(b)) ? (a) : (b))")
        e("#define MAXI(a,b)   (((a)>(b)) ? (a) : (b))")
        for p, v in scop.params.items():
            e(f"#define {p} {v}")
        for sc, v in self.scalars.items():
            e(f"static const double {sc} = {v!r};")
        for a, dims in ext.items():
            dd = "".join(f"[{max(d,1)}]" for d in dims)
            e(f"static double {a}{dd};")
        e("")
        e("static void init_arrays(void) {")
        self.indent += 1
        for a, dims in ext.items():
            idx = [f"i{k}" for k in range(len(dims))]
            for k, d in enumerate(dims):
                e("    " * k + f"for (int {idx[k]} = 0; {idx[k]} < {max(d,1)}; {idx[k]}++)")
            expr = " + ".join(f"{ix}*{7 + 6 * k}" for k, ix in enumerate(idx)) or "0"
            sub = "".join(f"[{ix}]" for ix in idx)
            init = scop.c_init.get(
                a, f"((double)(({expr} + 3) % 251)) / 251.0 + 0.1"
            )
            e("    " * len(dims) + f"{a}{sub} = {init};")
        self.indent -= 1
        e("}")
        e("")
        e("static double checksum(void) {")
        self.indent += 1
        e("double cksum_ = 0.0;")
        for a, dims in ext.items():
            idx = [f"i{k}" for k in range(len(dims))]
            for k, d in enumerate(dims):
                e("    " * k + f"for (int {idx[k]} = 0; {idx[k]} < {max(d,1)}; {idx[k]}++)")
            sub = "".join(f"[{ix}]" for ix in idx)
            e("    " * len(dims) + f"cksum_ += {a}{sub} * (1.0 + 0.0001*(({' + '.join(idx) if idx else '0'}) % 17));")
        e("return cksum_;")
        self.indent -= 1
        e("}")
        e("")
        e(f"static void {self.func_name}(void) {{")
        self.indent += 1
        n_dims = max(ss.n_dims() for ss in self.scan)
        self._gen_level(list(self.scan), 0, n_dims, {})
        self.indent -= 1
        e("}")
        e("")
        e(f"#define REPEATS {self.repeats}")
        e("int main(void) {")
        self.indent += 1
        e("init_arrays();")
        e(f"{self.func_name}();  /* warmup + correctness */")
        e("double warm = checksum();")
        e("init_arrays();")
        e("struct timespec t0, t1;")
        e("clock_gettime(CLOCK_MONOTONIC, &t0);")
        e(f"for (int r = 0; r < REPEATS; r++) {self.func_name}();")
        e("clock_gettime(CLOCK_MONOTONIC, &t1);")
        e("double secs = (t1.tv_sec - t0.tv_sec) + 1e-9*(t1.tv_nsec - t0.tv_nsec);")
        e('printf("TIME_S %.9f CHECKSUM %.9e\\n", secs / REPEATS, warm);')
        e("return 0;")
        self.indent -= 1
        e("}")
        return "\n".join(self.lines)

    # -- loop emission (C syntax + pragmas) ---------------------------------
    def _gen_loop(self, group, d, n_dims, guards):
        y = _yvar(d)
        los, his = [], []
        for ss in group:
            lo, hi = self._scanners[ss.stmt.index].bounds[d]
            los.append(self._bound_c(lo, lower=True))
            his.append(self._bound_c(hi, lower=False))
        lo_src = los[0] if len(set(los)) == 1 else _fold("MINI", sorted(set(los)))
        hi_src = his[0] if len(set(his)) == 1 else _fold("MAXI", sorted(set(his)))
        mixed = len(group) > 1 and (len(set(los)) > 1 or len(set(his)) > 1)
        new_guards = dict(guards)
        if mixed:
            for ss, l, h in zip(group, los, his):
                g = list(new_guards.get(ss.stmt.index, []))
                g += [f"{y} >= {l}", f"{y} <= {h}"]
                new_guards[ss.stmt.index] = g
        sd = min(ss.dims[d].sched_dim for ss in group)
        stmt_set = {ss.stmt.index for ss in group}
        par = self.sched.stmt_parallel_at_set(stmt_set, sd)
        innermost = all(self._innermost_linear(ss, d) for ss in group)
        # omp-parallel only on OUTERMOST loops: a parallel region inside a
        # hot nest pays fork/join per outer iteration (measured ~60 µs of
        # constant overhead on trsmL when emitted at depth 2)
        if (self.omp and par and not self._parallel_emitted and not innermost
                and self.indent == 1):
            self._emit("#pragma omp parallel for")
            self._parallel_emitted = True
        if self.omp and par and innermost:
            self._emit("#pragma omp simd")
            for ss in group:
                self.vectorized_stmts.add(ss.stmt.index)
        self._emit(f"for (int {y} = {lo_src}; {y} <= {hi_src}; {y}++) {{")
        self.indent += 1
        body_start = len(self.lines)
        self._gen_level(group, d + 1, n_dims, new_guards)
        if len(self.lines) == body_start:
            self._emit(";")
        self.indent -= 1
        self._emit("}")

    def _bound_c(self, bounds: List[Affine], lower: bool) -> str:
        terms = []
        for e in bounds:
            body, den = _affine_src(e)
            terms.append(_ceild_c(body, den) if lower else _floord_c(body, den))
        uniq = sorted(set(terms))
        return _fold("MAXI" if lower else "MINI", uniq)

    def _emit_leaf(self, ss, guard_exprs):
        s = ss.stmt
        scanner = self._scanners[s.index]
        sub_src = {}
        guard_exprs = list(guard_exprs)
        for it, expr in scanner.subst.items():
            body, den = _affine_src(expr)
            if den != 1:
                sub_src[it] = _floord_c(body, den)
                guard_exprs.append(f"(({body}) % {den}) == 0")
            else:
                sub_src[it] = body
        body = _c_body(s.body, sub_src)
        if guard_exprs:
            self._emit("if (" + " && ".join(guard_exprs) + ") {")
            self.indent += 1
            self._emit(body + ";")
            self.indent -= 1
            self._emit("}")
        else:
            self._emit(body + ";")


def _c_body(body: str, sub_src: Dict[str, str]) -> str:
    """Rewrite ``A[i,j]`` → ``A[(i)][(j)]`` and substitute iterators."""
    out = []
    pos = 0
    for m in _ACCESS.finditer(body):
        out.append(_substitute_body(body[pos:m.start()], sub_src))
        arr = m.group(1)
        subs = _split_subscripts(m.group(2))
        csubs = "".join(f"[{_substitute_body(t.strip(), sub_src)}]" for t in subs)
        out.append(f"{arr}{csubs}")
        pos = m.end()
    out.append(_substitute_body(body[pos:], sub_src))
    return "".join(out)
