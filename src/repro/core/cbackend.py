"""C backend: emit a self-contained PolyBench-style C program for a
schedule (kernel + deterministic init + timing + checksum).

The Python/numpy backend (codegen.py) is the correctness oracle; this
backend is the *measurement* path for the paper's CPU experiments
(§IV-B/C/D): gcc -O3 -march=native applies real SIMD vectorization and
real cache behaviour.  Both emitters walk the same schedule-tree IR
(:mod:`repro.core.schedtree`): loop separation, FM bounds and the
``parallel`` marks are computed once at tree construction; this class
only renders C syntax.  ``parallel``-marked bands get ``#pragma omp
parallel for`` (outermost / wavefront-inner only) and parallel innermost
bands ``#pragma omp simd`` (this container has one core, so omp-parallel
speedups are structural — documented in EXPERIMENTS.md; SIMD + locality
effects are real).

Concrete parameter values are baked in as compile-time constants,
exactly like PolyBench reference harnesses — and the tree for this
backend is built with that concrete context (``concrete=True``), which
is what collapses tiled/wavefronted MINI/MAXI bound chains.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .affine import Affine
from .codegen import CodeGenerator, _affine_src, _substitute_body, _yvar
from .polyhedron import maximum, minimum
from .schedtree import (BandNode, LeafNode, ScanStmt, ScheduleTree,
                        render_affine)
from .scheduler import Schedule
from .scop import Scop, _ACCESS, _split_subscripts

# Identifiers the generated program may not (re)declare: C keywords, the
# libc/libm names pulled in by the emitted #includes (math.h's Bessel
# functions y0/y1/yn/j0/j1/jn are the classic PolyBench trap — `mvt`'s
# vector y1 collides), and the harness's own symbols.  SCoP arrays or
# scalars with these names are transparently renamed in the C output.
_C_RESERVED = frozenset("""
auto break case char const continue default do double else enum extern
float for goto if inline int long register restrict return short signed
sizeof static struct switch typedef union unsigned void volatile while
y0 y1 yn j0 j1 jn gamma lgamma tgamma exp exp2 expm1 log log2 log10
log1p sqrt cbrt pow sin cos tan asin acos atan atan2 sinh cosh tanh
fabs fmod floor ceil round trunc erf erfc hypot fma fmin fmax nan
remainder copysign nearbyint rint ilogb logb frexp ldexp modf signbit
abs div rand srand exit free malloc calloc realloc abort atexit system
getenv atof atoi atol qsort bsearch labs ldiv printf scanf puts getchar
putchar fopen fclose remove rename tmpfile fflush stdin stdout stderr
time clock difftime mktime asctime ctime gmtime localtime strftime
main init_arrays checksum cksum_ secs warm REPEATS MINI MAXI floord
ceild
""".split())


def _ceild_c(num: str, den: int) -> str:
    return num if den == 1 else f"ceild({num}, {den})"


def _floord_c(num: str, den: int) -> str:
    return num if den == 1 else f"floord({num}, {den})"


def _fold(fn: str, terms: List[str]) -> str:
    out = terms[0]
    for t in terms[1:]:
        out = f"{fn}({out}, {t})"
    return out


def array_extents(scop: Scop) -> Dict[str, List[int]]:
    """Numeric extent of each array dim = 1 + max subscript value over all
    accesses (with the SCoP's concrete parameter values)."""
    ctx = scop.param_rows()
    ext: Dict[str, List[int]] = {a: [0] * r for a, r in scop.arrays.items()}
    for s in scop.statements:
        cons = list(s.domain) + ctx
        for acc in s.accesses:
            for d, sub in enumerate(acc.subscripts):
                hi = maximum(cons, sub)
                lo = minimum(cons, sub)
                if hi is None:   # empty domain
                    continue
                if lo is not None and lo < 0:
                    raise ValueError(f"negative subscript for {acc.array} in S{s.index}")
                ext[acc.array][d] = max(ext[acc.array][d], int(hi) + 1)
    return ext


def init_arrays(scop: Scop, seed: int = 0) -> Dict[str, "object"]:
    """Deterministic numpy inputs for the differential harnesses (the
    oracle/test/chaos helpers all share this so they cannot drift).

    Default: small positive noise.  Per-array ``scop.np_init``
    overrides apply where the default is numerically unsound — e.g.
    cholesky needs a symmetric positive-definite input or its oracle
    takes ``sqrt`` of negative intermediates and fills the output with
    NaNs (which ``assert_allclose`` happily matches NaN-to-NaN,
    silently voiding the comparison)."""
    import numpy as np

    ext = array_extents(scop)
    r = np.random.default_rng(seed)
    out: Dict[str, "object"] = {}
    for a, dims in ext.items():
        shape = tuple(max(d, 1) for d in dims)
        arr = r.standard_normal(shape) * 0.1 + 1.0
        override = scop.np_init.get(a)
        if override is not None:
            arr = np.asarray(override(shape, r), dtype=float)
            if arr.shape != shape:
                raise ValueError(
                    f"np_init[{a!r}] returned shape {arr.shape}, "
                    f"wanted {shape}")
        out[a] = arr
    return out


class CCodeGenerator(CodeGenerator):
    #: bake concrete parameter values into the FM bound-pruning context
    #: (they are #defines in the emitted program)
    CONCRETE = True

    def __init__(self, sched: Schedule, scan: Optional[List[ScanStmt]] = None,
                 scalars: Optional[Dict[str, float]] = None,
                 omp: bool = True, repeats: int = 1,
                 func_name: Optional[str] = None,
                 tree: Optional[ScheduleTree] = None):
        super().__init__(sched, scan=scan, vectorize=False,
                         func_name=func_name, tree=tree)
        self.scalars = dict(scalars or {})
        self.omp = omp
        self.repeats = repeats
        self._parallel_emitted = False
        self._cname = self._rename_map()

    def _rename_map(self) -> Dict[str, str]:
        """C-safe name for every array/scalar (identity unless reserved).
        Parameters are emitted as ``#define`` and appear verbatim in
        bound expressions everywhere — renaming them is not supported,
        so a reserved parameter name fails loudly instead of producing a
        cryptic macro-expansion gcc error."""
        for p in self.params:
            if p in _C_RESERVED:
                raise ValueError(
                    f"SCoP parameter {p!r} collides with a C/libm "
                    f"identifier; rename the parameter")
        taken = set(self.scop.arrays) | set(self.scop.scalars) | set(self.params)
        out: Dict[str, str] = {}
        for name in list(self.scop.arrays) + list(self.scop.scalars):
            if name in _C_RESERVED:
                new = name + "_pt"
                while new in taken or new in _C_RESERVED:
                    new += "_"
                taken.add(new)
                out[name] = new
        return out

    # -- program ----------------------------------------------------------
    def generate(self) -> str:
        scop = self.scop
        self.lines = []
        self.indent = 0
        self._bands = {}
        self._loop_depth = 0
        self._parallel_emitted = False
        ext = array_extents(scop)
        e = self._emit
        e("#include <stdio.h>")
        e("#include <stdlib.h>")
        e("#include <math.h>")
        e("#include <time.h>")
        e("#define floord(n,d) (((n)<0) ? -((-(n)+(d)-1)/(d)) : (n)/(d))")
        e("#define ceild(n,d)  (((n)<0) ? -((-(n))/(d)) : ((n)+(d)-1)/(d))")
        e("#define MINI(a,b)   (((a)<(b)) ? (a) : (b))")
        e("#define MAXI(a,b)   (((a)>(b)) ? (a) : (b))")
        for p, v in scop.params.items():
            e(f"#define {p} {v}")
        cn = lambda name: self._cname.get(name, name)
        for sc, v in self.scalars.items():
            e(f"static const double {cn(sc)} = {v!r};")
        for a, dims in ext.items():
            dd = "".join(f"[{max(d,1)}]" for d in dims)
            e(f"static double {cn(a)}{dd};")
        e("")
        e("static void init_arrays(void) {")
        self.indent += 1
        for a, dims in ext.items():
            idx = [f"i{k}" for k in range(len(dims))]
            for k, d in enumerate(dims):
                e("    " * k + f"for (int {idx[k]} = 0; {idx[k]} < {max(d,1)}; {idx[k]}++)")
            expr = " + ".join(f"{ix}*{7 + 6 * k}" for k, ix in enumerate(idx)) or "0"
            sub = "".join(f"[{ix}]" for ix in idx)
            init = scop.c_init.get(
                a, f"((double)(({expr} + 3) % 251)) / 251.0 + 0.1"
            )
            e("    " * len(dims) + f"{cn(a)}{sub} = {init};")
        self.indent -= 1
        e("}")
        e("")
        e("static double checksum(void) {")
        self.indent += 1
        e("double cksum_ = 0.0;")
        for a, dims in ext.items():
            idx = [f"i{k}" for k in range(len(dims))]
            for k, d in enumerate(dims):
                e("    " * k + f"for (int {idx[k]} = 0; {idx[k]} < {max(d,1)}; {idx[k]}++)")
            sub = "".join(f"[{ix}]" for ix in idx)
            e("    " * len(dims) + f"cksum_ += {cn(a)}{sub} * (1.0 + 0.0001*(({' + '.join(idx) if idx else '0'}) % 17));")
        e("return cksum_;")
        self.indent -= 1
        e("}")
        e("")
        e(f"static void {self.func_name}(void) {{")
        self.indent += 1
        self._walk(self.tree.root)
        self.indent -= 1
        e("}")
        e("")
        e(f"#define REPEATS {self.repeats}")
        e("int main(void) {")
        self.indent += 1
        e("init_arrays();")
        e(f"{self.func_name}();  /* warmup + correctness */")
        e("double warm = checksum();")
        e("init_arrays();")
        e("struct timespec t0, t1;")
        e("clock_gettime(CLOCK_MONOTONIC, &t0);")
        e(f"for (int r = 0; r < REPEATS; r++) {self.func_name}();")
        e("clock_gettime(CLOCK_MONOTONIC, &t1);")
        e("double secs = (t1.tv_sec - t0.tv_sec) + 1e-9*(t1.tv_nsec - t0.tv_nsec);")
        e('printf("TIME_S %.9f CHECKSUM %.9e\\n", secs / REPEATS, warm);')
        e("return 0;")
        self.indent -= 1
        e("}")
        return "\n".join(self.lines)

    # -- loop emission (C syntax + pragmas from the tree's marks) -----------
    def _emit_band(self, node: BandNode):
        self._bands[node.dim] = node
        y = _yvar(node.dim)
        lo_src, hi_src = self._band_bounds(node)
        # omp-parallel only on OUTERMOST loops: a parallel region inside a
        # hot nest pays fork/join per outer iteration (measured ~60 µs of
        # constant overhead on trsmL when emitted at depth 2).  Wavefront
        # tile counters are the exception — their parallelism only exists
        # under the sequential wave loop.
        if (self.omp and node.parallel and not self._parallel_emitted
                and not node.innermost
                and (self._loop_depth == 0 or node.role == "wave_par")):
            self._emit("#pragma omp parallel for")
            self._parallel_emitted = True
        if self.omp and node.parallel and node.innermost:
            self._emit("#pragma omp simd")
            for s in node.stmts:
                self.vectorized_stmts.add(s)
        self._emit(f"for (int {y} = {lo_src}; {y} <= {hi_src}; {y}++) {{")
        self.indent += 1
        self._loop_depth += 1
        body_start = len(self.lines)
        self._walk(node.child)
        if len(self.lines) == body_start:
            self._emit(";")
        self._loop_depth -= 1
        self.indent -= 1
        self._emit("}")

    def _render_bound(self, bounds: List[Affine], lower: bool) -> str:
        terms = []
        for e in bounds:
            body, den = render_affine(e)
            terms.append(_ceild_c(body, den) if lower else _floord_c(body, den))
        if not terms:
            raise ValueError("unbounded loop dimension")
        uniq = sorted(set(terms))
        return _fold("MAXI" if lower else "MINI", uniq)

    def _fold_group(self, terms: List[str], lower: bool) -> str:
        return _fold("MINI" if lower else "MAXI", terms)

    def _emit_leaf(self, leaf: LeafNode):
        s = self.scop.statements[leaf.stmt]
        guard_exprs = self._band_guards(leaf)
        sub_src = {}
        for it, expr in self.tree.subst[s.index].items():
            body, den = _affine_src(expr)
            if den != 1:
                sub_src[it] = _floord_c(body, den)
                guard_exprs.append(f"(({body}) % {den}) == 0")
            else:
                sub_src[it] = body
        for old, new in self._cname.items():
            sub_src.setdefault(old, new)     # reserved-name scalars
        body = _c_body(s.body, sub_src, self._cname)
        if guard_exprs:
            self._emit("if (" + " && ".join(guard_exprs) + ") {")
            self.indent += 1
            self._emit(body + ";")
            self.indent -= 1
            self._emit("}")
        else:
            self._emit(body + ";")


def _c_body(body: str, sub_src: Dict[str, str],
            rename: Optional[Dict[str, str]] = None) -> str:
    """Rewrite ``A[i,j]`` → ``A[(i)][(j)]`` and substitute iterators;
    ``rename`` maps reserved array names to their C-safe spelling."""
    out = []
    pos = 0
    rename = rename or {}
    for m in _ACCESS.finditer(body):
        out.append(_substitute_body(body[pos:m.start()], sub_src))
        arr = m.group(1)
        subs = _split_subscripts(m.group(2))
        csubs = "".join(f"[{_substitute_body(t.strip(), sub_src)}]" for t in subs)
        out.append(f"{rename.get(arr, arr)}{csubs}")
        pos = m.end()
    out.append(_substitute_body(body[pos:], sub_src))
    return "".join(out)
