"""Wire layer shared by the schedd daemon and its clients.

Everything both ends of a scheduling-service connection must agree on
lives here, so the two cannot drift:

* **Framing** — length-prefixed frames (``MAGIC | uint32 length |
  body [| 32-byte MAC]``) over a stream socket.  The body is either
  JSON (handshake control frames — safe to parse from an untrusted
  peer) or pickle (post-handshake request/response frames).

* **The handshake** — every connection opens with a JSON ``hello``
  carrying :data:`PROTOCOL_VERSION` plus the three cache-compatibility
  versions; a stale peer on either side is rejected with a typed
  ``version_skew`` before any pickle is exchanged.  Over TCP the hello
  continues into an HMAC-SHA256 challenge–response (both directions
  prove knowledge of the shared key over fresh nonces), and the rest of
  the connection carries per-frame MAC tags keyed by a per-connection
  session key.  See :func:`client_handshake` / :func:`server_handshake`.

* **The trust boundary** — pickle is only ever decoded from a peer
  that has already been authenticated (TCP: the challenge–response
  succeeded AND the frame's MAC verifies; Unix socket: the 0o600
  socket directory restricts peers to the same user).  Pre-auth frames
  are JSON, capped at :data:`PRE_AUTH_MAX_FRAME_BYTES`, so an
  unauthenticated peer can neither execute a pickle payload nor make
  the daemon allocate :data:`MAX_FRAME_BYTES` per connection.

* **Typed errors** — the exception family mirroring the daemon's
  wire-level error kinds (re-exported by :mod:`repro.core.schedclient`
  for compatibility).

This module must stay cheap to import: it is reachable from ``akg``'s
plan hook on every compile.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import pickle
import socket
import struct
from typing import Any, Dict, Optional, Tuple, Union

#: bump on any incompatible change to the frame format or message
#: shapes.  v2: JSON handshake frames, optional HMAC auth + per-frame
#: MACs (TCP), pre-auth frame cap.
PROTOCOL_VERSION = 2
MAGIC = b"PTSD"
_HEADER = struct.Struct(">I")
HEADER_LEN = len(MAGIC) + _HEADER.size
#: hard cap on a single post-auth frame — a garbage length prefix must
#: not make either side try to allocate gigabytes
MAX_FRAME_BYTES = 64 << 20
#: cap on a frame from a peer that has not completed the handshake —
#: hello/challenge/auth are tiny JSON, so an unauthenticated TCP peer
#: can make us buffer at most this much
PRE_AUTH_MAX_FRAME_BYTES = 64 << 10
#: HMAC-SHA256 tag appended to every post-handshake frame on an
#: authenticated connection
MAC_LEN = 32

#: environment variable naming the daemon's Unix socket; unset → none
SOCKET_ENV = "POLYTOPS_SCHEDD_SOCK"
#: environment variable naming the daemon address — either a Unix
#: socket path or ``host:port``; takes precedence over ``SOCKET_ENV``
ADDR_ENV = "POLYTOPS_SCHEDD_ADDR"
#: environment variable holding the shared TCP auth key (any string)
KEY_ENV = "POLYTOPS_SCHEDD_KEY"

_S2C_LABEL = b"polytops-schedd-s2c-v2"
_C2S_LABEL = b"polytops-schedd-c2s-v2"
_SESSION_LABEL = b"polytops-schedd-session-v2"


def wire_versions() -> Dict[str, int]:
    """The four versions exchanged in the handshake.  Imported lazily:
    this module is reachable from ``akg`` and must stay cheap to load."""
    from .autotune import SPACE_VERSION
    from .schedcache import CACHE_VERSION
    from .schedtree import TREE_VERSION

    return {"proto": PROTOCOL_VERSION, "cache": CACHE_VERSION,
            "tree": TREE_VERSION, "space": SPACE_VERSION}


def version_skew(theirs: Dict[str, Any]) -> Optional[str]:
    """Human-readable mismatch description, or None when compatible."""
    ours = wire_versions()
    bad = [f"{k}: ours={ours[k]} theirs={theirs.get(k)!r}"
           for k in ours if theirs.get(k) != ours[k]]
    return "; ".join(bad) or None


# ---------------------------------------------------------------------------
# typed errors (re-exported by schedclient)
# ---------------------------------------------------------------------------


class SchedClientError(RuntimeError):
    """Base of every typed daemon-communication error."""


class DaemonUnavailable(SchedClientError):
    """No daemon: socket missing, connection refused/reset, timeout."""


class ProtocolError(SchedClientError):
    """Malformed wire data: bad magic, truncated frame, unpicklable
    payload, or a ``bad_frame``/``bad_request`` response."""


class Overloaded(SchedClientError):
    """The daemon load-shed this request (typed ``overloaded`` reply)."""


class VersionSkew(SchedClientError):
    """Handshake rejected: the peer runs incompatible cache/tree/space
    versions.  Not transient — the breaker opens immediately."""


class AuthFailed(SchedClientError):
    """The HMAC handshake or a per-frame MAC failed: wrong or missing
    shared key, tampered frame, or an unauthenticated peer on a TCP
    transport.  Not transient — retrying with the same key cannot
    help, so the breaker opens immediately."""


class RemoteError(SchedClientError):
    """The daemon failed serving the request (typed ``internal`` /
    ``deadline`` reply); carries the wire error kind."""

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"daemon error [{kind}]"
                         + (f": {detail}" if detail else ""))
        self.kind = kind
        self.detail = detail


class WorkerCrashed(RemoteError):
    """A daemon pool worker died (or wedged) computing this request,
    twice — the daemon already retried once on a fresh worker.  The
    daemon itself is healthy; the request is the likely poison, so the
    client falls back in-process rather than hammering the pool."""

    def __init__(self, detail: str = ""):
        super().__init__("worker_crashed",
                         detail or "pool worker died computing the request")


class IdleTimeout(Exception):
    """Internal: a recv timed out at a clean frame boundary with zero
    bytes read — an idle keep-alive connection, not a slow-loris.  The
    daemon closes these quietly instead of counting a stalled peer."""


def response_error(resp: Dict[str, Any]) -> SchedClientError:
    """Map a ``{"ok": False, ...}`` response to its typed exception."""
    kind = str(resp.get("error", "internal"))
    detail = str(resp.get("detail", ""))
    if kind == "overloaded":
        return Overloaded(detail or "daemon load-shed the request")
    if kind == "version_skew":
        return VersionSkew(detail or "incompatible peer versions")
    if kind == "auth_failed":
        return AuthFailed(detail or "authentication failed")
    if kind in ("bad_frame", "bad_request"):
        return ProtocolError(f"{kind}: {detail}")
    if kind == "worker_crashed":
        return WorkerCrashed(detail)
    return RemoteError(kind, detail)


# ---------------------------------------------------------------------------
# addresses + keys
# ---------------------------------------------------------------------------

#: a parsed daemon address: ("unix", path) or ("tcp", (host, port))
Address = Tuple[str, Any]


def parse_address(addr: str) -> Address:
    """``host:port`` → a TCP address; anything else is a Unix socket
    path.  A path is never mistaken for ``host:port``: the TCP form
    requires a numeric port and no path separator."""
    if ":" in addr and os.sep not in addr:
        host, _, port = addr.rpartition(":")
        if host and port.isdigit():
            return ("tcp", (host, int(port)))
    return ("unix", addr)


def is_tcp_address(addr: Optional[str]) -> bool:
    return addr is not None and parse_address(addr)[0] == "tcp"


def load_key(keyfile: Optional[str] = None,
             env: Optional[str] = None) -> Optional[bytes]:
    """The shared auth key: an explicit keyfile wins, else
    ``$POLYTOPS_SCHEDD_KEY`` (or ``env`` when given).  None when
    neither is configured — the caller decides whether that is fatal
    (it is, for any TCP endpoint)."""
    if keyfile:
        with open(keyfile, "rb") as f:
            key = f.read().strip()
        if not key:
            raise ValueError(f"keyfile {keyfile!r} is empty")
        return key
    val = env if env is not None else os.environ.get(KEY_ENV)
    if val:
        return val.encode() if isinstance(val, str) else val
    return None


def normalize_key(key: Union[str, bytes, None]) -> Optional[bytes]:
    if key is None:
        return None
    return key.encode() if isinstance(key, str) else bytes(key)


# ---------------------------------------------------------------------------
# MAC session
# ---------------------------------------------------------------------------


def _tag(key: bytes, label: bytes, *parts: bytes) -> bytes:
    mac = hmac.new(key, label, hashlib.sha256)
    for p in parts:
        mac.update(p)
    return mac.digest()


def derive_session_key(key: bytes, client_nonce: bytes,
                       server_nonce: bytes) -> bytes:
    return _tag(key, _SESSION_LABEL, client_nonce, server_nonce)


class Session:
    """Per-connection MAC state after a successful handshake.

    Every post-handshake frame carries
    ``HMAC-SHA256(session_key, dir || seq || body)`` where ``dir`` is a
    direction byte (client→server vs server→client) and ``seq`` a
    per-direction monotonically increasing counter — so a frame cannot
    be replayed, reordered, or reflected within the connection, and a
    body is never unpickled before its tag verifies."""

    __slots__ = ("key", "send_dir", "recv_dir", "send_seq", "recv_seq")

    CLIENT_DIR = b"C"
    SERVER_DIR = b"S"

    def __init__(self, key: bytes, *, is_client: bool):
        self.key = key
        self.send_dir = self.CLIENT_DIR if is_client else self.SERVER_DIR
        self.recv_dir = self.SERVER_DIR if is_client else self.CLIENT_DIR
        self.send_seq = 0
        self.recv_seq = 0

    def _frame_tag(self, direction: bytes, seq: int, body: bytes) -> bytes:
        return _tag(self.key, b"frame", direction,
                    struct.pack(">Q", seq), body)

    def sign(self, body: bytes) -> bytes:
        tag = self._frame_tag(self.send_dir, self.send_seq, body)
        self.send_seq += 1
        return tag

    def verify(self, body: bytes, tag: bytes) -> None:
        want = self._frame_tag(self.recv_dir, self.recv_seq, body)
        self.recv_seq += 1
        if not hmac.compare_digest(want, tag):
            raise AuthFailed(
                f"frame MAC mismatch (recv seq {self.recv_seq - 1})")


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def _encode_body(obj: Any, json_codec: bool) -> bytes:
    if json_codec:
        return json.dumps(obj, sort_keys=True,
                          separators=(",", ":")).encode()
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


def encode_frame(obj: Any, *, json_codec: bool = False,
                 session: Optional[Session] = None) -> bytes:
    body = _encode_body(obj, json_codec)
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame too large: {len(body)} B")
    frame = MAGIC + _HEADER.pack(len(body)) + body
    if session is not None:
        frame += session.sign(body)
    return frame


def send_frame(sock: socket.socket, obj: Any, *, json_codec: bool = False,
               session: Optional[Session] = None) -> None:
    sock.sendall(encode_frame(obj, json_codec=json_codec, session=session))


def _recv_exact(sock: socket.socket, n: int, *, eof_ok: bool,
                idle_ok: bool = False) -> Optional[bytes]:
    """Exactly ``n`` bytes, or None on clean EOF at a frame boundary
    (``eof_ok``).  EOF mid-read is always a truncated frame.  With
    ``idle_ok``, a recv timeout before the *first* byte raises
    :class:`IdleTimeout` (an idle keep-alive connection) instead of
    ``socket.timeout`` (a mid-frame stall — a slow-loris)."""
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if idle_ok and not buf:
                raise IdleTimeout() from None
            raise
        if not chunk:
            if not buf and eof_ok:
                return None
            raise ProtocolError(
                f"truncated frame: got {len(buf)} of {n} bytes before EOF")
        buf += chunk
    return buf


def recv_frame(sock: socket.socket, *, eof_ok: bool = False,
               max_bytes: int = MAX_FRAME_BYTES, json_codec: bool = False,
               session: Optional[Session] = None,
               idle_ok: bool = False) -> Any:
    """One decoded frame; None on clean EOF when ``eof_ok``.  Raises
    :class:`ProtocolError` on garbage (bad magic, oversized length,
    truncation, undecodable body) and :class:`AuthFailed` on a MAC
    mismatch — never anything untyped.  On an authenticated session the
    MAC is verified *before* the body is unpickled."""
    head = _recv_exact(sock, HEADER_LEN, eof_ok=eof_ok, idle_ok=idle_ok)
    if head is None:
        return None
    if head[:len(MAGIC)] != MAGIC:
        raise ProtocolError(f"bad magic {head[:len(MAGIC)]!r}")
    (length,) = _HEADER.unpack(head[len(MAGIC):])
    if length > max_bytes:
        raise ProtocolError(f"frame length {length} exceeds {max_bytes} cap")
    body = _recv_exact(sock, length, eof_ok=False)
    assert body is not None
    if session is not None:
        tag = _recv_exact(sock, MAC_LEN, eof_ok=False)
        assert tag is not None
        session.verify(body, tag)     # raises AuthFailed before any decode
    try:
        if json_codec:
            obj = json.loads(body.decode())
        else:
            obj = pickle.loads(body)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:
        raise ProtocolError(f"undecodable frame body: "
                            f"{type(e).__name__}: {e}") from e
    if json_codec and not isinstance(obj, dict):
        raise ProtocolError(
            f"handshake frame is {type(obj).__name__}, not an object")
    return obj


# ---------------------------------------------------------------------------
# the handshake
# ---------------------------------------------------------------------------
#
# Unix socket (peers gated by 0o600 file permissions, like PR 7):
#
#     C → S   {"op": "hello", proto/cache/tree/space, "nonce": hex}
#     S → C   {"ok": true, "op": "hello", "pid": ..., versions...}
#     ... pickle frames, no MAC ...
#
# TCP (a shared key is mandatory — the daemon refuses to listen
# without one):
#
#     C → S   {"op": "hello", versions..., "nonce": c}        (JSON)
#     S → C   {"ok": true, "op": "challenge", "nonce": s,
#              "mac": HMAC(key, s2c-label || c || s)}         (JSON)
#     C → S   {"op": "auth", "mac": HMAC(key, c2s-label || s || c)}
#     S → C   {"ok": true, "op": "hello", "authed": true, ...} (JSON)
#     ... pickle frames, each MAC-tagged with the session key ...
#
# The server proves key knowledge first (its challenge MAC covers both
# nonces), so a client never authenticates to an impostor; the client's
# response covers the nonces in the opposite order under a different
# label, so neither side's MAC can be reflected back.  Version skew is
# rejected before the challenge: a stale peer never gets far enough to
# exchange pickles, with or without the key.


def client_handshake(sock: socket.socket, hello: Dict[str, Any], *,
                     key: Optional[bytes] = None
                     ) -> Tuple[Dict[str, Any], Optional[Session]]:
    """Run the client side of the handshake.  ``hello`` must carry the
    versions (see :func:`wire_versions`); a nonce is added here.
    Returns ``(hello_response, session)`` — session is None on an
    unauthenticated (Unix) transport.  Raises the typed error family
    on any failure."""
    client_nonce = os.urandom(16)
    hello = dict(hello, nonce=client_nonce.hex())
    send_frame(sock, hello, json_codec=True)
    resp = recv_frame(sock, json_codec=True,
                      max_bytes=PRE_AUTH_MAX_FRAME_BYTES)
    if resp is None:
        raise ProtocolError("daemon closed during handshake")
    if not resp.get("ok"):
        raise response_error(resp)
    if resp.get("op") != "challenge":
        return resp, None             # unauthenticated transport: done
    if key is None:
        raise AuthFailed("daemon requires authentication but no key is "
                         f"configured (set ${KEY_ENV} or pass key=)")
    try:
        server_nonce = bytes.fromhex(str(resp.get("nonce", "")))
        server_mac = bytes.fromhex(str(resp.get("mac", "")))
    except ValueError as e:
        raise ProtocolError(f"malformed challenge: {e}") from e
    if len(server_nonce) < 8:
        raise ProtocolError("malformed challenge: short nonce")
    want = _tag(key, _S2C_LABEL, client_nonce, server_nonce)
    if not hmac.compare_digest(want, server_mac):
        raise AuthFailed("server failed the challenge (key mismatch)")
    send_frame(sock, {"op": "auth",
                      "mac": _tag(key, _C2S_LABEL, server_nonce,
                                  client_nonce).hex()},
               json_codec=True)
    final = recv_frame(sock, json_codec=True,
                       max_bytes=PRE_AUTH_MAX_FRAME_BYTES)
    if final is None:
        raise ProtocolError("daemon closed during auth")
    if not final.get("ok"):
        raise response_error(final)
    session = Session(derive_session_key(key, client_nonce, server_nonce),
                      is_client=True)
    return final, session


def server_handshake(conn: socket.socket, hello: Dict[str, Any], *,
                     key: Optional[bytes], require_auth: bool,
                     hello_ok: Dict[str, Any]) -> Optional[Session]:
    """Run the server side of the handshake *after* the hello frame has
    been received and version-checked by the caller.  Sends either the
    plain hello-ok (Unix) or the challenge/auth exchange (TCP).
    Returns the MAC session (None when unauthenticated).  Raises
    :class:`AuthFailed` on bad credentials — after sending the typed
    ``auth_failed`` reply, so the caller only has to close."""
    if not require_auth:
        send_frame(conn, dict(hello_ok), json_codec=True)
        return None
    assert key is not None, "TCP listener started without a key"
    try:
        client_nonce = bytes.fromhex(str(hello.get("nonce", "")))
    except ValueError:
        client_nonce = b""
    if len(client_nonce) < 8:
        send_frame(conn, {"ok": False, "error": "auth_failed",
                          "detail": "hello carries no usable nonce"},
                   json_codec=True)
        raise AuthFailed("hello carries no usable nonce")
    server_nonce = os.urandom(16)
    send_frame(conn, {"ok": True, "op": "challenge",
                      "nonce": server_nonce.hex(),
                      "mac": _tag(key, _S2C_LABEL, client_nonce,
                                  server_nonce).hex()},
               json_codec=True)
    reply = recv_frame(conn, json_codec=True,
                       max_bytes=PRE_AUTH_MAX_FRAME_BYTES, eof_ok=True)
    if reply is None:
        raise AuthFailed("peer hung up at the challenge")
    try:
        client_mac = bytes.fromhex(str(reply.get("mac", "")))
    except ValueError:
        client_mac = b""
    want = _tag(key, _C2S_LABEL, server_nonce, client_nonce)
    if reply.get("op") != "auth" or not hmac.compare_digest(want,
                                                            client_mac):
        send_frame(conn, {"ok": False, "error": "auth_failed",
                          "detail": "bad credentials"}, json_codec=True)
        raise AuthFailed("peer failed the challenge")
    send_frame(conn, dict(hello_ok, authed=True), json_codec=True)
    return Session(derive_session_key(key, client_nonce, server_nonce),
                   is_client=False)
