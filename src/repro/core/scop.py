"""SCoP (Static Control Part) representation + builder DSL.

A SCoP is the scheduler's input: statements with iteration domains,
affine array accesses and an original (2d+1-style) schedule encoded by
loop nesting + textual order (beta vectors). The paper consumes
OpenScop/isl objects produced by Clan; here SCoPs are built
programmatically with a small context-manager DSL:

    k = Scop("gemm", params={"N": 512})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "N"):
            k.stmt("C[i,j] = C[i,j] * beta")
            with k.loop("k", 0, "N"):
                k.stmt("C[i,j] = C[i,j] + alpha * A[i,k] * B[k,j]")

Accesses (reads/writes) are parsed out of the statement body text:
``Name[aff, aff, ...]`` on the LHS of ``=`` is the write, everything on
the RHS (plus LHS re-reads for ``x = x + ...`` forms) are reads.
Non-subscripted names that are not iterators/parameters are scalars
(treated as read-only runtime constants; scalar *writes* are declared
explicitly via ``scalar_out``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from fractions import Fraction
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .affine import Affine, affine_sub, parse_affine

# ---------------------------------------------------------------------------
# Constraint rows: affine dicts over iterator/param names (+ const key 1),
# meaning expr >= 0 (or == 0 for equalities).
# ---------------------------------------------------------------------------


@dataclass
class Access:
    array: str
    subscripts: List[Affine]  # one affine map per array dimension
    is_write: bool

    def __repr__(self):
        from .affine import affine_to_str

        idx = ",".join(affine_to_str(s) for s in self.subscripts)
        rw = "W" if self.is_write else "R"
        return f"{rw}:{self.array}[{idx}]"


@dataclass
class Statement:
    index: int
    name: str
    iters: List[str]                 # surrounding loop iterators, outer→inner
    domain: List[Tuple[Affine, str]]  # constraints over iters+params ('>=0'/'==0')
    body: str                        # executable text, e.g. "C[i,j] = ..."
    accesses: List[Access]
    beta: List[int]                  # textual position vector, len == len(iters)+1
    loop_ids: List[int]              # AST identity of surrounding loops

    @property
    def dim(self) -> int:
        return len(self.iters)

    def writes(self) -> List[Access]:
        return [a for a in self.accesses if a.is_write]

    def reads(self) -> List[Access]:
        return [a for a in self.accesses if not a.is_write]

    def __repr__(self):
        return f"S{self.index}<{self.body[:40]}>"


@dataclass
class Loop:
    loop_id: int
    iterator: str
    lower: Affine   # it >= lower  →  it - lower >= 0
    upper: Affine   # it < upper   →  upper - 1 - it >= 0


class Scop:
    def __init__(self, name: str, params: Optional[Dict[str, int]] = None,
                 param_min: int = 1):
        self.name = name
        self.params: Dict[str, int] = dict(params or {})  # name -> concrete size
        self.param_min = param_min  # assumed lower bound for parametric analysis
        self.statements: List[Statement] = []
        self.arrays: Dict[str, int] = {}   # array -> rank
        self.scalars: List[str] = []
        self.loops: Dict[int, Loop] = {}   # loop_id -> Loop (bounds registry)
        # optional per-array init override for harnesses: C expression over
        # indices i0, i1, ... (e.g. diagonally-dominant input for cholesky)
        self.c_init: Dict[str, str] = {}
        # numpy-side counterpart for the differential oracles: array name
        # -> callable(shape, rng) -> ndarray (this module stays numpy-free;
        # cbackend.init_arrays consults it)
        self.np_init: Dict[str, Callable] = {}
        self._stack: List[Loop] = []
        self._counters: List[int] = [0]    # textual position counters per depth
        self._next_loop_id = 0

    # -- DSL ----------------------------------------------------------------
    def loop(self, iterator: str, lower, upper) -> "_LoopCtx":
        return _LoopCtx(self, iterator, lower, upper)

    def stmt(self, body: str, name: Optional[str] = None) -> Statement:
        iters = [l.iterator for l in self._stack]
        domain: List[Tuple[Affine, str]] = []
        for l in self._stack:
            domain.append((affine_sub({l.iterator: Fraction(1)}, l.lower), ">=0"))
            up = dict(l.upper)
            up[1] = up.get(1, Fraction(0)) - 1
            domain.append((affine_sub(up, {l.iterator: Fraction(1)}), ">=0"))
        accesses = _parse_accesses(body, iters, list(self.params))
        beta = self._counters[: len(iters) + 1][:]
        s = Statement(
            index=len(self.statements),
            name=name or f"S{len(self.statements)}",
            iters=iters,
            domain=domain,
            body=body.strip(),
            accesses=accesses,
            beta=beta,
            loop_ids=[l.loop_id for l in self._stack],
        )
        self.statements.append(s)
        self._counters[len(iters)] += 1
        for a in accesses:
            r = self.arrays.get(a.array)
            if r is None:
                self.arrays[a.array] = len(a.subscripts)
            elif r != len(a.subscripts):
                raise ValueError(f"array {a.array} used with ranks {r} and {len(a.subscripts)}")
        for nm in _scalar_names(body, iters, list(self.params), set(self.arrays)):
            if nm not in self.scalars:
                self.scalars.append(nm)
        return s

    # -- queries -------------------------------------------------------------
    def common_loops(self, s: Statement, r: Statement) -> int:
        n = 0
        for a, b in zip(s.loop_ids, r.loop_ids):
            if a != b:
                break
            n += 1
        return n

    def textually_before(self, s: Statement, r: Statement) -> bool:
        n = self.common_loops(s, r)
        return s.beta[: n + 1] < r.beta[: n + 1] or (
            s.beta[: n + 1] == r.beta[: n + 1] and s.index < r.index
        )

    def param_names(self) -> List[str]:
        return list(self.params)

    def param_rows(self) -> List[Tuple[Affine, str]]:
        """Concrete-parameter equality rows (``p == value``) — the LP
        context shared by array-extent computation, cache-model extent
        estimation, and C-backend bound pruning."""
        return [({p: Fraction(1), 1: Fraction(-v)}, "==0")
                for p, v in self.params.items()]

    def param_min_rows(self) -> List[Tuple[Affine, str]]:
        """Parametric lower-bound rows (``p >= param_min``) — the
        context for dependence analysis and the Python oracle's bound
        pruning, where parameters stay symbolic."""
        return [({p: Fraction(1), 1: Fraction(-self.param_min)}, ">=0")
                for p in self.params]

    def __repr__(self):
        return f"Scop({self.name}, {len(self.statements)} stmts, params={self.params})"


class _LoopCtx:
    def __init__(self, scop: Scop, iterator: str, lower, upper):
        self.scop = scop
        lo = lower if isinstance(lower, dict) else parse_affine(str(lower))
        up = upper if isinstance(upper, dict) else parse_affine(str(upper))
        self.loop = Loop(scop._next_loop_id, iterator, lo, up)
        scop.loops[self.loop.loop_id] = self.loop
        scop._next_loop_id += 1

    def __enter__(self):
        s = self.scop
        s._stack.append(self.loop)
        depth = len(s._stack)
        if len(s._counters) <= depth:
            s._counters.append(0)
        else:
            s._counters[depth] = 0
        return self.loop

    def __exit__(self, *exc):
        s = self.scop
        depth = len(s._stack)
        s._stack.pop()
        s._counters[depth - 1] += 1
        # reset deeper counters
        del s._counters[depth + 1:]
        return False


# ---------------------------------------------------------------------------
# Access parsing
# ---------------------------------------------------------------------------

_ACCESS = re.compile(r"([A-Za-z_][A-Za-z_0-9]*)\s*\[((?:[^\[\]]|\[[^\]]*\])*)\]")
_NAME = re.compile(r"[A-Za-z_][A-Za-z_0-9]*")
_FUNCS = {"sqrt", "abs", "min", "max", "exp", "log", "pow", "floor", "SCALAR_VAL"}


def _split_subscripts(text: str) -> List[str]:
    parts, depth, cur = [], 0, ""
    for ch in text:
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            if ch == "[":
                depth += 1
            elif ch == "]":
                depth -= 1
            cur += ch
    parts.append(cur)
    return parts


def _parse_accesses(body: str, iters: Sequence[str], params: Sequence[str]) -> List[Access]:
    if "=" not in body:
        raise ValueError(f"statement body must be an assignment: {body!r}")
    # split on the first top-level '=' that isn't ==, <=, >=, !=
    eq = _find_assign(body)
    lhs, rhs = body[:eq], body[eq + 1:]
    accesses: List[Access] = []
    lhs_accs = list(_ACCESS.finditer(lhs))
    if len(lhs_accs) != 1:
        raise ValueError(f"LHS must be exactly one array access: {lhs!r}")
    m = lhs_accs[0]
    write = Access(m.group(1), [parse_affine(s) for s in _split_subscripts(m.group(2))], True)
    accesses.append(write)
    for m in _ACCESS.finditer(rhs):
        accesses.append(
            Access(m.group(1), [parse_affine(s) for s in _split_subscripts(m.group(2))], False)
        )
    return accesses


def _find_assign(body: str) -> int:
    depth = 0
    i = 0
    while i < len(body):
        ch = body[i]
        if ch in "[(":
            depth += 1
        elif ch in "])":
            depth -= 1
        elif ch == "=" and depth == 0:
            prev = body[i - 1] if i else ""
            nxt = body[i + 1] if i + 1 < len(body) else ""
            if prev not in "<>=!" and nxt != "=":
                return i
        i += 1
    raise ValueError(f"no assignment in {body!r}")


def _scalar_names(body: str, iters, params, arrays) -> List[str]:
    out = []
    for m in _NAME.finditer(body):
        nm = m.group(0)
        if nm in iters or nm in params or nm in arrays or nm in _FUNCS or nm in out:
            continue
        # skip names immediately followed by '(' (function calls) or '[' (arrays)
        rest = body[m.end():].lstrip()
        if rest[:1] in ("(", "["):
            continue
        out.append(nm)
    return out
