"""The PolyTOPS iterative scheduler — paper Algorithm 1.

Finds Θ dimension by dimension (outermost → innermost). Each dimension
is either a *scalar* dimension (loop distribution: constant per
statement, from the fusion configuration / SCC fallback) or a *linear*
dimension solved as an ILP:

  validity  (Eq. 2, Farkas-linearized)     — always
  progression (Eq. 3, orthogonal complement) — always
  cost stages (config: proximity/feautrier/contiguity/BLF/custom vars)
  custom constraints + directives (dropped if they break legality)

Band bookkeeping matches Pluto: all dependences not strongly satisfied
before the current band are weakly enforced (φ_R − φ_S ≥ 0) at every
dimension of the band, which makes bands fully permutable (→ tilable in
post-processing). On ILP failure the band is cut (satisfied dependences
removed) and the dimension retried; if that fails too, statements are
distributed by SCCs; if a single SCC remains, the scheduler falls back
to the original program order (paper §IV-B: nussinov/adi/deriche
behaviour without negative coefficients).

Incremental architecture (compile-time hot path)
------------------------------------------------

Scheduling runs per-kernel inside the compiler (AKG integration), so the
solver pipeline is built to amortize everything that repeats:

* **Per-band base problems** (``_base_problem``): the schedule-coefficient
  variables and the legality Farkas rows of the band's active dependences
  are compiled once per band; each dimension pushes only its own rows
  (completed-statement pinning, cost bounding for unsatisfied deps,
  progression, directives) and pops them after the solve.
* **Memoized Farkas expansions** (``costs.cached_farkas``): a dependence's
  linearization is dimension-independent, so dimension k+1 replays the
  expansion computed at dimension k.
* **Per-component ILP decomposition** (``_ilp_components``): one ILP per
  connected component of the active dependence graph.  Components share
  no constraints and every objective stage is a sum of per-component
  terms, so the merged per-component lexmins equal the monolithic lexmin;
  components coupled through proximity's shared bounding coefficients
  u/w are merged to keep this exact.  Custom constraints / user
  variables force the monolithic problem.
* **Compiled dependence polyhedra** (``deps.compiled_poly``): distance /
  satisfaction queries reuse per-dependence LP matrices, with an
  affine-hull reduction that answers constant-distance queries with no
  LP at all.
* **Incremental lexmin** (``ilp.ILPProblem.lexmin`` → the exact
  rational simplex in ``lexsimplex``): append-only fixing rows on one
  live tableau, warm-start stage skipping, exact (uncapped) weighted
  combination of the box-bounded integer tail stages, and a canonical
  tie-break over the schedule coefficients that makes the chosen
  optimum unique — seed path ≡ incremental path ≡ repeat runs,
  bit-for-bit, on every kernel×strategy combination (the
  golden-schedule CI gate).

``incremental=False`` reproduces the seed pipeline end to end and is the
baseline of ``benchmarks/bench_scheduler.py`` (≈3–4x geomean win).
Repeat scheduling of the same kernel shape is a structural-cache lookup
(``repro.core.schedcache``).
"""
from __future__ import annotations

import re
import time
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from . import costs as C
from .affine import Affine, parse_constraint
from .config import DimConfig, Directive, SchedulerConfig
from .deps import Dependence, compiled_poly, compute_dependences, dep_distance_max, dep_distance_min, dep_distance_range, phi_difference
from .farkas import add_farkas_nonneg
from .ilp import ILPProblem, Unbounded
from .linalg_q import orth_complement_basis
from .resilience import fault_point
from .scop import Scop, Statement


@dataclass
class ScheduleRow:
    kind: str                      # 'linear' | 'scalar'
    coeffs: Dict[Tuple, Fraction]  # ('it',k) / ('par',p) / ('cst',) -> value

    def it_vector(self, dim: int) -> List[int]:
        return [int(self.coeffs.get(("it", k), 0)) for k in range(dim)]

    def cst(self) -> int:
        return int(self.coeffs.get(("cst",), 0))


@dataclass
class Schedule:
    scop: Scop
    rows: Dict[int, List[ScheduleRow]]        # stmt index -> rows per dim
    bands: List[int]                          # band id per dim
    parallel: List[bool]                      # per dim: zero-distance for all
    seq_marked: Set[Tuple[int, int]] = field(default_factory=set)
    vector_iter: Dict[int, int] = field(default_factory=dict)  # stmt -> iter idx
    dropped_directives: List[Directive] = field(default_factory=list)
    fallback: bool = False
    deps: List[Dependence] = field(default_factory=list)
    stats: Dict[str, Any] = field(default_factory=dict)
    # degradation-ladder provenance (repro.core.resilience): a clean
    # schedule is level 0; faults/deadline breaches step the ladder down
    # and record why.  Read through resilience.provenance() — cached
    # pickles from older format versions may lack the fields.
    degraded: bool = False
    fallback_level: int = 0
    degrade_reasons: List[str] = field(default_factory=list)

    @property
    def n_dims(self) -> int:
        return len(self.bands)

    def theta(self, stmt: Statement) -> List[ScheduleRow]:
        return self.rows[stmt.index]

    def it_matrix(self, stmt: Statement) -> List[List[int]]:
        return [r.it_vector(stmt.dim) for r in self.rows[stmt.index] if r.kind == "linear"]

    def pretty(self) -> str:
        out = []
        params = self.scop.param_names()
        for s in self.scop.statements:
            terms = []
            for r in self.rows[s.index]:
                if r.kind == "scalar":
                    terms.append(str(r.cst()))
                else:
                    bits = []
                    for k, it in enumerate(s.iters):
                        c = int(r.coeffs.get(("it", k), 0))
                        if c == 1:
                            bits.append(it)
                        elif c:
                            bits.append(f"{c}{it}")
                    for p in params:
                        c = int(r.coeffs.get(("par", p), 0))
                        if c:
                            bits.append(f"{c}{p}" if c != 1 else p)
                    c = r.cst()
                    if c or not bits:
                        bits.append(str(c))
                    terms.append("+".join(bits).replace("+-", "-"))
            out.append(f"S{s.index}: [{', '.join(terms)}]   # {s.body[:48]}")
        out.append(f"bands={self.bands} parallel={self.parallel}")
        return "\n".join(out)

    def innermost_linear_dim(self, stmt: Statement) -> Optional[int]:
        rr = self.rows[stmt.index]
        for d in range(len(rr) - 1, -1, -1):
            if rr[d].kind == "linear" and any(v != 0 for v in rr[d].it_vector(stmt.dim)):
                return d
        return None

    def stmt_parallel_at(self, stmt: Statement, dim: int) -> bool:
        """True if executing dim `dim` in parallel/vector fashion is legal
        for `stmt` alone: every dependence touching stmt that is not
        strongly satisfied at an *outer* dim has zero distance at `dim`."""
        return self.stmt_parallel_at_set({stmt.index}, dim)

    def stmt_parallel_at_set(self, stmt_set, dim: int) -> bool:
        """Parallel-execution legality of dim `dim` for a loop containing
        exactly the statements in `stmt_set`: every dependence with BOTH
        endpoints in the set, not strongly satisfied at an outer dim, must
        have zero distance at `dim`."""
        params = self.scop.param_names()
        for dep in self.deps:
            if dep.source.index not in stmt_set or dep.target.index not in stmt_set:
                continue
            if dep.satisfied_at is not None and dep.satisfied_at < dim:
                continue
            rs = self.rows[dep.source.index][dim].coeffs
            rt = self.rows[dep.target.index][dim].coeffs
            lo, hi = dep_distance_range(dep, rs, rt, params)
            if lo != 0 or hi != 0:
                return False
        return True


class SchedulingError(Exception):
    pass


@dataclass
class StrategyState:
    """State handed to the Python strategy callback (the paper's C++
    interface analogue): inspect anything, return a DimConfig."""
    dim: int
    band: int
    band_start: bool
    parallel_failed: bool
    scop: Scop
    rows: Dict[int, List[ScheduleRow]]
    active_deps: List[Dependence]
    completed: Set[int]


class PolyTOPSScheduler:
    def __init__(self, scop: Scop, config: Optional[SchedulerConfig] = None,
                 deps: Optional[List[Dependence]] = None, engine: str = "lex",
                 incremental: bool = True, decompose: bool = True,
                 record_stage_values: bool = False,
                 deadline: Optional["Deadline"] = None):
        self.scop = scop
        self.config = config or SchedulerConfig()
        self.deps = deps if deps is not None else compute_dependences(scop)
        self.engine = engine
        # wall-clock budget (resilience.Deadline), checked at dimension
        # boundaries and before every ILP solve; None → never expires
        self.deadline = deadline
        self._partial: Optional[Tuple] = None
        # incremental=False reproduces the seed pipeline end to end
        # (clone-per-lexmin dense ILPs, no Farkas memoization, no compiled
        # dependence polyhedra) — kept for benchmarking and differential
        # tests.  decompose=False forces one monolithic ILP per dimension.
        self.incremental = incremental
        self.decompose = decompose and incremental
        self._farkas_cache: Optional[Dict[Tuple, Any]] = {} if incremental else None
        self._base_probs: Dict[Tuple, Any] = {}
        self._fusion_applied: Set[int] = set()
        # opt-in (differential tests): exact per-dim stage objective
        # values in stats — off on the production path, where nothing
        # reads them
        self.record_stage_values = record_stage_values
        self.params = scop.param_names()
        self.stats: Dict[str, Any] = {
            "ilp_solves": 0, "ilp_time": 0.0,
            "components": 0, "lex_stages_skipped": 0, "lex_pivots": 0,
        }

    def _want_order(self, stmts) -> List[str]:
        """The canonical variable order for lexmin tie-breaking AND the
        set of variables materialized from solutions.  Identical in the
        seed and incremental paths — together with the exact engine's
        canonicalization this makes the chosen optimum a pure function
        of the mathematical problem, not of the pipeline."""
        want: List[str] = []
        for s in stmts:
            want += [C.t_it(s, k) for k in range(s.dim)]
            want += [C.t_par(s, p) for p in self.params]
            want.append(C.t_cst(s))
        return want

    # -- public -------------------------------------------------------------
    def schedule(self) -> Schedule:
        t0 = time.time()
        scop, cfg = self.scop, self.config
        stmts = scop.statements
        self._base_probs.clear()
        self._fusion_applied: Set[int] = set()
        for d in self.deps:
            d.satisfied_at = None
        active: List[Dependence] = list(self.deps)
        H: Dict[int, List[List[Fraction]]] = {s.index: [] for s in stmts}
        rows: Dict[int, List[ScheduleRow]] = {s.index: [] for s in stmts}
        bands: List[int] = []
        parallel: List[bool] = []
        band = 0
        band_start = True
        dropped: List[Directive] = []
        directives = self._expand_directives()
        vector_iter = {d.stmts[0]: d.iterator for d in directives
                       if d.type == "vectorize" and d.iterator is not None}
        seq_marked: Set[Tuple[int, int]] = set()
        max_dims = 2 * max((s.dim for s in stmts), default=1) + 3 + len(stmts)
        dim = 0
        # live references for partial-prefix salvage: rows/bands/parallel
        # are mutated in place only at completed-dimension boundaries, so
        # the ladder can recover everything solved before a fault
        self._partial = (rows, bands, parallel, seq_marked, vector_iter,
                         dropped)

        def completed() -> Set[int]:
            return {s.index for s in stmts if len(H[s.index]) >= s.dim}

        while dim < max_dims:
            if self.deadline is not None:
                self.deadline.check(f"scheduler dim {dim}")
            comp = completed()
            if len(comp) == len(stmts):
                # progression exhausted — remaining (equal-date) dependences
                # are ordered by the final textual scalar dimension and
                # verified in _verify_remaining.
                break

            # ---- distribution step (Algorithm 1 lines 8-14) -------------
            groups = self._distribution_groups(dim, active, comp, band_start)
            if groups is not None and len(groups) > 1:
                self._check_groups_legal(groups, active)
                self._emit_scalar(rows, groups)
                self._mark_scalar_satisfied(groups, active, dim)
                bands.append(band)
                parallel.append(False)
                active = [d for d in active if d.satisfied_at is None]
                band += 1
                band_start = True
                dim += 1
                continue

            # ---- ILP step (lines 16-30) ----------------------------------
            state = StrategyState(dim, band, band_start, False, scop, rows,
                                  list(active), comp)
            dc = cfg.dim_config(dim, state if cfg.strategy else None)
            sol = None
            attempts: List[Tuple[DimConfig, bool]] = [(dc, True)]
            if dc.require_parallel:
                state2 = StrategyState(dim, band, band_start, True, scop, rows,
                                       list(active), comp)
                dc_fb = cfg.dim_config(dim, state2 if cfg.strategy else None)
                if cfg.strategy is None:
                    dc_fb = DimConfig(cost_functions=["feautrier"])
                attempts.append((dc_fb, True))
            attempts.append((attempts[-1][0], False))  # drop directives

            for cand, with_dirs in attempts:
                sol = self._solve_dim(cand, active, comp, H, dim, directives,
                                      vector_iter, with_dirs, band_start)
                if sol is not None:
                    if not with_dirs:
                        dropped.extend(d for d in directives if d.type == "vectorize")
                        directives = [d for d in directives if d.type != "vectorize"]
                        vector_iter = {}
                    break

            if sol is None:
                # cut band, retry (lines 23-30)
                if any(d.satisfied_at is not None for d in active):
                    active = [d for d in active if d.satisfied_at is None]
                    band += 1
                    band_start = True
                    continue
                # SCC distribution (lines 32-36) — only if it makes progress
                # (at least one unsatisfied dependence crosses groups)
                sccs = _scc_groups(stmts, active)
                if len(sccs) > 1 and self._distribution_progress(sccs, active):
                    self._check_groups_legal(sccs, active)
                    self._emit_scalar(rows, sccs)
                    self._mark_scalar_satisfied(sccs, active, dim)
                    bands.append(band)
                    parallel.append(False)
                    active = [d for d in active if d.satisfied_at is None]
                    band += 1
                    band_start = True
                    dim += 1
                    continue
                return self._fallback_original()

            # record the linear dimension
            for s in stmts:
                row = ScheduleRow("linear", sol[s.index])
                rows[s.index].append(row)
                itv = [Fraction(sol[s.index].get(("it", k), 0)) for k in range(s.dim)]
                if any(itv) and len(H[s.index]) < s.dim:
                    H[s.index].append(itv)
            # satisfaction + parallelism bookkeeping (max-side LP only
            # when the min side leaves parallelism possible)
            is_par = True
            for dep in active:
                rs = sol[dep.source.index]
                rt = sol[dep.target.index]
                lo = dep_distance_min(dep, rs, rt, self.params,
                                      cache=self.incremental)
                if dep.satisfied_at is None and lo is not None and lo >= 1:
                    dep.satisfied_at = dim
                if dep.satisfied_at is None or dep.satisfied_at == dim:
                    if lo != 0:
                        is_par = False
                    elif is_par:
                        hi = dep_distance_max(dep, rs, rt, self.params,
                                              cache=self.incremental)
                        if hi != 0:
                            is_par = False
            # honor explicit 'sequential' directives in the report
            for dv in directives:
                if dv.type == "sequential":
                    for si in dv.stmts:
                        seq_marked.add((si, dim))
            bands.append(band)
            parallel.append(is_par)
            band_start = False
            dim += 1

        sched = Schedule(scop, rows, bands, parallel, seq_marked, vector_iter,
                         dropped, False, self.deps, dict(self.stats))
        if not self._append_final_order(sched):
            # remaining equal-date dependences are cyclic across
            # statements: no scalar ordering exists → original schedule
            # (paper §IV-B fallback behaviour)
            return self._fallback_original()
        self._verify_remaining(active, sched)
        self.stats["time"] = time.time() - t0
        sched.stats = dict(self.stats)
        return sched

    # -- distribution -------------------------------------------------------
    def _distribution_groups(self, dim, active, comp, band_start):
        fspec = self.config.fusion_for(dim)
        stmts = self.scop.statements
        # an explicit FusionSpec is a *one-shot* distribution decision:
        # once its scalar dimension is emitted the spec must not fire
        # again (a 'default'-dimension spec would otherwise re-distribute
        # at every subsequent dim, emitting scalar dims until max_dims
        # with no linear progression at all)
        if fspec is not None and id(fspec) in self._fusion_applied:
            fspec = None
        if fspec is not None:
            if fspec.groups is not None:
                self._fusion_applied.add(id(fspec))
                covered = {i for g in fspec.groups for i in g}
                groups = [list(g) for g in fspec.groups]
                for s in stmts:
                    if s.index not in covered:
                        groups.append([s.index])
                return groups
            if fspec.total_distribution:
                self._fusion_applied.add(id(fspec))
                return _scc_groups(stmts, active)
        if dim == 0 and self.config.fusion_mode != "max" and len(stmts) > 1:
            sccs = _scc_groups(stmts, active)
            if self.config.fusion_mode == "no":
                return sccs
            # smart fuse: merge adjacent SCCs with equal loop dimensionality
            merged: List[List[int]] = []
            for g in sccs:
                gdim = max(stmts[i].dim for i in g)
                if merged and max(stmts[i].dim for i in merged[-1]) == gdim:
                    merged[-1].extend(g)
                else:
                    merged.append(list(g))
            return merged
        return None

    def _distribution_progress(self, groups, active) -> bool:
        pos = {}
        for gi, g in enumerate(groups):
            for si in g:
                pos[si] = gi
        return any(
            d.satisfied_at is None and pos[d.source.index] < pos[d.target.index]
            for d in active
        )

    def _check_groups_legal(self, groups, active):
        pos = {}
        for gi, g in enumerate(groups):
            for si in g:
                pos[si] = gi
        for dep in active:
            if dep.satisfied_at is not None:
                continue
            if pos[dep.source.index] > pos[dep.target.index]:
                raise SchedulingError(
                    f"fusion/distribution config violates dependence {dep}"
                )

    def _emit_scalar(self, rows, groups):
        pos = {}
        for gi, g in enumerate(groups):
            for si in g:
                pos[si] = gi
        for s in self.scop.statements:
            rows[s.index].append(ScheduleRow("scalar", {("cst",): Fraction(pos[s.index])}))

    def _mark_scalar_satisfied(self, groups, active, dim):
        pos = {}
        for gi, g in enumerate(groups):
            for si in g:
                pos[si] = gi
        for dep in active:
            if dep.satisfied_at is None and pos[dep.source.index] < pos[dep.target.index]:
                dep.satisfied_at = dim

    # -- the per-dimension ILP ----------------------------------------------
    def _ilp_components(self, active, dc: DimConfig) -> Optional[List[List[int]]]:
        """Connected components of the active dependence graph (undirected),
        or None when a single monolithic ILP is required.

        Statements in different components share no validity/cost
        constraints — every constraint row is induced by a dependence or
        is per-statement (progression, bounds, tail) — and every
        objective stage is a sum of per-component terms (proximity's
        bounding coefficients u/w become per-component instances), so
        solving the components independently and merging is exact: the
        lexmin of a separable objective over a product feasible set is
        the product of the per-component lexmins."""
        if not self.decompose:
            return None
        # custom constraints / user variables may couple arbitrary
        # statements → stay monolithic
        if self.config.new_variables or dc.constraints:
            return None
        stmts = self.scop.statements
        parent = {s.index: s.index for s in stmts}

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for d in active:
            a, b = find(d.source.index), find(d.target.index)
            if a != b:
                parent[a] = b
        groups: Dict[int, List[int]] = {}
        for s in stmts:
            groups.setdefault(find(s.index), []).append(s.index)
        if len(groups) <= 1:
            return None
        out = [sorted(g) for g in sorted(groups.values(), key=min)]
        if "proximity" in dc.cost_functions:
            # proximity's bounding coefficients u/w are shared by every
            # unsatisfied dependence: merge all components that contain
            # one, so the decomposition stays exact wrt the monolithic
            # lexmin (components without unsat deps contribute only
            # per-statement/per-dep terms and stay separate)
            unsat_stmts = {d.source.index for d in active if d.satisfied_at is None}
            unsat_stmts |= {d.target.index for d in active if d.satisfied_at is None}
            coupled = [g for g in out if any(i in unsat_stmts for i in g)]
            if len(coupled) > 1:
                rest = [g for g in out if not any(i in unsat_stmts for i in g)]
                merged = sorted(i for g in coupled for i in g)
                out = sorted(rest + [merged], key=min)
        if len(out) <= 1:
            return None
        return out

    def _solve_dim(self, dc: DimConfig, active, comp, H, dim, directives,
                   vector_iter, with_directives, band_start):
        groups = self._ilp_components(active, dc)
        if groups is None:
            return self._solve_dim_group(None, dc, active, comp, H, dim,
                                         directives, vector_iter,
                                         with_directives, band_start)
        out: Dict[int, Dict[Tuple, Fraction]] = {}
        self.stats["components"] += len(groups)
        for g in groups:
            gset = set(g)
            gdeps = [d for d in active if d.source.index in gset]
            if len(g) == 1 and g[0] in comp and not gdeps:
                # completed isolated statement: T_it is pinned to zero and
                # the tail stages drive T_par/T_cst to their lower bound 0
                # — the unique lexmin, no LP needed
                out[g[0]] = {}
                continue
            sub = self._solve_dim_group(gset, dc, gdeps, comp, H, dim,
                                        directives, vector_iter,
                                        with_directives, band_start)
            if sub is None:
                # one infeasible component makes the monolithic problem
                # infeasible too (disjoint constraint systems)
                return None
            out.update(sub)
        return out

    def _base_problem(self, group, stmts, active, feautrier_mode):
        """Per-band persistent base ILP: schedule-coefficient variables +
        legality Farkas rows for the band's active dependences.

        Those rows are identical for every dimension of a band (the
        active set only changes on band cuts / distribution, which change
        the key), so the compiled float matrices are built once per band;
        each dimension pushes only its own rows (completed pinning, cost
        bounding for still-unsatisfied deps, progression, directives) and
        pops them after the solve."""
        scop, cfg = self.scop, self.config
        gkey = None if group is None else tuple(sorted(group))
        key = (gkey, tuple(d.id for d in active), feautrier_mode)
        entry = self._base_probs.get(key)
        if entry is not None:
            return entry
        # bound memory without thrashing: evict the oldest entry only
        # (a band of a many-component SCoP holds one base per group)
        if len(self._base_probs) >= 64:
            self._base_probs.pop(next(iter(self._base_probs)))
        prob = ILPProblem(self.engine, incremental=True)
        cb = cfg.coeff_bound
        for s in stmts:
            for k in range(s.dim):
                prob.var(C.t_it(s, k), lb=0, ub=cb, integer=True)
            for p in self.params:
                ub = cb if getattr(cfg, "parametric_shift", False) else 0
                prob.var(C.t_par(s, p), lb=0, ub=ub, integer=True)
            prob.var(C.t_cst(s), lb=0, ub=cfg.cst_bound, integer=True)
        for v in cfg.new_variables:
            prob.ensure_var(v, lb=0, ub=None, integer=True)
        # validity (Eq. 2); deps the feautrier cost covers get their
        # (stronger) farkas rows per-dim instead
        legal_ids: Set[int] = set()
        for dep in active:
            if feautrier_mode and dep.satisfied_at is None:
                continue
            C.cached_farkas(prob, self._farkas_cache, "legality", dep,
                            lambda dep=dep: C.phi_coef_map(dep, self.params),
                            f"lv{dep.id}")
            legal_ids.add(dep.id)
        # canonical tail: small coefficients, no parametric part, prefer
        # the original loop order on ties, small consts
        tp: Affine = {}
        ti: Affine = {}
        to: Affine = {}
        tc: Affine = {}
        for s in stmts:
            for p in self.params:
                tp[C.t_par(s, p)] = Fraction(1)
            for k in range(s.dim):
                ti[C.t_it(s, k)] = Fraction(1)
                to[C.t_it(s, k)] = Fraction(k + 1)
            tc[C.t_cst(s)] = Fraction(1)
        entry = (prob, legal_ids, [tp, ti, to, tc])
        self._base_probs[key] = entry
        return entry

    def _solve_dim_group(self, group, dc: DimConfig, active, comp, H, dim,
                         directives, vector_iter, with_directives, band_start):
        if not self.incremental:
            return self._solve_dim_seed(dc, active, comp, H, dim, directives,
                                        vector_iter, with_directives,
                                        band_start)
        scop, cfg = self.scop, self.config
        stmts = (scop.statements if group is None
                 else [s for s in scop.statements if s.index in group])
        unsat = [d for d in active if d.satisfied_at is None]
        feautrier_mode = "feautrier" in dc.cost_functions

        prob, legal_ids, tail = self._base_problem(group, stmts, active,
                                                   feautrier_mode)
        # feautrier mode: deps strongly satisfied after the base was built
        # now need plain legality — append to the base (persists; the
        # active set, and hence the base key, is unchanged)
        if feautrier_mode:
            for dep in active:
                if dep.satisfied_at is not None and dep.id not in legal_ids:
                    C.cached_farkas(
                        prob, self._farkas_cache, "legality", dep,
                        lambda dep=dep: C.phi_coef_map(dep, self.params),
                        f"lv{dep.id}")
                    legal_ids.add(dep.id)

        mark = prob.push()
        try:
            for s in stmts:
                if s.index in comp:
                    for k in range(s.dim):
                        prob.add({C.t_it(s, k): Fraction(1)}, "==0")

            stages: List[Affine] = []
            for name in dc.cost_functions:
                if name == "proximity":
                    stages += C.setup_proximity(prob, unsat, self.params, dim,
                                                cache=self._farkas_cache)
                elif name == "feautrier":
                    stages += C.setup_feautrier(prob, unsat, self.params, dim,
                                                cache=self._farkas_cache)
                elif name == "contiguity":
                    coeffs = {s.index: C.contiguity_coeffs(s) for s in stmts}
                    obj = C.stage_from_coeffs(stmts, coeffs,
                                              [s.index for s in stmts if s.index not in comp])
                    if obj:
                        stages.append(obj)
                elif name == "bigLoopsFirst":
                    coeffs = {s.index: C.bigloops_coeffs(s, scop) for s in stmts}
                    obj = C.stage_from_coeffs(stmts, coeffs,
                                              [s.index for s in stmts if s.index not in comp])
                    if obj:
                        stages.append(obj)
                elif name in cfg.new_variables:
                    stages.append({name: Fraction(1)})
                else:
                    raise SchedulingError(f"unknown cost function {name!r}")

            # require_parallel (isl-style coincidence): zero distance on
            # unsat deps
            if dc.require_parallel:
                for dep in unsat:
                    C.cached_farkas(
                        prob, self._farkas_cache, "coincidence", dep,
                        lambda dep=dep: C.phi_coef_map(dep, self.params,
                                                       negate=True),
                        f"lc{dep.id}")

            # progression (Eq. 3) — row basis of H⊥ (see linalg_q)
            for s in stmts:
                if s.index in comp:
                    continue
                orth = orth_complement_basis(H[s.index], s.dim)
                total: Affine = {}
                for r in orth:
                    expr: Affine = {}
                    for k in range(s.dim):
                        if r[k]:
                            expr[C.t_it(s, k)] = r[k]
                            total[C.t_it(s, k)] = total.get(C.t_it(s, k), Fraction(0)) + r[k]
                    if expr:
                        prob.add(expr, ">=0")
                if total:
                    total[1] = Fraction(-1)
                    prob.add(total, ">=0")   # Σ H⊥_i · h ≥ 1

            # custom constraints
            for text in dc.constraints:
                for expr, kind in self._expand_custom(text, comp):
                    prob.add(expr, kind)

            # directives
            if with_directives:
                coin_added: Set[int] = set()   # deps already zero-forced
                if dc.require_parallel:
                    coin_added.update(d.id for d in unsat)
                for dv in directives:
                    if dv.type == "vectorize" and dv.iterator is not None:
                        for si in dv.stmts:
                            if group is not None and si not in group:
                                continue
                            s = scop.statements[si]
                            if si in comp or dv.iterator >= s.dim:
                                continue
                            remaining = s.dim - len(H[si])
                            if remaining > 1:
                                prob.add({C.t_it(s, dv.iterator): Fraction(1)}, "==0")
                            else:
                                prob.add({C.t_it(s, dv.iterator): Fraction(1),
                                          1: Fraction(-1)}, "==0")
                    elif dv.type == "parallel" and band_start:
                        for si in dv.stmts:
                            for dep in unsat:
                                if dep.id in coin_added:
                                    continue
                                if dep.source.index == si or dep.target.index == si:
                                    coin_added.add(dep.id)
                                    C.cached_farkas(
                                        prob, self._farkas_cache, "coincidence",
                                        dep,
                                        lambda dep=dep: C.phi_coef_map(
                                            dep, self.params, negate=True),
                                        f"lc{dep.id}")

            want = self._want_order(stmts)

            if self.deadline is not None:
                self.deadline.check("ilp.solve")
            fault_point("ilp.solve")
            t0 = time.time()
            self.stats["ilp_solves"] += 1
            try:
                sol = prob.lexmin(stages + tail, want=want, canon=want)
            except Unbounded:
                sol = None
            self.stats["ilp_time"] += time.time() - t0
            self.stats["lex_stages_skipped"] += prob.stages_skipped
            self.stats["lex_pivots"] += prob.last_pivots
            prob.last_pivots = 0
            if sol is not None and self.record_stage_values:
                from .ilp import stage_values
                self.stats.setdefault("stage_values", []).append(
                    (dim, stage_values(stages, sol)))
        finally:
            prob.pop(mark)
        if sol is None:
            return None
        out: Dict[int, Dict[Tuple, Fraction]] = {}
        for s in stmts:
            coeffs: Dict[Tuple, Fraction] = {}
            for k in range(s.dim):
                v = sol[C.t_it(s, k)]
                if v:
                    coeffs[("it", k)] = v
            for p in self.params:
                v = sol[C.t_par(s, p)]
                if v:
                    coeffs[("par", p)] = v
            v = sol[C.t_cst(s)]
            if v:
                coeffs[("cst",)] = v
            out[s.index] = coeffs
        return out

    def _solve_dim_seed(self, dc: DimConfig, active, comp, H, dim, directives,
                        vector_iter, with_directives, band_start):
        """The seed per-dimension ILP: one monolithic problem rebuilt
        from scratch every dimension, no Farkas memoization, no
        decomposition.  Kept as the benchmarking baseline
        (``incremental=False``).  It shares the exact engine and the
        canonical lexmin tie-break with the incremental path, so both
        must produce bit-identical schedules — a tier-1 invariant."""
        scop, cfg = self.scop, self.config
        stmts = scop.statements
        prob = ILPProblem(self.engine, incremental=False)
        cb = cfg.coeff_bound
        for s in stmts:
            for k in range(s.dim):
                prob.var(C.t_it(s, k), lb=0, ub=cb, integer=True)
            for p in self.params:
                ub = cb if getattr(cfg, "parametric_shift", False) else 0
                prob.var(C.t_par(s, p), lb=0, ub=ub, integer=True)
            prob.var(C.t_cst(s), lb=0, ub=cfg.cst_bound, integer=True)
            if s.index in comp:
                for k in range(s.dim):
                    prob.add({C.t_it(s, k): Fraction(1)}, "==0")
        for v in cfg.new_variables:
            prob.ensure_var(v, lb=0, ub=None, integer=True)

        # validity (Eq. 2) for every active dependence
        unsat = [d for d in active if d.satisfied_at is None]
        feautrier_mode = "feautrier" in dc.cost_functions
        stages: List[Affine] = []
        for name in dc.cost_functions:
            if name == "proximity":
                stages += C.setup_proximity(prob, unsat, self.params, dim)
            elif name == "feautrier":
                stages += C.setup_feautrier(prob, unsat, self.params, dim)
            elif name == "contiguity":
                coeffs = {s.index: C.contiguity_coeffs(s) for s in stmts}
                obj = C.stage_from_coeffs(stmts, coeffs,
                                          [s.index for s in stmts if s.index not in comp])
                if obj:
                    stages.append(obj)
            elif name == "bigLoopsFirst":
                coeffs = {s.index: C.bigloops_coeffs(s, scop) for s in stmts}
                obj = C.stage_from_coeffs(stmts, coeffs,
                                          [s.index for s in stmts if s.index not in comp])
                if obj:
                    stages.append(obj)
            elif name in cfg.new_variables:
                stages.append({name: Fraction(1)})
            else:
                raise SchedulingError(f"unknown cost function {name!r}")
        # plain legality for deps not already covered by feautrier's farkas
        for dep in active:
            if feautrier_mode and dep.satisfied_at is None:
                continue  # feautrier already added φ_R − φ_S − e ≥ 0, e ≥ 0
            coef, const = C.phi_coef_map(dep, self.params)
            add_farkas_nonneg(prob, dep.cons, coef, const, tag="v")

        # require_parallel (isl-style coincidence): zero distance on unsat deps
        if dc.require_parallel:
            for dep in unsat:
                coef, const = C.phi_coef_map(dep, self.params, negate=True)
                add_farkas_nonneg(prob, dep.cons, coef, const, tag="c")

        # progression (Eq. 3) — row basis of H⊥ (see linalg_q)
        for s in stmts:
            if s.index in comp:
                continue
            orth = orth_complement_basis(H[s.index], s.dim)
            total: Affine = {}
            for r in orth:
                expr: Affine = {}
                for k in range(s.dim):
                    if r[k]:
                        expr[C.t_it(s, k)] = r[k]
                        total[C.t_it(s, k)] = total.get(C.t_it(s, k), Fraction(0)) + r[k]
                if expr:
                    prob.add(expr, ">=0")
            if total:
                total[1] = Fraction(-1)
                prob.add(total, ">=0")   # Σ H⊥_i · h ≥ 1

        # custom constraints
        for text in dc.constraints:
            for expr, kind in self._expand_custom(text, comp):
                prob.add(expr, kind)

        # directives
        if with_directives:
            for dv in directives:
                if dv.type == "vectorize" and dv.iterator is not None:
                    for si in dv.stmts:
                        s = stmts[si]
                        if si in comp or dv.iterator >= s.dim:
                            continue
                        remaining = s.dim - len(H[si])
                        if remaining > 1:
                            prob.add({C.t_it(s, dv.iterator): Fraction(1)}, "==0")
                        else:
                            prob.add({C.t_it(s, dv.iterator): Fraction(1),
                                      1: Fraction(-1)}, "==0")
                elif dv.type == "parallel" and band_start:
                    for si in dv.stmts:
                        for dep in unsat:
                            if dep.source.index == si or dep.target.index == si:
                                coef, const = C.phi_coef_map(dep, self.params, negate=True)
                                add_farkas_nonneg(prob, dep.cons, coef, const, tag="d")

        # canonical tail: small coefficients, no parametric part, prefer the
        # original loop order on ties, small consts
        tp: Affine = {}
        ti: Affine = {}
        to: Affine = {}
        tc: Affine = {}
        for s in stmts:
            for p in self.params:
                tp[C.t_par(s, p)] = Fraction(1)
            for k in range(s.dim):
                ti[C.t_it(s, k)] = Fraction(1)
                to[C.t_it(s, k)] = Fraction(k + 1)
            tc[C.t_cst(s)] = Fraction(1)
        tail = [tp, ti, to, tc]
        want = self._want_order(stmts)

        if self.deadline is not None:
            self.deadline.check("ilp.solve")
        fault_point("ilp.solve")
        t0 = time.time()
        self.stats["ilp_solves"] += 1
        try:
            sol = prob.lexmin(stages + tail, want=want, canon=want)
        except Unbounded:
            sol = None
        self.stats["ilp_time"] += time.time() - t0
        self.stats["lex_pivots"] += prob.last_pivots
        prob.last_pivots = 0
        if sol is None:
            return None
        if self.record_stage_values:
            from .ilp import stage_values
            self.stats.setdefault("stage_values", []).append(
                (dim, stage_values(stages, sol)))
        out: Dict[int, Dict[Tuple, Fraction]] = {}
        for s in stmts:
            coeffs: Dict[Tuple, Fraction] = {}
            for k in range(s.dim):
                v = sol[C.t_it(s, k)]
                if v:
                    coeffs[("it", k)] = v
            for p in self.params:
                v = sol[C.t_par(s, p)]
                if v:
                    coeffs[("par", p)] = v
            v = sol[C.t_cst(s)]
            if v:
                coeffs[("cst",)] = v
            out[s.index] = coeffs
        return out

    # -- custom constraint expansion -----------------------------------------
    _CUSTOM = re.compile(r"^S(\d+|i)_(it|par)_(\d+|i)$|^S(\d+|i)_cst$")

    def _expand_custom(self, text: str, comp) -> List[Tuple[Affine, str]]:
        stmts = self.scop.statements
        if text.strip() == "no-skewing":
            out = []
            for s in stmts:
                if s.index in comp:
                    continue
                expr = {C.t_it(s, k): Fraction(-1) for k in range(s.dim)}
                expr[1] = Fraction(1)
                out.append((expr, ">=0"))   # Σ T_it ≤ 1
            return out
        expr, kind = parse_constraint(text)
        mapped: Affine = {}
        for sym, coef in expr.items():
            if sym == 1:
                mapped[1] = mapped.get(1, Fraction(0)) + coef
                continue
            m = self._CUSTOM.match(str(sym))
            if not m:
                if sym in self.config.new_variables:
                    mapped[sym] = mapped.get(sym, Fraction(0)) + coef
                    continue
                raise SchedulingError(f"unknown symbol {sym!r} in custom constraint")
            if m.group(4) is not None:   # S<x>_cst
                sids = range(len(stmts)) if m.group(4) == "i" else [int(m.group(4))]
                for si in sids:
                    key = C.t_cst(stmts[si])
                    mapped[key] = mapped.get(key, Fraction(0)) + coef
            else:
                sids = range(len(stmts)) if m.group(1) == "i" else [int(m.group(1))]
                vt = m.group(2)
                for si in sids:
                    s = stmts[si]
                    if vt == "it":
                        ks = range(s.dim) if m.group(3) == "i" else [int(m.group(3))]
                        for k in ks:
                            if k < s.dim:
                                key = C.t_it(s, k)
                                mapped[key] = mapped.get(key, Fraction(0)) + coef
                    else:
                        ps = (self.params if m.group(3) == "i"
                              else [self.params[int(m.group(3))]])
                        for p in ps:
                            key = C.t_par(s, p)
                            mapped[key] = mapped.get(key, Fraction(0)) + coef
        return [(mapped, kind)]

    # -- directives -----------------------------------------------------------
    def _expand_directives(self) -> List[Directive]:
        out = [Directive(d.type, list(d.stmts), d.iterator) for d in self.config.directives]
        if self.config.auto_vectorize:
            for s in self.scop.statements:
                if any(d.type == "vectorize" and s.index in d.stmts for d in out):
                    continue
                v = _auto_vector_iter(s)
                if v is not None:
                    out.append(Directive("vectorize", [s.index], v))
        # one directive entry per statement simplifies handling
        flat: List[Directive] = []
        for d in out:
            for si in d.stmts:
                flat.append(Directive(d.type, [si], d.iterator))
        return flat

    # -- fallback + verification ----------------------------------------------
    def partial_schedule(self) -> Optional[Schedule]:
        """Degradation rung 1: salvage the legal prefix a failed
        :meth:`schedule` run already solved.

        Every completed dimension is legality-constrained (all active
        dependences weakly satisfied), so any completed prefix followed
        by the program-order suffix (beta scalars interleaved with
        identity dims) is a legal schedule.  The per-dim ILPs decompose
        per SCC, so the prefix carries every SCC result solved before
        the fault.  Returns None when nothing was solved; the result is
        point-wise verified (the salvage path must never publish an
        illegal schedule — verification failure raises and the ladder
        steps down instead)."""
        st = self._partial
        if st is None:
            return None
        rows, bands, parallel, seq_marked, _vec, dropped = st
        n = min((len(rr) for rr in rows.values()), default=0)
        if n == 0:
            return None
        prows = {i: list(rr[:n]) for i, rr in rows.items()}
        pbands = list(bands[:n])
        ppar = list(parallel[:n])
        stmts = self.scop.statements
        maxd = max((s.dim for s in stmts), default=0)
        nb = (max(pbands) + 1) if pbands else 0
        for level in range(maxd + 1):
            for s in stmts:
                b = s.beta[level] if level < len(s.beta) else 0
                prows[s.index].append(
                    ScheduleRow("scalar", {("cst",): Fraction(b)}))
            pbands.append(nb)
            ppar.append(False)
            nb += 1
            if level < maxd:
                for s in stmts:
                    coeffs = ({("it", level): Fraction(1)}
                              if level < s.dim else {})
                    prows[s.index].append(ScheduleRow("linear", coeffs))
                pbands.append(nb)
                ppar.append(False)
                nb += 1
        # conservative marks: directives may have been mid-application
        # when the fault hit, so no vectorization claims survive salvage
        sched = Schedule(self.scop, prows, pbands, ppar, set(seq_marked),
                         {}, list(dropped), True, self.deps,
                         dict(self.stats))
        for dep in self.deps:
            if dep.satisfied_at is not None and dep.satisfied_at >= n:
                dep.satisfied_at = None
        self._verify_remaining([d for d in self.deps
                                if d.satisfied_at is None], sched)
        return sched

    def _fallback_original(self) -> Schedule:
        scop = self.scop
        stmts = scop.statements
        maxd = max((s.dim for s in stmts), default=0)
        rows: Dict[int, List[ScheduleRow]] = {s.index: [] for s in stmts}
        bands: List[int] = []
        parallel: List[bool] = []
        for level in range(maxd + 1):
            for s in stmts:
                b = s.beta[level] if level < len(s.beta) else 0
                rows[s.index].append(ScheduleRow("scalar", {("cst",): Fraction(b)}))
            bands.append(2 * level)
            parallel.append(False)
            if level < maxd:
                sol = {}
                for s in stmts:
                    coeffs = {("it", level): Fraction(1)} if level < s.dim else {}
                    rows[s.index].append(ScheduleRow("linear", coeffs))
                    sol[s.index] = coeffs
                is_par = True
                for dep in self.deps:
                    lo = dep_distance_min(dep, sol[dep.source.index],
                                          sol[dep.target.index], self.params,
                                          cache=self.incremental)
                    if dep.satisfied_at is None and lo is not None and lo >= 1:
                        dep.satisfied_at = len(bands)
                    if dep.satisfied_at is None or dep.satisfied_at == len(bands):
                        if lo != 0:
                            is_par = False
                        elif is_par:
                            hi = dep_distance_max(dep, sol[dep.source.index],
                                                  sol[dep.target.index], self.params,
                                                  cache=self.incremental)
                            if hi != 0:
                                is_par = False
                bands.append(2 * level + 1)
                parallel.append(is_par)
        self.stats["fallback"] = True
        return Schedule(scop, rows, bands, parallel, set(), {}, [], True,
                        self.deps, dict(self.stats))

    def _append_final_order(self, sched: Schedule) -> bool:
        """Final scalar dimension ordering statements at equal linear
        dates. Ordered by the topology of still-unsatisfied dependences
        (NOT plain textual order — backward anti/output deps at equal
        dates would be reversed). Returns False if cyclic."""
        stmts = self.scop.statements
        if len(stmts) < 2:
            return True
        remaining = [d for d in self.deps if d.satisfied_at is None
                     and d.source.index != d.target.index]
        groups = _scc_groups(stmts, remaining)
        if any(len(g) > 1 for g in groups):
            return False
        pos = {g[0]: gi for gi, g in enumerate(groups)}
        for s in stmts:
            sched.rows[s.index].append(
                ScheduleRow("scalar", {("cst",): Fraction(pos[s.index])})
            )
        sched.bands.append(sched.bands[-1] + 1 if sched.bands else 0)
        sched.parallel.append(False)
        return True

    def _verify_remaining(self, active, sched: Schedule) -> None:
        """Safety net: any dependence never strongly satisfied must still be
        lexicographically satisfied point-wise by the full schedule."""
        for dep in active:
            if dep.satisfied_at is not None:
                continue
            if not self._lex_satisfied(dep, sched):
                raise SchedulingError(f"schedule does not satisfy {dep}")
            dep.satisfied_at = sched.n_dims - 1

    def _lex_satisfied(self, dep: Dependence, sched: Schedule) -> bool:
        rows_s = sched.rows[dep.source.index]
        rows_t = sched.rows[dep.target.index]
        cp = compiled_poly(dep, self.params) if self.incremental else None

        def _piece_feasible(extra):
            if cp is not None:
                return cp.feasible_with(extra)
            from .polyhedron import feasible as _feas
            return _feas(list(dep.cons) + list(extra))

        prefix: List[Affine] = []
        for d in range(len(rows_s)):
            diff = phi_difference(dep, rows_s[d].coeffs, rows_t[d].coeffs, self.params)
            # piece: all previous diffs == 0 and this diff <= -1  → must be empty
            neg = {k: -v for k, v in diff.items()}
            neg[1] = neg.get(1, Fraction(0)) - 1
            if _piece_feasible([(p, "==0") for p in prefix] + [(neg, ">=0")]):
                return False
            prefix.append(diff)
        # all-equal piece must be empty too (no unordered equal dates)
        return not _piece_feasible([(p, "==0") for p in prefix])


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _scc_groups(stmts: Sequence[Statement], deps: Sequence[Dependence]) -> List[List[int]]:
    """SCC condensation of the dependence graph, in topological order."""
    adj: Dict[int, Set[int]] = {s.index: set() for s in stmts}
    for d in deps:
        if d.satisfied_at is None and d.source.index != d.target.index:
            adj[d.source.index].add(d.target.index)
    # Tarjan
    index: Dict[int, int] = {}
    low: Dict[int, int] = {}
    on: Set[int] = set()
    stack: List[int] = []
    out: List[List[int]] = []
    counter = [0]

    def strong(v):
        work = [(v, iter(sorted(adj[v])))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on.add(w)
                    work.append((w, iter(sorted(adj[w]))))
                    advanced = True
                    break
                elif w in on:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                out.append(sorted(comp))

    for s in stmts:
        if s.index not in index:
            strong(s.index)
    # Tarjan emits reverse topological order
    out.reverse()
    # stable order among independent SCCs: by textual position
    comp_of = {}
    for ci, comp in enumerate(out):
        for v in comp:
            comp_of[v] = ci
    cadj: Dict[int, Set[int]] = {i: set() for i in range(len(out))}
    for d in deps:
        if d.satisfied_at is None:
            a, b = comp_of[d.source.index], comp_of[d.target.index]
            if a != b:
                cadj[a].add(b)
    # Kahn with min-textual-position tie-break
    indeg = {i: 0 for i in range(len(out))}
    for a, succs in cadj.items():
        for b in succs:
            indeg[b] += 1
    import heapq
    heap = [(min(out[i]), i) for i in range(len(out)) if indeg[i] == 0]
    heapq.heapify(heap)
    order: List[List[int]] = []
    while heap:
        _, i = heapq.heappop(heap)
        order.append(out[i])
        for b in cadj[i]:
            indeg[b] -= 1
            if indeg[b] == 0:
                heapq.heappush(heap, (min(out[b]), b))
    return order


def _auto_vector_iter(stmt: Statement) -> Optional[int]:
    """Paper §III-B2: pick the iterator moving contiguously in memory."""
    best, best_score = None, 0
    for k, it in enumerate(stmt.iters):
        score = 0
        for acc in stmt.accesses:
            if not acc.subscripts:
                continue
            last = acc.subscripts[-1]
            outer = acc.subscripts[:-1]
            c = last.get(it, Fraction(0))
            if abs(c) == 1 and not any(o.get(it) for o in outer):
                score += 3 if acc.is_write else 2
        if score > best_score:
            best, best_score = k, score
    return best


def schedule_scop(scop: Scop, config: Optional[SchedulerConfig] = None,
                  engine: str = "lex", **kwargs) -> Schedule:
    """Schedule a SCoP. Extra kwargs (``incremental``, ``decompose``)
    are forwarded to :class:`PolyTOPSScheduler`."""
    return PolyTOPSScheduler(scop, config, engine=engine, **kwargs).schedule()
