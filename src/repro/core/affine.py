"""Tiny affine-expression parser.

Parses strings like ``"2*i + j - N + 3"`` into {symbol: Fraction} maps
(the constant term is stored under key ``1``). Used for loop bounds,
array subscripts and the paper's custom-constraint interface
(Section III-A2: ``S0_it_1 - x >= 0`` etc.).

Grammar (recursive descent):
  expr   := term (('+'|'-') term)*
  term   := factor ('*' factor)*
  factor := INT | NAME | '-' factor | '(' expr ')'
Products must stay affine: at most one non-constant factor per term.
"""
from __future__ import annotations

import re
from fractions import Fraction
from typing import Dict, Union

Affine = Dict[Union[str, int], Fraction]  # {name: coeff, 1: const}

_TOKEN = re.compile(r"\s*(?:(\d+)|([A-Za-z_][A-Za-z_0-9]*)|(.))")


class _Parser:
    def __init__(self, text: str):
        self.toks = []
        for m in _TOKEN.finditer(text):
            if m.group(1):
                self.toks.append(("int", int(m.group(1))))
            elif m.group(2):
                self.toks.append(("name", m.group(2)))
            elif m.group(3).strip():
                self.toks.append(("op", m.group(3)))
        self.pos = 0

    def peek(self):
        return self.toks[self.pos] if self.pos < len(self.toks) else (None, None)

    def next(self):
        t = self.peek()
        self.pos += 1
        return t

    def parse(self) -> Affine:
        e = self.expr()
        if self.pos != len(self.toks):
            raise ValueError(f"trailing tokens at {self.pos}: {self.toks[self.pos:]}")
        return e

    def expr(self) -> Affine:
        out = self.term()
        while True:
            kind, val = self.peek()
            if kind == "op" and val in "+-":
                self.next()
                rhs = self.term()
                sign = 1 if val == "+" else -1
                for k, v in rhs.items():
                    out[k] = out.get(k, Fraction(0)) + sign * v
            else:
                return out

    def term(self) -> Affine:
        out = self.factor()
        while True:
            kind, val = self.peek()
            if kind == "op" and val == "*":
                self.next()
                rhs = self.factor()
                out = _affine_mul(out, rhs)
            elif kind == "op" and val == "/":
                self.next()
                rhs = self.factor()
                if set(rhs) - {1}:
                    raise ValueError("non-constant divisor in affine expr")
                out = {k: v / rhs.get(1, Fraction(0)) for k, v in out.items()}
            else:
                return out

    def factor(self) -> Affine:
        kind, val = self.next()
        if kind == "int":
            return {1: Fraction(val)}
        if kind == "name":
            return {val: Fraction(1)}
        if kind == "op" and val == "-":
            f = self.factor()
            return {k: -v for k, v in f.items()}
        if kind == "op" and val == "+":
            return self.factor()
        if kind == "op" and val == "(":
            e = self.expr()
            k2, v2 = self.next()
            if (k2, v2) != ("op", ")"):
                raise ValueError("expected ')'")
            return e
        raise ValueError(f"unexpected token {kind} {val}")


def _affine_mul(a: Affine, b: Affine) -> Affine:
    a_syms = set(a) - {1}
    b_syms = set(b) - {1}
    if a_syms and b_syms:
        raise ValueError("non-affine product")
    if b_syms:
        a, b = b, a
    c = b.get(1, Fraction(0))
    return {k: v * c for k, v in a.items()}


def parse_affine(text: str) -> Affine:
    """Parse an affine expression string into {symbol: coeff, 1: const}."""
    out = _Parser(str(text)).parse()
    return {k: v for k, v in out.items() if v != 0} or {1: Fraction(0)}


def affine_add(a: Affine, b: Affine, bsign: int = 1) -> Affine:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, Fraction(0)) + bsign * v
    return {k: v for k, v in out.items() if v != 0}


def affine_sub(a: Affine, b: Affine) -> Affine:
    return affine_add(a, b, -1)


def affine_scale(a: Affine, c) -> Affine:
    c = Fraction(c)
    return {k: v * c for k, v in a.items() if v * c != 0}


def affine_eval(a: Affine, env: Dict[str, Fraction]) -> Fraction:
    tot = Fraction(0)
    for k, v in a.items():
        if k == 1:
            tot += v
        else:
            tot += v * Fraction(env[k])
    return tot


def affine_to_str(a: Affine, order=None) -> str:
    if not a:
        return "0"
    keys = [k for k in (order or sorted(a, key=str)) if k in a and a[k] != 0]
    parts = []
    for k in keys:
        v = a[k]
        if k == 1:
            parts.append(f"{v}")
        elif v == 1:
            parts.append(f"{k}")
        elif v == -1:
            parts.append(f"-{k}")
        else:
            parts.append(f"{v}*{k}")
    s = " + ".join(parts).replace("+ -", "- ")
    return s or "0"


_COMPARE = re.compile(r"(.*?)(<=|>=|==|=|<|>)(.*)")


def parse_constraint(text: str):
    """Parse ``lhs (<=|>=|==|<|>) rhs`` into (affine, kind) with kind in
    {'>=0', '==0'} after normalization to ``affine {>=,==} 0``.

    Strict inequalities are integerized: a > b  →  a - b - 1 >= 0.
    """
    m = _COMPARE.match(text)
    if not m:
        raise ValueError(f"not a constraint: {text!r}")
    lhs, op, rhs = m.group(1), m.group(2), m.group(3)
    diff = affine_sub(parse_affine(lhs), parse_affine(rhs))
    if op in ("==", "="):
        return diff, "==0"
    if op == ">=":
        return diff, ">=0"
    if op == "<=":
        return {k: -v for k, v in diff.items()}, ">=0"
    if op == ">":
        d = dict(diff)
        d[1] = d.get(1, Fraction(0)) - 1
        return d, ">=0"
    if op == "<":
        d = {k: -v for k, v in diff.items()}
        d[1] = d.get(1, Fraction(0)) - 1
        return d, ">=0"
    raise ValueError(op)
