"""Learned static ranker for the autotuner (paper §III-E, LOOPer-style).

The analytic cost model in :mod:`repro.core.autotune` is a hand-built
prior.  This module learns a correction from the *measured*
(kernel, configuration, time) triples the autotuner persists in the
schedule-cache pool (:func:`repro.core.schedcache.record_measurements`):
a ridge regression from cheap static features of a candidate
configuration to its log runtime.  The fitted model replaces the
analytic ranking when enough training data has accumulated, pruning the
enumerated configuration space to the measurable top-k.

Design constraints:

* **Deterministic** — features are exact functions of the SCoP/schedule,
  the closed-form ridge solve has no randomness, and training rows come
  from an append-only JSONL pool in file order.  Re-ranking the same
  kernel against the same pool returns the same order.
* **Within-kernel contrastive** — rows are centered per kernel (both X
  and y) before fitting, so the model learns *which configuration of a
  kernel is faster*, not absolute kernel speed; ranking candidates of
  one kernel is exactly the question the autotuner asks.
* **Graceful** — below :data:`MIN_SAMPLES` usable rows (or on any
  numerical trouble) :func:`fit_ranker` returns None and the autotuner
  keeps the analytic ranking.

Features come from the same primitives as the cache model
(:mod:`repro.core.cachemodel`): tile working sets vs the cache budget,
temporal-reuse weights, band structure, parallel depth.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .cachemodel import (CacheSpec, default_spec, shared_bands,
                         shared_groups, shared_tile_sizes, working_set_bytes)

#: bump when the feature definition changes — rows from an older
#: feature version must not train a newer model
FEATURE_VERSION = 1

FEATURE_NAMES = (
    "log_static_cost",     # the analytic model's opinion (strong prior)
    "log_trip",            # total box-volume iteration estimate
    "n_dims",              # schedule dims
    "n_scalar_dims",       # distribution structure (the fusion axis)
    "par_frac",            # fraction of parallel dims
    "outer_par",           # first linear dim parallel?
    "max_band_len",        # longest permutable band
    "reuse_frac",          # access groups with temporal reuse in band 0
    "log_ws_ratio",        # tile working set / L2 budget (0 when untiled)
    "tiled",
    "wave",
    "autovec",
)

MIN_SAMPLES = 32           # usable rows before the learned model kicks in
RIDGE_LAMBDA = 1.0


def features(scop, sched, tc, static_cost_val: float,
             spec: Optional[CacheSpec] = None,
             trips: Optional[Dict[int, float]] = None,
             memo: Optional[dict] = None) -> List[float]:
    """Feature vector of candidate ``tc`` applied to ``sched`` —
    deterministic and cheap: ``memo`` uses the *same* keys as the
    analytic model (``autotune.static_cost``), so the per-schedule
    scan/bands/groups/tile-size intermediates are computed once per
    schedule across both rankers."""
    spec = spec or default_spec()
    memo = {} if memo is None else memo
    bands = shared_bands(sched, memo)

    n_dims = sched.n_dims
    n_scalar = 0
    for d in range(n_dims):
        if all(sched.rows[s.index][d].kind == "scalar"
               for s in scop.statements):
            n_scalar += 1
    par_frac = (sum(1 for p in sched.parallel if p) / n_dims) if n_dims else 0.0
    outer_par = 0.0
    for d in range(n_dims):
        if any(sched.rows[s.index][d].kind == "linear"
               for s in scop.statements):
            outer_par = 1.0 if sched.parallel[d] else 0.0
            break
    max_band = max((b.length for b in bands), default=0)

    reuse_frac = 0.0
    log_ws_ratio = 0.0
    if bands:
        b = bands[0]
        groups = shared_groups(sched, memo, b.start, b.length)
        if groups:
            reuse_frac = sum(
                1 for g in groups if any(g.reused_by(d) for d in range(b.length))
            ) / len(groups)
        if tc.tile is not None and groups:
            sizes = shared_tile_sizes(sched, memo, tc.tile, spec).get(
                b.start, [32] * b.length)
            ws = working_set_bytes(groups, sizes, spec.elem_bytes)
            log_ws_ratio = math.log(max(ws, 1) / spec.l2_bytes)

    trip_total = sum(trips.values()) if trips else 1.0
    return [
        math.log(max(static_cost_val, 1e-9)),
        math.log(max(trip_total, 1.0)),
        float(n_dims),
        float(n_scalar),
        float(par_frac),
        float(outer_par),
        float(max_band),
        float(reuse_frac),
        float(log_ws_ratio),
        1.0 if tc.tile is not None else 0.0,
        1.0 if tc.wavefront else 0.0,
        1.0 if tc.autovec else 0.0,
    ]


@dataclass
class LearnedRanker:
    """Fitted ridge model: ``score = w · x`` ranks candidates of one
    kernel (lower = predicted faster).  The per-kernel intercept is
    deliberately dropped — it cancels within a kernel."""
    weights: List[float]
    n_rows: int
    n_kernels: int

    def predict(self, feats: Sequence[float]) -> float:
        return float(sum(w * x for w, x in zip(self.weights, feats)))


def fit_ranker(rows: Sequence[dict]) -> Optional[LearnedRanker]:
    """Fit from measurement-pool rows ({kernel, feats, seconds, fv}).

    Rows with the wrong feature version, malformed feature vectors, or
    non-positive times are dropped; kernels with fewer than two rows
    carry no within-kernel contrast and are dropped too.  Returns None
    below :data:`MIN_SAMPLES` usable rows or when the solve fails."""
    import numpy as np

    by_kernel: Dict[str, List[tuple]] = {}
    nf = len(FEATURE_NAMES)
    for r in rows:
        feats = r.get("feats")
        secs = r.get("seconds")
        if (r.get("fv") != FEATURE_VERSION or not isinstance(feats, list)
                or len(feats) != nf or not isinstance(secs, (int, float))
                or not secs or secs <= 0):
            continue
        by_kernel.setdefault(str(r.get("kernel")), []).append(
            (feats, math.log(secs)))
    xs, ys = [], []
    n_kernels = 0
    for rows_k in by_kernel.values():
        if len(rows_k) < 2:
            continue
        n_kernels += 1
        fm = [sum(f[i] for f, _ in rows_k) / len(rows_k) for i in range(nf)]
        ym = sum(y for _, y in rows_k) / len(rows_k)
        for f, y in rows_k:
            xs.append([f[i] - fm[i] for i in range(nf)])
            ys.append(y - ym)
    if len(xs) < MIN_SAMPLES or n_kernels < 2:
        return None
    try:
        x = np.asarray(xs, dtype=float)
        y = np.asarray(ys, dtype=float)
        a = x.T @ x + RIDGE_LAMBDA * np.eye(nf)
        w = np.linalg.solve(a, x.T @ y)
        if not np.all(np.isfinite(w)):
            return None
    except Exception:
        return None
    return LearnedRanker([float(v) for v in w], len(xs), n_kernels)
