"""Compile-and-run harness for the C backend, with on-disk caching."""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import Optional, Tuple

CACHE_DIR = Path(os.environ.get("POLYTOPS_CC_CACHE", "/tmp/polytops_cc_cache"))
CFLAGS = ["-O3", "-march=native", "-fopenmp", "-lm"]


@dataclass
class RunResult:
    seconds: float
    checksum: float
    cached: bool = False


MAX_SOURCE_BYTES = 400_000      # FM blowups produce pathological sources
GCC_MEM_KB = 6 * 1024 * 1024    # cap cc1 at 6 GB (observed 36 GB OOM on
                                # a wavefront-tiled 3D stencil at -O3)


def compile_and_run(source: str, tag: str = "kernel", timeout: int = 600,
                    use_cache: bool = True) -> RunResult:
    key = hashlib.sha256((source + " ".join(CFLAGS)).encode()).hexdigest()[:24]
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = CACHE_DIR / f"{key}.json"
    if use_cache and cache_file.exists():
        data = json.loads(cache_file.read_text())
        return RunResult(data["seconds"], data["checksum"], cached=True)
    if len(source) > MAX_SOURCE_BYTES:
        raise RuntimeError(
            f"generated source too large for {tag} "
            f"({len(source)} B > {MAX_SOURCE_BYTES}) — codegen blowup")
    with tempfile.TemporaryDirectory(prefix="polytops_cc_") as td:
        csrc = Path(td) / f"{tag}.c"
        exe = Path(td) / tag
        csrc.write_text(source)
        gcc_cmd = " ".join(["gcc", str(csrc), "-o", str(exe)] + CFLAGS)
        cp = subprocess.run(
            ["bash", "-c", f"ulimit -v {GCC_MEM_KB}; exec {gcc_cmd}"],
            capture_output=True, text=True, timeout=timeout,
        )
        if cp.returncode != 0:
            raise RuntimeError(f"gcc failed for {tag}:\n{cp.stderr[:4000]}\n--- source ---\n{source[:4000]}")
        rp = subprocess.run([str(exe)], capture_output=True, text=True, timeout=timeout)
        if rp.returncode != 0:
            raise RuntimeError(f"run failed for {tag}: {rp.stderr[:2000]}")
        out = rp.stdout.strip().split()
        seconds = float(out[out.index("TIME_S") + 1])
        checksum = float(out[out.index("CHECKSUM") + 1])
    cache_file.write_text(json.dumps({"seconds": seconds, "checksum": checksum}))
    return RunResult(seconds, checksum)
