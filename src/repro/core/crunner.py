"""Compile-and-run harness for the C backend, with on-disk caching."""
from __future__ import annotations

import functools
import hashlib
import json
import os
import subprocess
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import Optional, Tuple

CACHE_DIR = Path(os.environ.get("POLYTOPS_CC_CACHE", "/tmp/polytops_cc_cache"))
CFLAGS = ["-O3", "-march=native", "-fopenmp", "-lm"]


@dataclass
class RunResult:
    seconds: float
    checksum: float
    cached: bool = False


MAX_SOURCE_BYTES = 400_000      # FM blowups produce pathological sources
GCC_MEM_KB = 6 * 1024 * 1024    # cap cc1 at 6 GB (observed 36 GB OOM on
                                # a wavefront-tiled 3D stencil at -O3)


@functools.lru_cache(maxsize=1)
def compiler_version() -> str:
    """Toolchain fingerprint for the result cache: a compiler upgrade can
    change both timings and (for FP reassociation) checksums, so cached
    results must not survive one."""
    try:
        cp = subprocess.run(["gcc", "-dumpfullversion", "-dumpversion"],
                            capture_output=True, text=True, timeout=30)
        return cp.stdout.split()[0] if cp.stdout.split() else "unknown"
    except Exception:
        return "unknown"


def _result_key(source: str) -> str:
    """Cache key over everything that determines the measured result:
    source text, the exact CFLAGS, and the gcc version — flag or
    toolchain changes must never serve stale binaries' numbers."""
    payload = json.dumps({
        "src": hashlib.sha256(source.encode()).hexdigest(),
        "cflags": list(CFLAGS),
        "gcc": compiler_version(),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:24]


def compile_and_run(source: str, tag: str = "kernel", timeout: int = 600,
                    use_cache: bool = True) -> RunResult:
    key = _result_key(source)
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = CACHE_DIR / f"{key}.json"
    if use_cache and cache_file.exists():
        data = json.loads(cache_file.read_text())
        return RunResult(data["seconds"], data["checksum"], cached=True)
    if len(source) > MAX_SOURCE_BYTES:
        raise RuntimeError(
            f"generated source too large for {tag} "
            f"({len(source)} B > {MAX_SOURCE_BYTES}) — codegen blowup")
    with tempfile.TemporaryDirectory(prefix="polytops_cc_") as td:
        csrc = Path(td) / f"{tag}.c"
        exe = Path(td) / tag
        csrc.write_text(source)
        gcc_cmd = " ".join(["gcc", str(csrc), "-o", str(exe)] + CFLAGS)
        cp = subprocess.run(
            ["bash", "-c", f"ulimit -v {GCC_MEM_KB}; exec {gcc_cmd}"],
            capture_output=True, text=True, timeout=timeout,
        )
        if cp.returncode != 0:
            raise RuntimeError(f"gcc failed for {tag}:\n{cp.stderr[:4000]}\n--- source ---\n{source[:4000]}")
        rp = subprocess.run([str(exe)], capture_output=True, text=True, timeout=timeout)
        if rp.returncode != 0:
            raise RuntimeError(f"run failed for {tag}: {rp.stderr[:2000]}")
        out = rp.stdout.strip().split()
        seconds = float(out[out.index("TIME_S") + 1])
        checksum = float(out[out.index("CHECKSUM") + 1])
    cache_file.write_text(json.dumps({"seconds": seconds, "checksum": checksum}))
    return RunResult(seconds, checksum)


def measure_source(source: str, tag: str = "kernel", target_s: float = 0.15,
                   timeout: int = 900, use_cache: bool = True) -> RunResult:
    """compile_and_run plus the shared re-measurement policy: a result
    too fast to trust (< 20 ms) is re-run with an internal repeat loop
    sized to ~``target_s``.  The single policy used by both the
    benchmark harness and the autotuner, so winners are picked under
    the same measurement rules they are later reported with."""
    r = compile_and_run(source, tag=tag, timeout=timeout, use_cache=use_cache)
    if r.seconds < 0.02:
        reps = max(3, min(200000, int(target_s / max(r.seconds, 1e-7))))
        src2 = source.replace("#define REPEATS 1\n", f"#define REPEATS {reps}\n")
        r = compile_and_run(src2, tag=f"{tag}_r", timeout=timeout,
                            use_cache=use_cache)
    return r


def checksums_match(got: float, ref: float, rel: float = 1e-6) -> bool:
    """NaN-aware checksum comparison (NaN only matches NaN) — shared by
    the benchmark checksum gate and the autotuner's oracle guard."""
    import math

    if math.isnan(got) or math.isnan(ref):
        return math.isnan(got) and math.isnan(ref)
    return abs(got - ref) <= rel * max(1.0, abs(ref))
