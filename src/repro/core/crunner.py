"""Compile-and-run harness for the C backend, with on-disk caching.

Failure model (repro.core.resilience): every way a measurement can die
— oversized source, gcc OOM/timeout, a crashing or hanging binary,
malformed TIME_S/CHECKSUM output — surfaces as a typed
:class:`~repro.core.resilience.MeasurementError` carrying the build tag
and the phase that failed, so the autotuner can record/retry/exclude
instead of aborting the search.  The result cache is crash-safe: writes
are atomic (tmp+rename) and a corrupt/truncated cache file is
quarantined and recomputed, never raised.  Fault sites ``cache.read``,
``cache.write``, ``cc.compile``, ``cc.run`` and ``measure`` let the
chaos harness inject each of those failures deterministically.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import subprocess
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

from .resilience import InjectedFault, MeasurementError, fault_point

CACHE_DIR = Path(os.environ.get("POLYTOPS_CC_CACHE", "/tmp/polytops_cc_cache"))
CFLAGS = ["-O3", "-march=native", "-fopenmp", "-lm"]


@dataclass
class RunResult:
    seconds: float
    checksum: float
    cached: bool = False


MAX_SOURCE_BYTES = 400_000      # FM blowups produce pathological sources
GCC_MEM_KB = 6 * 1024 * 1024    # cap cc1 at 6 GB (observed 36 GB OOM on
                                # a wavefront-tiled 3D stencil at -O3)


@functools.lru_cache(maxsize=1)
def compiler_version() -> str:
    """Toolchain fingerprint for the result cache: a compiler upgrade can
    change both timings and (for FP reassociation) checksums, so cached
    results must not survive one."""
    try:
        cp = subprocess.run(["gcc", "-dumpfullversion", "-dumpversion"],
                            capture_output=True, text=True, timeout=30)
        return cp.stdout.split()[0] if cp.stdout.split() else "unknown"
    except Exception:
        return "unknown"


def _result_key(source: str) -> str:
    """Cache key over everything that determines the measured result:
    source text, the exact CFLAGS, and the gcc version — flag or
    toolchain changes must never serve stale binaries' numbers."""
    payload = json.dumps({
        "src": hashlib.sha256(source.encode()).hexdigest(),
        "cflags": list(CFLAGS),
        "gcc": compiler_version(),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:24]


def _quarantine(path: Path) -> None:
    """Move a corrupt cache file aside (never delete evidence, never
    raise): recompute proceeds as a plain miss."""
    try:
        qdir = path.parent / "quarantine"
        qdir.mkdir(parents=True, exist_ok=True)
        os.replace(path, qdir / path.name)
    except OSError:
        try:
            path.unlink()
        except OSError:
            pass


def _read_cached(cache_file: Path, tag: str) -> Optional[RunResult]:
    """Cached result, or None on miss.  A truncated/corrupt/partial
    JSON file (a writer died mid-write before writes were atomic, disk
    corruption, an injected cache.read fault) is quarantined and
    recomputed — it must never crash the measurement."""
    try:
        fault_point("cache.read")
        data = json.loads(cache_file.read_text())
        return RunResult(float(data["seconds"]), float(data["checksum"]),
                         cached=True)
    except FileNotFoundError:
        return None
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception:   # corrupt payload or injected fault: quarantine
        if cache_file.exists():
            _quarantine(cache_file)
        return None


def _write_cached(cache_file: Path, seconds: float, checksum: float) -> None:
    """Atomic tmp+rename publish; failures degrade to uncached."""
    try:
        fault_point("cache.write")
        fd, tmp = tempfile.mkstemp(dir=str(cache_file.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps({"seconds": seconds, "checksum": checksum}))
            os.replace(tmp, cache_file)
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception:
        pass


def compile_and_run(source: str, tag: str = "kernel", timeout: int = 600,
                    use_cache: bool = True) -> RunResult:
    key = _result_key(source)
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cache_file = CACHE_DIR / f"{key}.json"
    if use_cache:
        hit = _read_cached(cache_file, tag)
        if hit is not None:
            return hit
    if len(source) > MAX_SOURCE_BYTES:
        raise MeasurementError(
            "source_blowup", tag=tag, phase="codegen",
            detail=f"{len(source)} B > {MAX_SOURCE_BYTES} B cap")
    with tempfile.TemporaryDirectory(prefix="polytops_cc_") as td:
        csrc = Path(td) / f"{tag}.c"
        exe = Path(td) / tag
        csrc.write_text(source)
        gcc_cmd = " ".join(["gcc", str(csrc), "-o", str(exe)] + CFLAGS)
        try:
            fault_point("cc.compile")
            cp = subprocess.run(
                ["bash", "-c", f"ulimit -v {GCC_MEM_KB}; exec {gcc_cmd}"],
                capture_output=True, text=True, timeout=timeout,
            )
        except subprocess.TimeoutExpired:
            raise MeasurementError("compile_timeout", tag=tag,
                                   phase="compile",
                                   detail=f"gcc exceeded {timeout}s") from None
        except InjectedFault as e:
            raise MeasurementError("injected", tag=tag, phase="compile",
                                   detail=str(e)) from e
        if cp.returncode != 0:
            raise MeasurementError(
                "compile_failed", tag=tag, phase="compile",
                detail=f"gcc rc={cp.returncode}:\n{cp.stderr[:4000]}"
                       f"\n--- source ---\n{source[:4000]}")
        try:
            fault_point("cc.run")
            rp = subprocess.run([str(exe)], capture_output=True, text=True,
                                timeout=timeout)
        except subprocess.TimeoutExpired:
            raise MeasurementError("run_timeout", tag=tag, phase="run",
                                   detail=f"binary exceeded {timeout}s"
                                   ) from None
        except InjectedFault as e:
            raise MeasurementError("injected", tag=tag, phase="run",
                                   detail=str(e)) from e
        if rp.returncode != 0:
            raise MeasurementError("run_failed", tag=tag, phase="run",
                                   detail=f"rc={rp.returncode}: "
                                          f"{rp.stderr[:2000]}")
        try:
            out = rp.stdout.strip().split()
            seconds = float(out[out.index("TIME_S") + 1])
            checksum = float(out[out.index("CHECKSUM") + 1])
        except (ValueError, IndexError) as e:
            raise MeasurementError(
                "parse", tag=tag, phase="parse",
                detail=f"{e}: stdout={rp.stdout[:500]!r}") from None
    # written even under use_cache=False (matching the original
    # behaviour): a no-cache *read* run still warms the pool
    _write_cached(cache_file, seconds, checksum)
    return RunResult(seconds, checksum)


def measure_source(source: str, tag: str = "kernel", target_s: float = 0.15,
                   timeout: int = 900, use_cache: bool = True) -> RunResult:
    """compile_and_run plus the shared re-measurement policy: a result
    too fast to trust (< 20 ms) is re-run with an internal repeat loop
    sized to ~``target_s``.  The single policy used by both the
    benchmark harness and the autotuner, so winners are picked under
    the same measurement rules they are later reported with."""
    try:
        fault_point("measure")
    except InjectedFault as e:
        raise MeasurementError("injected", tag=tag, phase="measure",
                               detail=str(e)) from e
    r = compile_and_run(source, tag=tag, timeout=timeout, use_cache=use_cache)
    if r.seconds < 0.02:
        reps = max(3, min(200000, int(target_s / max(r.seconds, 1e-7))))
        src2 = source.replace("#define REPEATS 1\n", f"#define REPEATS {reps}\n")
        r = compile_and_run(src2, tag=f"{tag}_r", timeout=timeout,
                            use_cache=use_cache)
    return r


def checksums_match(got: float, ref: float, rel: float = 1e-6) -> bool:
    """NaN-aware checksum comparison (NaN only matches NaN) — shared by
    the benchmark checksum gate and the autotuner's oracle guard."""
    import math

    if math.isnan(got) or math.isnan(ref):
        return math.isnan(got) and math.isnan(ref)
    return abs(got - ref) <= rel * max(1.0, abs(ref))
