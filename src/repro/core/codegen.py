"""Polyhedral code generation (CLooG-lite) + reference interpreter.

Turns a :class:`Schedule` into executable Python/numpy source that scans
statement instances in lexicographic schedule-date order:

* scalar dims  → sequencing (loop distribution),
* linear dims  → loops with Fourier–Motzkin bounds,
* *separation*: statements in one loop level are split into sequential
  loops when the active-dependence direction graph permits (this is how
  PolyTOPS' distribution materializes; cyclic groups stay fused with
  per-statement guards),
* innermost parallel loops of single-statement groups are emitted as
  numpy slice/sum expressions — the CPU stand-in for the paper's NPU/SIMD
  vector unit (DESIGN.md §2).

Tile dims (from postproc) arrive as inequality-defined dims and flow
through the same FM machinery.
"""
from __future__ import annotations

import math
import re
import textwrap
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .affine import Affine, affine_eval, affine_to_str, parse_affine
from .polyhedron import Constraint, bounds_of, fm_eliminate
from .scheduler import Schedule, ScheduleRow
from .scop import Scop, Statement

# functions visible to generated Python code (match C's libm names)
_EXEC_ENV: Dict[str, object] = {
    "np": np, "math": math, "sqrt": np.sqrt, "fabs": np.abs, "pow": np.power,
    "exp": np.exp, "log": np.log, "fmod": np.fmod, "floor": np.floor,
}

# ---------------------------------------------------------------------------
# Scanning systems: per statement, dims described as equalities or
# tile inequalities over (y*, it*, params)
# ---------------------------------------------------------------------------


@dataclass
class DimSpec:
    kind: str              # 'eq' (y == phi(it, N, 1)) | 'tile'
    phi: Affine            # over stmt iterators / params / const(1)
    tile: int = 0          # tile size for kind == 'tile'
    sched_dim: int = 0     # schedule dim governing dependence satisfaction:
                           # own dim for eq rows, band start for tile/wave dims
    role: str = ""         # '' (point/eq) | 'tile' (tile counter) |
                           # 'wave' (sequential wavefront sum) |
                           # 'wave_par' (tile counter inside a wave: parallel
                           # by band permutability, see level_parallel)


@dataclass
class ScanStmt:
    stmt: Statement
    dims: List[DimSpec]
    guards: List[str] = field(default_factory=list)

    def n_dims(self) -> int:
        return len(self.dims)


def scan_from_schedule(sched: Schedule) -> List[ScanStmt]:
    out = []
    for s in sched.scop.statements:
        dims = []
        for d, row in enumerate(sched.rows[s.index]):
            phi: Affine = {}
            for (key, *rest), v in row.coeffs.items():
                if key == "it":
                    phi[s.iters[rest[0]]] = v
                elif key == "par":
                    phi[rest[0]] = v
                else:
                    phi[1] = v
            dims.append(DimSpec("eq", phi, sched_dim=d))
        out.append(ScanStmt(s, dims))
    return out


def _yvar(d: int) -> str:
    # underscore avoids collisions with SCoP array/scalar names like "y1"
    return f"y_{d}"


def _full_system(ss: ScanStmt, params: Sequence[str]) -> List[Constraint]:
    """Constraints over (y*, it*, params) for one statement."""
    cons: List[Constraint] = [(dict(e), k) for e, k in ss.stmt.domain]
    for d, spec in enumerate(ss.dims):
        y = _yvar(d)
        if spec.kind == "eq":
            e = dict(spec.phi)
            e[y] = e.get(y, Fraction(0)) - 1
            cons.append((e, "==0"))
        else:  # tile: T*y <= phi <= T*y + T - 1
            T = Fraction(spec.tile)
            e1 = dict(spec.phi)
            e1[y] = e1.get(y, Fraction(0)) - T
            cons.append((e1, ">=0"))                      # phi - T*y >= 0
            e2 = {k: -v for k, v in spec.phi.items()}
            e2[y] = e2.get(y, Fraction(0)) + T
            e2[1] = e2.get(1, Fraction(0)) + T - 1
            cons.append((e2, ">=0"))                      # T*y + T-1 - phi >= 0
    return cons


def iterator_substitution(ss: ScanStmt) -> Dict[str, Affine]:
    """Express each statement iterator as affine over (y*, params) by
    inverting a full-rank subset of the scan's 'eq' rows.  Shared by the
    scanners, the cache model (tile-footprint strides) and the autotuner
    (locality scoring)."""
    from .linalg_q import inverse, mat, rank

    s = ss.stmt
    eqs = []
    for d, spec in enumerate(ss.dims):
        if spec.kind == "eq" and any(k in s.iters for k in spec.phi):
            eqs.append((d, spec.phi))
    # build T (rows over iterators) picking a full-rank subset
    rows, chosen = [], []
    for d, phi in eqs:
        row = [phi.get(it, Fraction(0)) for it in s.iters]
        if rank(mat(rows + [row])) > len(rows):
            rows.append(row)
            chosen.append((d, phi))
        if len(rows) == s.dim:
            break
    if len(rows) < s.dim:
        raise ValueError(f"schedule not invertible for {s}")
    tinv = inverse(mat(rows))
    subst: Dict[str, Affine] = {}
    for i, it in enumerate(s.iters):
        expr: Affine = {}
        for j, (d, phi) in enumerate(chosen):
            c = tinv[i][j]
            if c == 0:
                continue
            expr[_yvar(d)] = expr.get(_yvar(d), Fraction(0)) + c
            for k, v in phi.items():
                if k not in s.iters:   # params / const move to RHS
                    expr[k] = expr.get(k, Fraction(0)) - c * v
        subst[it] = {k: v for k, v in expr.items() if v != 0}
    return subst


def wave_parallel(group: Sequence[ScanStmt], d: int) -> bool:
    """True when scan level ``d`` is a wavefront-inner tile counter for
    every statement in the group — the one loop whose parallelism lives
    under a sequential wave dim (see level_parallel)."""
    specs = [ss.dims[d] for ss in group if d < ss.n_dims()]
    return bool(specs) and all(spec.role == "wave_par" for spec in specs)


def level_parallel(sched: Schedule, group: Sequence[ScanStmt], d: int) -> bool:
    """Single source of truth for loop-level parallel legality, shared by
    the Python oracle (vectorized emission) and the C backend (omp
    parallel/simd pragmas) so both mark the same dims.

    * wavefront sum dims are sequential by construction;
    * the tile counter inside a wavefront ('wave_par') is parallel: the
      band is fully permutable, so every active dependence has
      componentwise non-negative distance, tile counters inherit that,
      and equal wave value forces both tile deltas to zero (same tile);
    * everything else is judged against SCHEDULE dims via
      stmt_parallel_at_set (distance zero for all deps not satisfied
      outside)."""
    specs = [ss.dims[d] for ss in group if d < ss.n_dims()]
    if not specs:
        return False
    if any(spec.role == "wave" for spec in specs):
        return False
    if wave_parallel(group, d):
        return True
    stmt_set = {ss.stmt.index for ss in group if d < ss.n_dims()}
    sd = min(spec.sched_dim for spec in specs)
    return sched.stmt_parallel_at_set(stmt_set, sd)


class _StmtScanner:
    """Precomputes, per statement, loop bounds of each y dim (in terms of
    outer y dims and params) and the iterator substitution it = g(y).

    ``context`` rows (parameter bounds or concrete values — see
    ``bounds_of``) drive LP redundancy pruning of the FM chains."""

    def __init__(self, ss: ScanStmt, params: Sequence[str],
                 context: Sequence[Constraint] = ()):
        self.ss = ss
        self.params = list(params)
        self.n = ss.n_dims()
        sys_full = _full_system(ss, params)
        self.bounds: List[Tuple[List[Affine], List[Affine]]] = []
        for d in range(self.n):
            inner = [it for it in ss.stmt.iters] + [_yvar(k) for k in range(self.n - 1, d, -1)]
            lo, hi = bounds_of(sys_full, _yvar(d), inner, context=context)
            self.bounds.append((lo, hi))
        self.subst = iterator_substitution(ss)


# ---------------------------------------------------------------------------
# emission helpers
# ---------------------------------------------------------------------------

def _ceil_div_src(num: str, den: int) -> str:
    return num if den == 1 else f"-((-({num})) // {den})"


def _floor_div_src(num: str, den: int) -> str:
    return num if den == 1 else f"({num}) // {den}"


def _affine_src(e: Affine, sub: Optional[Dict[str, Affine]] = None) -> str:
    """Affine over y*/params (ints at runtime) to Python source."""
    if sub:
        e2: Affine = {}
        for k, v in e.items():
            if k != 1 and k in sub:
                for k2, v2 in sub[k].items():
                    e2[k2] = e2.get(k2, Fraction(0)) + v * v2
            else:
                e2[k] = e2.get(k, Fraction(0)) + v
        e = {k: v for k, v in e2.items() if v != 0}
    # common denominator
    den = 1
    for v in e.values():
        den = den * v.denominator // math.gcd(den, v.denominator)
    parts = []
    for k, v in sorted(e.items(), key=lambda kv: str(kv[0])):
        c = int(v * den)
        if c == 0:
            continue
        if k == 1:
            parts.append(f"{c:+d}")
        elif c == 1:
            parts.append(f"+{k}")
        elif c == -1:
            parts.append(f"-{k}")
        else:
            parts.append(f"{c:+d}*{k}")
    body = "".join(parts) or "0"
    if body.startswith("+"):
        body = body[1:]
    return body, den


def _bound_src(bounds: List[Affine], lower: bool) -> str:
    terms = []
    for e in bounds:
        body, den = _affine_src(e)
        terms.append(_ceil_div_src(body, den) if lower else _floor_div_src(body, den))
    if not terms:
        raise ValueError("unbounded loop dimension")
    uniq = sorted(set(terms))
    if len(uniq) == 1:
        return uniq[0]
    return ("max(" if lower else "min(") + ", ".join(uniq) + ")"


_NAME_RE = re.compile(r"[A-Za-z_][A-Za-z_0-9]*")


def _substitute_body(body: str, subst: Dict[str, str]) -> str:
    def repl(m):
        nm = m.group(0)
        return f"({subst[nm]})" if nm in subst else nm

    return _NAME_RE.sub(repl, body)


# ---------------------------------------------------------------------------
# the generator
# ---------------------------------------------------------------------------

class CodeGenerator:
    def __init__(self, sched: Schedule, scan: Optional[List[ScanStmt]] = None,
                 vectorize: bool = True, func_name: Optional[str] = None):
        self.sched = sched
        self.scop = sched.scop
        self.params = self.scop.param_names()
        self.scan = scan if scan is not None else scan_from_schedule(sched)
        self.vectorize = vectorize
        self.func_name = func_name or f"kernel_{self.scop.name}".replace("-", "_")
        self.lines: List[str] = []
        self.indent = 0
        ctx = self._scan_context()
        self._scanners = {ss.stmt.index: _StmtScanner(ss, self.params, ctx)
                          for ss in self.scan}
        self.vectorized_stmts: Set[int] = set()

    def _scan_context(self) -> List[Constraint]:
        """Known-true rows for FM redundancy pruning.  The Python oracle
        stays parametric: only the SCoP's assumed parameter lower bound.
        (The C backend bakes concrete parameter values — see
        CCodeGenerator.)"""
        return self.scop.param_min_rows()

    # -- public ---------------------------------------------------------
    def generate(self) -> str:
        self.lines = []
        args = ", ".join(list(self.scop.arrays) + self.scop.scalars + self.params)
        self._emit(f"def {self.func_name}({args}):")
        self.indent += 1
        n_dims = max(ss.n_dims() for ss in self.scan)
        self._gen_level(list(self.scan), 0, n_dims, {})
        self._emit("return None")
        self.indent -= 1
        return "\n".join(self.lines)

    def build(self):
        src = self.generate()
        env: Dict[str, object] = dict(_EXEC_ENV)
        exec(compile(src, f"<polytops:{self.func_name}>", "exec"), env)
        return env[self.func_name], src

    # -- internals --------------------------------------------------------
    def _emit(self, line: str):
        self.lines.append("    " * self.indent + line)

    def _const_at(self, ss: ScanStmt, d: int) -> Optional[int]:
        spec = ss.dims[d]
        if spec.kind != "eq":
            return None
        if any(k in ss.stmt.iters for k in spec.phi):
            return None
        if any(k != 1 for k in spec.phi):
            return None   # parametric constant: treat as loop
        return int(spec.phi.get(1, Fraction(0)))

    def _gen_level(self, group: List[ScanStmt], d: int, n_dims: int,
                   guards: Dict[int, List[str]]):
        if not group:
            return
        if d >= n_dims or all(ss.n_dims() <= d for ss in group):
            for ss in sorted(group, key=lambda s: s.stmt.index):
                self._emit_leaf(ss, guards.get(ss.stmt.index, []))
            return
        consts = {ss.stmt.index: self._const_at(ss, d) for ss in group}
        if all(c is not None for c in consts.values()):
            order: Dict[int, List[ScanStmt]] = {}
            for ss in group:
                order.setdefault(consts[ss.stmt.index], []).append(ss)
            for c in sorted(order):
                self._gen_level(order[c], d + 1, n_dims, guards)
            return
        # linear level: separate into sequential loop groups when legal
        for sub in self._separate(group, d):
            self._gen_loop(sub, d, n_dims, guards)

    def _separate(self, group: List[ScanStmt], d: int) -> List[List[ScanStmt]]:
        """Order statements into sequential loop groups; merge cyclic ones."""
        if len(group) == 1:
            return [group]
        idx = {ss.stmt.index: ss for ss in group}
        # deps that still constrain relative order at/below this level —
        # satisfaction is judged against SCHEDULE dims, not scan levels
        level_sd = min(ss.dims[d].sched_dim for ss in group if d < ss.n_dims())
        edges: Set[Tuple[int, int]] = set()
        for dep in self.sched.deps:
            a, b = dep.source.index, dep.target.index
            if a == b or a not in idx or b not in idx:
                continue
            if dep.satisfied_at is not None and dep.satisfied_at < level_sd:
                continue
            edges.add((a, b))
        # union cyclic pairs via SCC on the subgraph
        from .scheduler import _scc_groups
        deps_like = [_FakeDep(a, b, idx) for (a, b) in edges]
        sccs = _scc_groups([ss.stmt for ss in group], deps_like)
        out = []
        for comp in sccs:
            # keep statements with *identical* loop structure together only
            # if they are in the same SCC; singleton SCCs become their own
            # sequential loop (classic distribution)
            out.append([idx[i] for i in comp if i in idx])
        return [g for g in out if g]

    def _gen_loop(self, group: List[ScanStmt], d: int, n_dims: int,
                  guards: Dict[int, List[str]]):
        y = _yvar(d)
        los, his = [], []
        for ss in group:
            lo, hi = self._scanners[ss.stmt.index].bounds[d]
            los.append(_bound_src(lo, lower=True))
            his.append(_bound_src(hi, lower=False))
        lo_src = los[0] if len(set(los)) == 1 else "min(" + ", ".join(sorted(set(los))) + ")"
        hi_src = his[0] if len(set(his)) == 1 else "max(" + ", ".join(sorted(set(his))) + ")"
        mixed = len(group) > 1 and (len(set(los)) > 1 or len(set(his)) > 1)
        new_guards = dict(guards)
        if mixed:
            for ss, l, h in zip(group, los, his):
                g = new_guards.setdefault(ss.stmt.index, list(guards.get(ss.stmt.index, [])))
                g += [f"{y} >= {l}", f"{y} <= {h}"]
                new_guards[ss.stmt.index] = g
        # vectorized innermost?
        if (
            self.vectorize
            and len(group) == 1
            and self._innermost_linear(group[0], d)
            and self._can_vectorize(group[0], d)
            and not new_guards.get(group[0].stmt.index)
        ):
            if self._emit_vectorized(group[0], d, lo_src, hi_src):
                return
        self._emit(f"for {y} in range({lo_src}, ({hi_src}) + 1):")
        self.indent += 1
        body_start = len(self.lines)
        self._gen_level(group, d + 1, n_dims, new_guards)
        if len(self.lines) == body_start:
            self._emit("pass")
        self.indent -= 1

    def _innermost_linear(self, ss: ScanStmt, d: int) -> bool:
        for dd in range(d + 1, ss.n_dims()):
            if self._const_at(ss, dd) is None:
                return False
        return True

    def _can_vectorize(self, ss: ScanStmt, d: int) -> bool:
        spec = ss.dims[d]
        if spec.kind != "eq":
            return False
        s = ss.stmt
        # schedule-legality via the marking shared with the C backend
        if not level_parallel(self.sched, [ss], d):
            return False
        # the loop variable must enter subscripts with coeff in {0, ±1}
        sub = self._scanners[s.index].subst
        for acc in s.accesses:
            for e in acc.subscripts:
                c = self._coeff_of_y(e, sub, d)
                if c is None or abs(c) not in (0, 1):
                    return False
        return True

    def _coeff_of_y(self, e: Affine, sub: Dict[str, Affine], d: int) -> Optional[Fraction]:
        tot = Fraction(0)
        for k, v in e.items():
            if k == 1 or k in self.params:
                continue
            c = sub[k].get(_yvar(d), Fraction(0))
            tot += v * c
        if tot.denominator != 1:
            return None
        return tot

    def _emit_vectorized(self, ss: ScanStmt, d: int, lo: str, hi: str) -> bool:
        """Emit the innermost loop as numpy slices. Two patterns:
        parallel assignment (LHS varies with y) or sum-reduction
        (LHS constant in y, body is `X = X + expr`)."""
        s = ss.stmt
        sub = self._scanners[s.index].subst
        y = _yvar(d)
        lhs_acc = s.writes()[0]
        lhs_coef = [self._coeff_of_y(e, sub, d) for e in lhs_acc.subscripts]
        if any(_affine_src(expr)[1] != 1 for expr in sub.values()):
            return False   # non-unimodular substitution: fall back to loops
        sub_src = {it: _affine_src(expr)[0] for it, expr in sub.items()}

        def slice_subscripts(text_subs: List[Affine]) -> Optional[str]:
            # the vector iterator may appear in at most ONE subscript —
            # otherwise independent slices form a cross product instead
            # of the diagonal access (hypothesis-found bug)
            n_vec = sum(1 for e in text_subs
                        if self._coeff_of_y(e, sub, d) not in (0, None))
            if n_vec > 1:
                return None
            parts = []
            for e in text_subs:
                c = self._coeff_of_y(e, sub, d)
                body, den = _affine_src(e, sub)
                if den != 1:
                    return None
                if c == 0:
                    parts.append(body)
                else:
                    base = _drop_var(e, sub, d)
                    if base is None:
                        return None
                    bsrc, bden = _affine_src(base)
                    if bden != 1:
                        return None
                    if c == 1:
                        parts.append(f"({bsrc})+({lo}):({bsrc})+({hi})+1")
                    else:  # c == -1 → reversed slice
                        top = f"({bsrc})-({lo})"
                        bot = f"({bsrc})-({hi})"
                        parts.append(f"{top}:({bot})-1 if ({bot})>0 else None:-1")
            return ", ".join(parts)

        from .scop import _find_assign
        eq = _find_assign(s.body)
        lhs_txt, rhs_txt = s.body[:eq].strip(), s.body[eq + 1:].strip()

        def vec_expr(txt: str) -> Optional[str]:
            out = []
            pos = 0
            from .scop import _ACCESS
            for m in _ACCESS.finditer(txt):
                out.append(_substitute_body(txt[pos:m.start()], sub_src))
                arr = m.group(1)
                from .scop import _split_subscripts
                subs = [parse_affine(t) for t in _split_subscripts(m.group(2))]
                sl = slice_subscripts(subs)
                if sl is None:
                    return None
                out.append(f"{arr}[{sl}]")
                pos = m.end()
            out.append(_substitute_body(txt[pos:], sub_src))
            return "".join(out)

        if any(c != 0 for c in lhs_coef):
            # parallel elementwise
            lv = vec_expr(lhs_txt)
            rv = vec_expr(rhs_txt)
            if lv is None or rv is None:
                return False
            self._emit(f"if ({hi}) >= ({lo}):  # vectorized {y}")
            self.indent += 1
            self._emit(f"{lv} = {rv}")
            self.indent -= 1
            self.vectorized_stmts.add(s.index)
            return True
        # reduction: X = X + f(y)  →  X += np.sum(f(slice))
        m = re.match(re.escape(lhs_txt) + r"\s*\+\s*(.*)$", rhs_txt)
        if not m:
            return False
        addend = m.group(1)
        av = vec_expr(addend)
        lv = _substitute_body(lhs_txt, sub_src)
        if av is None:
            return False
        self._emit(f"if ({hi}) >= ({lo}):  # vectorized reduction {y}")
        self.indent += 1
        self._emit(f"{lv} = {lv} + np.sum({av})")
        self.indent -= 1
        self.vectorized_stmts.add(s.index)
        return True

    def _emit_leaf(self, ss: ScanStmt, guard_exprs: List[str]):
        s = ss.stmt
        scanner = self._scanners[s.index]
        sub_src = {}
        integral = True
        for it, expr in scanner.subst.items():
            body, den = _affine_src(expr)
            if den != 1:
                integral = False
                sub_src[it] = _floor_div_src(body, den)
                guard_exprs = guard_exprs + [f"({body}) % {den} == 0"]
            else:
                sub_src[it] = body
        body = _substitute_body(s.body, sub_src)
        if guard_exprs:
            self._emit("if " + " and ".join(guard_exprs) + ":")
            self.indent += 1
            self._emit(body)
            self.indent -= 1
        else:
            self._emit(body)


def _drop_var(e: Affine, sub: Dict[str, Affine], d: int) -> Optional[Affine]:
    """Substituted expr with the y_d term removed (slice base address)."""
    out: Affine = {}
    for k, v in e.items():
        if k == 1:
            out[1] = out.get(1, Fraction(0)) + v
        elif k in sub:
            for k2, v2 in sub[k].items():
                out[k2] = out.get(k2, Fraction(0)) + v * v2
        else:
            out[k] = out.get(k, Fraction(0)) + v
    out.pop(_yvar(d), None)
    return {k: v for k, v in out.items() if v != 0}


class _FakeDep:
    """Adapter so codegen can reuse the scheduler's SCC machinery."""

    def __init__(self, a: int, b: int, idx):
        self.source = idx[a].stmt
        self.target = idx[b].stmt
        self.satisfied_at = None


# ---------------------------------------------------------------------------
# reference interpreter (independent oracle for equivalence tests)
# ---------------------------------------------------------------------------

def interpret_source(scop: Scop) -> str:
    """Python source executing the SCoP in original program order — the
    independent oracle for schedule-equivalence tests."""
    src_lines = ["def __run__(arrays, scalars, params):"]
    for a in scop.arrays:
        src_lines.append(f"    {a} = arrays['{a}']")
    for sc in scop.scalars:
        src_lines.append(f"    {sc} = scalars.get('{sc}', 1.0)")
    for p in scop.params:
        src_lines.append(f"    {p} = params['{p}']")

    open_loops: List[int] = []

    def indent() -> str:
        return "    " * (1 + len(open_loops))

    order = sorted(scop.statements, key=lambda s: tuple(s.beta))
    for s in order:
        while open_loops and open_loops != s.loop_ids[: len(open_loops)]:
            open_loops.pop()
        for lid in s.loop_ids[len(open_loops):]:
            loop = scop.loops[lid]
            lo, lo_den = _affine_src(loop.lower)
            hi, hi_den = _affine_src(loop.upper)
            assert lo_den == 1 and hi_den == 1
            src_lines.append(f"{indent()}for {loop.iterator} in range({lo}, {hi}):")
            open_loops.append(lid)
        src_lines.append(indent() + s.body)
    return "\n".join(src_lines)


def interpret_scop(scop: Scop, arrays: Dict[str, np.ndarray],
                   scalars: Optional[Dict[str, float]] = None) -> None:
    """Execute the SCoP in original program order, mutating ``arrays``."""
    src = interpret_source(scop)
    env: Dict[str, object] = dict(_EXEC_ENV)
    exec(compile(src, f"<interp:{scop.name}>", "exec"), env)
    env["__run__"](arrays, scalars or {}, dict(scop.params))
