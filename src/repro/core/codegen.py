"""Tree-walking numpy emitter + reference interpreter.

Turns a :class:`~repro.core.schedtree.ScheduleTree` (built once from a
:class:`Schedule` by :mod:`repro.core.schedtree` — loop separation,
Fourier–Motzkin bounds and parallel/vector marks all live there) into
executable Python/numpy source that scans statement instances in
lexicographic schedule-date order:

* sequence nodes → sequencing (loop distribution),
* band nodes     → loops over the tree's precomputed FM bounds,
* bands carrying the ``vector`` mark (single-statement innermost
  parallel loops) are emitted as numpy slice/sum expressions — the CPU
  stand-in for the paper's NPU/SIMD vector unit (DESIGN.md §2).

Tile/wavefront dims (from postproc) are ordinary bands with
``tile``/``wavefront`` marks and flow through the same walk.  This
emitter is the correctness oracle; the C measurement backend
(:mod:`repro.core.cbackend`) walks the *same* tree.
"""
from __future__ import annotations

import math
import re
from fractions import Fraction
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .affine import Affine, parse_affine
from .schedtree import (BandNode, LeafNode, ScanStmt, ScheduleTree,
                        SequenceNode, build_tree, coeff_of_y, render_affine,
                        schedule_tree, yvar as _yvar)
from .scheduler import Schedule
from .scop import Scop

# functions visible to generated Python code (match C's libm names)
_EXEC_ENV: Dict[str, object] = {
    "np": np, "math": math, "sqrt": np.sqrt, "fabs": np.abs, "pow": np.power,
    "exp": np.exp, "log": np.log, "fmod": np.fmod, "floor": np.floor,
}


# ---------------------------------------------------------------------------
# emission helpers
# ---------------------------------------------------------------------------

def _ceil_div_src(num: str, den: int) -> str:
    return num if den == 1 else f"-((-({num})) // {den})"


def _floor_div_src(num: str, den: int) -> str:
    return num if den == 1 else f"({num}) // {den}"


def _affine_src(e: Affine, sub: Optional[Dict[str, Affine]] = None) -> str:
    """Affine over y*/params (ints at runtime) to source, optionally
    substituting iterator expressions first.  Returns (body, den)."""
    if sub:
        e2: Affine = {}
        for k, v in e.items():
            if k != 1 and k in sub:
                for k2, v2 in sub[k].items():
                    e2[k2] = e2.get(k2, Fraction(0)) + v * v2
            else:
                e2[k] = e2.get(k, Fraction(0)) + v
        e = {k: v for k, v in e2.items() if v != 0}
    return render_affine(e)


_NAME_RE = re.compile(r"[A-Za-z_][A-Za-z_0-9]*")


def _substitute_body(body: str, subst: Dict[str, str]) -> str:
    def repl(m):
        nm = m.group(0)
        return f"({subst[nm]})" if nm in subst else nm

    return _NAME_RE.sub(repl, body)


def _drop_var(e: Affine, sub: Dict[str, Affine], d: int) -> Optional[Affine]:
    """Substituted expr with the y_d term removed (slice base address)."""
    out: Affine = {}
    for k, v in e.items():
        if k == 1:
            out[1] = out.get(1, Fraction(0)) + v
        elif k in sub:
            for k2, v2 in sub[k].items():
                out[k2] = out.get(k2, Fraction(0)) + v * v2
        else:
            out[k] = out.get(k, Fraction(0)) + v
    out.pop(_yvar(d), None)
    return {k: v for k, v in out.items() if v != 0}


# ---------------------------------------------------------------------------
# the generator
# ---------------------------------------------------------------------------

class CodeGenerator:
    """Tree-walking Python/numpy emitter.

    Accepts either a prebuilt ``tree`` or a ``Schedule`` (+ optional
    tiled ``scan``), in which case the tree is built here with the
    parametric bound context (see :data:`CONCRETE`)."""

    #: bound-pruning context: the numpy oracle stays parametric (only the
    #: SCoP's assumed parameter lower bound); the C backend overrides
    #: this to bake concrete parameter values (see CCodeGenerator)
    CONCRETE = False

    def __init__(self, sched: Schedule, scan: Optional[List[ScanStmt]] = None,
                 vectorize: bool = True, func_name: Optional[str] = None,
                 tree: Optional[ScheduleTree] = None):
        self.sched = sched
        self.scop = sched.scop
        self.params = self.scop.param_names()
        self.vectorize = vectorize
        self.func_name = func_name or f"kernel_{self.scop.name}".replace("-", "_")
        self.lines: List[str] = []
        self.indent = 0
        if tree is None:
            if scan is None and not self.CONCRETE:
                tree = schedule_tree(sched)      # shared memoized tree
            else:
                tree = build_tree(sched, scan=scan, concrete=self.CONCRETE)
        self.tree = tree
        self.vectorized_stmts: Set[int] = set()
        self._bands: Dict[int, BandNode] = {}
        self._loop_depth = 0

    # -- public ---------------------------------------------------------
    def generate(self) -> str:
        self.lines = []
        self._bands = {}
        self._loop_depth = 0
        args = ", ".join(list(self.scop.arrays) + self.scop.scalars + self.params)
        self._emit(f"def {self.func_name}({args}):")
        self.indent += 1
        self._walk(self.tree.root)
        self._emit("return None")
        self.indent -= 1
        return "\n".join(self.lines)

    def build(self):
        src = self.generate()
        env: Dict[str, object] = dict(_EXEC_ENV)
        exec(compile(src, f"<polytops:{self.func_name}>", "exec"), env)
        return env[self.func_name], src

    # -- the walk ---------------------------------------------------------
    def _emit(self, line: str):
        self.lines.append("    " * self.indent + line)

    def _walk(self, node):
        if node is None:
            return
        if isinstance(node, SequenceNode):
            for c in node.children:
                self._walk(c)
        elif isinstance(node, BandNode):
            self._emit_band(node)
        else:
            self._emit_leaf(node)

    def _band_bounds(self, node: BandNode) -> Tuple[str, str]:
        """Loop bounds: per-statement rendered bounds, folded across the
        group (min of lowers / max of uppers for the domain union)."""
        los, his = [], []
        for s in node.stmts:
            lo, hi = node.bounds[s]
            los.append(self._render_bound(lo, lower=True))
            his.append(self._render_bound(hi, lower=False))
        lo_src = (los[0] if len(set(los)) == 1
                  else self._fold_group(sorted(set(los)), lower=True))
        hi_src = (his[0] if len(set(his)) == 1
                  else self._fold_group(sorted(set(his)), lower=False))
        return lo_src, hi_src

    def _render_bound(self, bounds: List[Affine], lower: bool) -> str:
        terms = []
        for e in bounds:
            body, den = render_affine(e)
            terms.append(_ceil_div_src(body, den) if lower
                         else _floor_div_src(body, den))
        if not terms:
            raise ValueError("unbounded loop dimension")
        uniq = sorted(set(terms))
        if len(uniq) == 1:
            return uniq[0]
        return ("max(" if lower else "min(") + ", ".join(uniq) + ")"

    def _fold_group(self, terms: List[str], lower: bool) -> str:
        return ("min(" if lower else "max(") + ", ".join(terms) + ")"

    def _emit_band(self, node: BandNode):
        self._bands[node.dim] = node
        y = _yvar(node.dim)
        lo_src, hi_src = self._band_bounds(node)
        if (self.vectorize and node.vector
                and self._emit_vectorized(node, lo_src, hi_src)):
            return
        self._emit(f"for {y} in range({lo_src}, ({hi_src}) + 1):")
        self.indent += 1
        self._loop_depth += 1
        body_start = len(self.lines)
        self._walk(node.child)
        if len(self.lines) == body_start:
            self._emit("pass")
        self._loop_depth -= 1
        self.indent -= 1

    def _band_guards(self, leaf: LeafNode) -> List[str]:
        """Per-statement bound guards for mixed-bound fused loops, from
        the enclosing bands the tree flagged."""
        out: List[str] = []
        for d in leaf.guards:
            band = self._bands[d]
            lo, hi = band.bounds[leaf.stmt]
            l = self._render_bound(lo, lower=True)
            h = self._render_bound(hi, lower=False)
            y = _yvar(d)
            out += [f"{y} >= {l}", f"{y} <= {h}"]
        return out

    def _emit_vectorized(self, node: BandNode, lo: str, hi: str) -> bool:
        """Emit a ``vector``-marked band as numpy slices. Two patterns:
        parallel assignment (LHS varies with y) or sum-reduction
        (LHS constant in y, body is `X = X + expr`)."""
        d = node.dim
        s = self.scop.statements[node.stmts[0]]
        sub = self.tree.subst[s.index]
        y = _yvar(d)
        lhs_acc = s.writes()[0]
        lhs_coef = [coeff_of_y(e, sub, d, self.params)
                    for e in lhs_acc.subscripts]
        if any(_affine_src(expr)[1] != 1 for expr in sub.values()):
            return False   # non-unimodular substitution: fall back to loops
        sub_src = {it: _affine_src(expr)[0] for it, expr in sub.items()}

        def slice_subscripts(text_subs: List[Affine]) -> Optional[str]:
            # the vector iterator may appear in at most ONE subscript —
            # otherwise independent slices form a cross product instead
            # of the diagonal access (hypothesis-found bug)
            n_vec = sum(1 for e in text_subs
                        if coeff_of_y(e, sub, d, self.params) not in (0, None))
            if n_vec > 1:
                return None
            parts = []
            for e in text_subs:
                c = coeff_of_y(e, sub, d, self.params)
                body, den = _affine_src(e, sub)
                if den != 1:
                    return None
                if c == 0:
                    parts.append(body)
                else:
                    base = _drop_var(e, sub, d)
                    if base is None:
                        return None
                    bsrc, bden = _affine_src(base)
                    if bden != 1:
                        return None
                    if c == 1:
                        parts.append(f"({bsrc})+({lo}):({bsrc})+({hi})+1")
                    else:  # c == -1 → reversed slice
                        top = f"({bsrc})-({lo})"
                        bot = f"({bsrc})-({hi})"
                        parts.append(f"{top}:({bot})-1 if ({bot})>0 else None:-1")
            return ", ".join(parts)

        from .scop import _find_assign
        eq = _find_assign(s.body)
        lhs_txt, rhs_txt = s.body[:eq].strip(), s.body[eq + 1:].strip()

        def vec_expr(txt: str) -> Optional[str]:
            out = []
            pos = 0
            from .scop import _ACCESS
            for m in _ACCESS.finditer(txt):
                out.append(_substitute_body(txt[pos:m.start()], sub_src))
                arr = m.group(1)
                from .scop import _split_subscripts
                subs = [parse_affine(t) for t in _split_subscripts(m.group(2))]
                sl = slice_subscripts(subs)
                if sl is None:
                    return None
                out.append(f"{arr}[{sl}]")
                pos = m.end()
            out.append(_substitute_body(txt[pos:], sub_src))
            return "".join(out)

        if any(c != 0 for c in lhs_coef):
            # parallel elementwise
            lv = vec_expr(lhs_txt)
            rv = vec_expr(rhs_txt)
            if lv is None or rv is None:
                return False
            self._emit(f"if ({hi}) >= ({lo}):  # vectorized {y}")
            self.indent += 1
            self._emit(f"{lv} = {rv}")
            self.indent -= 1
            self.vectorized_stmts.add(s.index)
            return True
        # reduction: X = X + f(y)  →  X += np.sum(f(slice))
        m = re.match(re.escape(lhs_txt) + r"\s*\+\s*(.*)$", rhs_txt)
        if not m:
            return False
        addend = m.group(1)
        av = vec_expr(addend)
        lv = _substitute_body(lhs_txt, sub_src)
        if av is None:
            return False
        self._emit(f"if ({hi}) >= ({lo}):  # vectorized reduction {y}")
        self.indent += 1
        self._emit(f"{lv} = {lv} + np.sum({av})")
        self.indent -= 1
        self.vectorized_stmts.add(s.index)
        return True

    def _emit_leaf(self, leaf: LeafNode):
        s = self.scop.statements[leaf.stmt]
        guard_exprs = self._band_guards(leaf)
        sub_src = {}
        for it, expr in self.tree.subst[s.index].items():
            body, den = _affine_src(expr)
            if den != 1:
                sub_src[it] = _floor_div_src(body, den)
                guard_exprs = guard_exprs + [f"({body}) % {den} == 0"]
            else:
                sub_src[it] = body
        body = _substitute_body(s.body, sub_src)
        if guard_exprs:
            self._emit("if " + " and ".join(guard_exprs) + ":")
            self.indent += 1
            self._emit(body)
            self.indent -= 1
        else:
            self._emit(body)


# ---------------------------------------------------------------------------
# reference interpreter (independent oracle for equivalence tests)
# ---------------------------------------------------------------------------

def interpret_source(scop: Scop) -> str:
    """Python source executing the SCoP in original program order — the
    independent oracle for schedule-equivalence tests."""
    src_lines = ["def __run__(arrays, scalars, params):"]
    for a in scop.arrays:
        src_lines.append(f"    {a} = arrays['{a}']")
    for sc in scop.scalars:
        src_lines.append(f"    {sc} = scalars.get('{sc}', 1.0)")
    for p in scop.params:
        src_lines.append(f"    {p} = params['{p}']")

    open_loops: List[int] = []

    def indent() -> str:
        return "    " * (1 + len(open_loops))

    order = sorted(scop.statements, key=lambda s: tuple(s.beta))
    for s in order:
        while open_loops and open_loops != s.loop_ids[: len(open_loops)]:
            open_loops.pop()
        for lid in s.loop_ids[len(open_loops):]:
            loop = scop.loops[lid]
            lo, lo_den = _affine_src(loop.lower)
            hi, hi_den = _affine_src(loop.upper)
            assert lo_den == 1 and hi_den == 1
            src_lines.append(f"{indent()}for {loop.iterator} in range({lo}, {hi}):")
            open_loops.append(lid)
        src_lines.append(indent() + s.body)
    return "\n".join(src_lines)


def interpret_scop(scop: Scop, arrays: Dict[str, np.ndarray],
                   scalars: Optional[Dict[str, float]] = None) -> None:
    """Execute the SCoP in original program order, mutating ``arrays``."""
    src = interpret_source(scop)
    env: Dict[str, object] = dict(_EXEC_ENV)
    exec(compile(src, f"<interp:{scop.name}>", "exec"), env)
    env["__run__"](arrays, scalars or {}, dict(scop.params))
