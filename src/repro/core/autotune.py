"""Kernel-specific autotuning (paper §III-E / §IV-B "kernel-specific").

The paper's headline PolyBench numbers come from *kernel-specific
configurations* — per-kernel choices of cost functions, fusion,
vectorization and tiling.  This module searches that full §III-E space:

1. **Configuration enumeration** — candidate ``SchedulerConfig``s are
   composed from four axes:

   * scheduling strategy (``pluto``/``tensor``/``bigloops``/``feautrier``
     — isl-style is excluded: its dynamic Python callback makes
     schedules uncacheable, see schedcache);
   * **fusion**: ``smart``/``max``/``no`` modes plus explicit
     SCC-derived :class:`~repro.core.config.FusionSpec` statement groups
     (adjacent SCCs of the dependence graph merged pairwise — points
     *between* the extremes);
   * **per-dimension cost-function mixes**
     (:data:`repro.core.costs.COST_MIXES`): contiguity/proximity stride
     orderings, big-loops-first outer dims, and a static isl-style
     require-parallel variant — threaded into the per-dim ILP objective
     construction by the scheduler;
   * tile source (none / cache-model L1 / cache-model L2 / fixed 32) ×
     wavefront × auto-vectorization, pruned by schedule structure.

   Base schedules come through the structural schedule cache and are
   **deduplicated** by :func:`repro.core.schedcache.schedule_fingerprint`
   — on a single-SCC kernel the fusion modes all collapse to one
   candidate instead of three.
2. **Static ranking** — the analytic access-stride cost model below,
   replaced by a *learned* ridge ranker (:mod:`repro.core.ranker`) once
   enough measured (kernel, config, time) triples have accumulated in
   the cache pool.  Ranking prunes the enumeration to a measurable
   ``top_k``.
3. **Measurement** — the ``top_k`` ranked candidates are compiled and
   timed through :mod:`repro.core.crunner`; each must checksum-match the
   original-program-order reference or it is discarded.  Every valid
   measurement is persisted as a training triple
   (:func:`repro.core.schedcache.record_measurements`).
4. **Persistence** — the winner is stored in the schedule-cache pool
   keyed by SCoP structure + search-space version
   (:func:`repro.core.schedcache.autotune_key`), so the second compile
   of the same kernel shape is a dictionary/disk lookup — winner
   replay, no re-enumeration.

Everything is deterministic: candidate order is fixed, ranking
tie-breaks on candidate index, and measurements go through crunner's
on-disk result cache, so re-tuning the same kernel against the same
measurement pool returns the same configuration.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field, replace
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple, Union

from . import config as CFG
from . import costs as C
from .resilience import Deadline, DeadlineExceeded, MeasurementError
from .cachemodel import (CacheSpec, default_spec, shared_bands,
                         shared_groups, shared_scan, shared_tile_sizes,
                         working_set_bytes)
from .schedtree import (iterator_substitution, level_parallel,
                        schedule_tree, yvar as _yvar)
from .postproc import find_tilable_bands, tile_schedule
from .schedcache import (ScheduleCache, autotune_key, cached_schedule_scop,
                         global_cache, load_measurements,
                         record_measurements, schedule_fingerprint)
from .scheduler import PolyTOPSScheduler, Schedule, _scc_groups
from .scop import Scop

SPACE_VERSION = 2          # bump when the candidate space / model changes

#: strategies the autotuner explores (isl-style is excluded: its dynamic
#: Python callback makes schedules uncacheable — see schedcache)
TUNE_STRATEGIES = ("pluto", "tensor", "bigloops", "feautrier")
TILED_STRATEGIES = ("pluto", "tensor")
#: strategies the fusion axis is enumerated on
FUSION_STRATEGIES = ("pluto", "tensor")
#: strategies the cost-mix axis is enumerated on (mixes replace the
#: per-dim ILP recipe, so they only compose with the plain-proximity base)
MIX_STRATEGIES = ("pluto",)
#: cap on explicit SCC-derived statement-group variants per kernel
MAX_GROUP_VARIANTS = 2


@dataclass(frozen=True)
class TunedConfig:
    """One point of the kernel-specific search space."""
    strategy: str                       # key into config.STRATEGIES
    tile: Optional[Union[int, str]] = None   # None | int | 'l1' | 'l2'
    wavefront: bool = False
    autovec: bool = False
    fusion: str = "smart"               # 'smart' | 'max' | 'no' | 'groups'
    #: explicit statement groups (fusion == 'groups'), outermost dim
    fusion_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    mix: Optional[str] = None           # key into costs.COST_MIXES

    @property
    def label(self) -> str:
        bits = [self.strategy]
        if self.mix:
            bits.append(f"mix{self.mix}")
        if self.fusion == "groups" and self.fusion_groups:
            bits.append("fg" + "-".join(
                "".join(str(i) for i in g) for g in self.fusion_groups))
        elif self.fusion != "smart":
            bits.append(f"f{self.fusion}")
        if self.autovec:
            bits.append("autovec")
        if self.tile is not None:
            bits.append(f"tile{self.tile}")
        if self.wavefront:
            bits.append("wave")
        return "+".join(bits)

    @property
    def base(self) -> "TunedConfig":
        """The schedule-determining part (tile/wavefront are
        post-processing and share the base schedule)."""
        return replace(self, tile=None, wavefront=False)

    @property
    def uses_new_axes(self) -> bool:
        """True when the winning choice exercises the fusion or cost-mix
        axis (the §III-E space beyond strategy×tile×wavefront)."""
        return self.fusion != "smart" or self.mix is not None

    def scheduler_config(self) -> CFG.SchedulerConfig:
        if self.strategy == "original":    # untransformed program order
            return CFG.SchedulerConfig()
        cfg = CFG.STRATEGIES[self.strategy]()
        if self.autovec:
            cfg.auto_vectorize = True
        if self.fusion in ("max", "no"):
            cfg.fusion_mode = self.fusion
        elif self.fusion == "groups" and self.fusion_groups:
            cfg.fusion = [CFG.FusionSpec(
                0, groups=[list(g) for g in self.fusion_groups])]
        if self.mix:
            base_cons = list(cfg.ilp.get("default", CFG.DimConfig()).constraints)
            cfg.ilp = {
                dim: CFG.DimConfig(list(cfs), list(base_cons), rp)
                for dim, (cfs, rp) in C.COST_MIXES[self.mix].items()
            }
            cfg.name = f"{cfg.name}+mix{self.mix}"
        return cfg

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        d = dict(d)
        fg = d.get("fusion_groups")
        if fg is not None:
            d["fusion_groups"] = tuple(tuple(int(i) for i in g) for g in fg)
        return cls(**d)


@dataclass
class TunedResult:
    config: TunedConfig
    static_cost: float = 0.0
    seconds: Optional[float] = None
    checksum: Optional[float] = None
    source: str = "static"              # 'static' | 'measured' | 'cache'
    ranked: List[str] = field(default_factory=list)   # candidate labels, best-first
    ranker: str = "analytic"            # 'analytic' | 'learned'
    #: True when the search itself was compromised (deadline truncation,
    #: reference-measurement failure) — the winner may not be the true
    #: optimum and is never persisted.  Individual candidate failures
    #: alone do not degrade the result: the surviving winner is still a
    #: fully validated measurement.
    degraded: bool = False
    reasons: List[str] = field(default_factory=list)
    #: MeasurementError rows (kind/tag/phase/detail) of every failed
    #: compile-and-measure attempt, including retries and checksum
    #: mismatches — the search's failure log, not an error state
    failures: List[dict] = field(default_factory=list)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["config"] = asdict(self.config)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunedResult":
        cfg = TunedConfig.from_dict(d["config"])
        return cls(cfg, d.get("static_cost", 0.0), d.get("seconds"),
                   d.get("checksum"), "cache", list(d.get("ranked", [])),
                   d.get("ranker", "analytic"),
                   bool(d.get("degraded", False)),
                   list(d.get("reasons", [])),
                   list(d.get("failures", [])))


# ---------------------------------------------------------------------------
# configuration enumeration
# ---------------------------------------------------------------------------


def scc_group_variants(scop: Scop, deps=None) -> List[Tuple[Tuple[int, ...], ...]]:
    """Explicit FusionSpec statement groups derived from the SCC
    condensation of the dependence graph: adjacent SCCs (in topological
    order) merged pairwise — legal by construction, and points *between*
    total distribution and maximal fusion.  Bounded and deterministic."""
    stmts = scop.statements
    if len(stmts) < 3:
        return []           # with ≤2 statements 'max'/'no' already cover this
    if deps is None:
        from .deps import compute_dependences
        deps = compute_dependences(scop)
    for d in deps:
        d.satisfied_at = None
    sccs = _scc_groups(stmts, deps)
    if not 2 <= len(sccs) <= 6:
        return []
    out: List[Tuple[Tuple[int, ...], ...]] = []
    for i in range(min(len(sccs) - 1, MAX_GROUP_VARIANTS)):
        groups = (sccs[:i] + [sorted(sccs[i] + sccs[i + 1])] + sccs[i + 2:])
        if len(groups) < 2:
            continue     # all statements in one group ≡ 'max', already enumerated
        out.append(tuple(tuple(g) for g in groups))
    return out


def base_configs(scop: Scop, deps=None) -> List[TunedConfig]:
    """Schedule-determining configuration bases: strategy × fusion ×
    cost-mix (+ tensor autovec).  Deterministic order; tile/wavefront
    variants are layered on later by :func:`candidate_space`."""
    out: List[TunedConfig] = [TunedConfig(s) for s in TUNE_STRATEGIES]
    out.append(TunedConfig("tensor", autovec=True))
    if len(scop.statements) > 1:
        for strat in FUSION_STRATEGIES:
            for fm in ("max", "no"):
                out.append(TunedConfig(strat, fusion=fm))
        for groups in scc_group_variants(scop, deps):
            out.append(TunedConfig("pluto", fusion="groups",
                                   fusion_groups=groups))
    for strat in MIX_STRATEGIES:
        for mix in sorted(C.COST_MIXES):
            out.append(TunedConfig(strat, mix=mix))
    return out


def _schedules_for_space(scop: Scop, cache: ScheduleCache,
                         bases: Optional[Sequence[TunedConfig]] = None,
                         deadline: Optional[Deadline] = None,
                         reasons: Optional[List[str]] = None
                         ) -> Dict[TunedConfig, Schedule]:
    """One schedule per configuration base — structural-cache lookups
    after the first tuning of a kernel shape.  Each miss computes its
    own dependences so cached Schedule objects never share mutable
    dependence state across candidates.  Bases whose configuration
    cannot schedule (an illegal fusion spec, an infeasible
    require-parallel demand) are dropped — any *other* exception is a
    real defect in the enumerated space and propagates loudly instead
    of silently shrinking the search.

    A ``deadline`` breach (checked at each base boundary and inside the
    scheduler's dimension loop) *truncates* enumeration rather than
    raising: the bases already scheduled stay usable, and the truncation
    is appended to ``reasons`` so the caller can mark its result
    degraded."""
    from .scheduler import SchedulingError

    if bases is None:
        bases = base_configs(scop)
    scheds: Dict[TunedConfig, Schedule] = {}
    for base in bases:
        if deadline is not None and deadline.expired():
            if reasons is not None:
                reasons.append(
                    f"enumeration truncated at {base.label!r}: deadline "
                    f"({deadline.elapsed():.3f}s > {deadline.budget_s:.3f}s)")
            break
        try:
            scheds[base] = cached_schedule_scop(
                scop, base.scheduler_config(), cache=cache,
                deadline=deadline)
        except SchedulingError:
            continue
        except DeadlineExceeded as e:
            if reasons is not None:
                reasons.append(f"enumeration truncated at {base.label!r}: {e}")
            break
    return scheds


def candidate_space(scop: Scop, scheds: Dict[TunedConfig, Schedule]
                    ) -> List[TunedConfig]:
    """The bounded, deterministic search space: every *structurally
    distinct* base schedule (fingerprint-deduplicated, first base wins)
    plus its tile/wavefront variants where the schedule shape admits
    them."""
    out: List[TunedConfig] = []
    seen: Dict[str, TunedConfig] = {}
    for base, sched in scheds.items():
        fp = schedule_fingerprint(sched)
        if fp in seen:
            continue
        seen[fp] = base
        out.append(base)
        if base.strategy not in TILED_STRATEGIES:
            continue
        bands = find_tilable_bands(sched)
        if not bands:
            continue
        out.append(replace(base, tile="l1"))
        out.append(replace(base, tile="l2"))
        out.append(replace(base, tile=32))
        if any(b.length >= 2 and not b.parallel_first for b in bands):
            # pipelined-parallel shape: wavefront variants
            out.append(replace(base, tile="l2", wavefront=True))
            out.append(replace(base, tile=32, wavefront=True))
    return out


# ---------------------------------------------------------------------------
# static cost model
# ---------------------------------------------------------------------------

# relative per-iteration access costs (arbitrary units ~ cache-line moves)
_COST_INVARIANT = 0.05     # register / L1-resident scalar
_COST_CONTIG = 0.125       # stride-1: one line per line_elems iterations
_COST_STRIDED = 1.0        # one line per iteration
_SIMD_FACTOR = 0.55        # innermost simd-legal all-contiguous loop
_REUSE_FACTOR = 0.35       # temporal reuse captured in-cache
_WAVE_PENALTY = 1.08       # wavefront bound overhead (single-core container)


def _stmt_trip(scop: Scop, stmt) -> float:
    """Box-volume iteration estimate with concrete parameter values.
    Identical across candidate schedules of the same SCoP, so it only
    weights statements against each other."""
    from .cachemodel import stmt_iter_ranges

    trip = 1.0
    for rng in stmt_iter_ranges(scop, stmt).values():
        if rng is None:
            trip *= 100.0
        else:
            trip *= max(1.0, float(rng[1] - rng[0]) + 1.0)
    return trip


def static_cost(scop: Scop, sched: Schedule, tc: TunedConfig,
                spec: Optional[CacheSpec] = None,
                trips: Optional[Dict[int, float]] = None,
                memo: Optional[dict] = None) -> float:
    """Estimated relative runtime of ``tc`` applied to ``sched``.

    ``trips`` (statement index → box-volume iteration estimate) is
    SCoP-invariant and ``memo`` caches the per-(schedule, tile-source)
    intermediates (scan, bands, access groups, cache-model tile sizes):
    candidates share 1-2 schedules, so callers scoring the whole space
    pass both to avoid recomputing LP extents per candidate."""
    spec = spec or default_spec()
    if trips is None:
        trips = {s.index: _stmt_trip(scop, s) for s in scop.statements}
    memo = {} if memo is None else memo
    sid = id(sched)
    scan = shared_scan(sched, memo)
    bands = shared_bands(sched, memo) if tc.tile is not None else []
    tiled_ws_ok: Dict[int, bool] = {}
    if tc.tile is not None and bands:
        wskey = ("wsok", sid, str(tc.tile))
        if wskey not in memo:
            sizes_by_band = shared_tile_sizes(sched, memo, tc.tile, spec)
            ok: Dict[int, bool] = {}
            for b in bands:
                groups = shared_groups(sched, memo, b.start, b.length)
                ws = working_set_bytes(groups, sizes_by_band.get(
                    b.start, [32] * b.length), spec.elem_bytes)
                ok[b.start] = ws <= spec.l2_bytes
            memo[wskey] = ok
        tiled_ws_ok = memo[wskey]
    total = 0.0
    for ss in scan:
        stmt = ss.stmt
        try:
            subst = iterator_substitution(ss)
        except ValueError:
            total += trips[stmt.index] * _COST_STRIDED * len(stmt.accesses)
            continue
        # innermost linear scan dim
        inner = None
        for d in range(ss.n_dims() - 1, -1, -1):
            phi = ss.dims[d].phi
            if any(it in stmt.iters for it in phi):
                inner = d
                break
        if inner is None:
            continue

        def coeff(e, d):
            c = Fraction(0)
            for it, v in e.items():
                if it in subst:
                    c += v * subst[it].get(_yvar(d), Fraction(0))
            return c

        cost = 0.0
        all_vec_friendly = True
        for acc in stmt.accesses:
            cs = [coeff(e, inner) for e in acc.subscripts]
            moves_inner = any(c != 0 for c in cs)
            contiguous = (
                moves_inner and abs(cs[-1]) == 1
                and all(c == 0 for c in cs[:-1])
            )
            if not moves_inner:
                a = _COST_INVARIANT
            elif contiguous:
                a = _COST_CONTIG
            else:
                a = _COST_STRIDED
                all_vec_friendly = False
            # temporal reuse along a non-innermost band dim: captured when
            # a tile working set fits the budget
            if tc.tile is not None and a >= _COST_CONTIG:
                for b in bands:
                    if not tiled_ws_ok.get(b.start):
                        continue
                    dims_in_b = [d for d in range(b.start, b.start + b.length)
                                 if d != inner]
                    if any(all(coeff(e, d) == 0 for e in acc.subscripts)
                           for d in dims_in_b):
                        a *= _REUSE_FACTOR
                        break
            cost += a
        if all_vec_friendly and level_parallel(sched, [ss], inner):
            cost *= _SIMD_FACTOR
        total += trips[stmt.index] * max(cost, 1e-3)
    if tc.wavefront:
        total *= _WAVE_PENALTY
    return total


# ---------------------------------------------------------------------------
# source building + measurement
# ---------------------------------------------------------------------------


def build_source(scop: Scop, tc: TunedConfig, sched: Schedule,
                 scalars: Optional[Dict[str, float]] = None,
                 repeats: int = 1) -> str:
    from .cbackend import CCodeGenerator

    scan = (tile_schedule(sched, tc.tile, wavefront=tc.wavefront)
            if tc.tile is not None else None)
    return CCodeGenerator(sched, scan=scan, scalars=scalars,
                          repeats=repeats).generate()


def _ref_source(scop: Scop, scalars) -> str:
    """C source of the untransformed program order — the correctness
    anchor every measured candidate must checksum-match."""
    from .cbackend import CCodeGenerator

    sched = PolyTOPSScheduler(scop, CFG.SchedulerConfig())._fallback_original()
    return CCodeGenerator(sched, scalars=scalars).generate()


def _original_reference(scop: Scop, scalars, use_cache: bool):
    """Measured reference checksum/seconds (no retry policy — callers
    needing record/retry/exclude go through autotune's loop)."""
    from .crunner import measure_source

    return measure_source(_ref_source(scop, scalars),
                          tag=f"tune_{scop.name}_orig", use_cache=use_cache)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


#: backoff before the single retry of a failed measurement — long
#: enough to ride out a transient (a scheduler blip, an injected
#: one-shot fault), short enough not to dominate the search
RETRY_BACKOFF_S = 0.05


def autotune(scop: Scop, *, scalars: Optional[Dict[str, float]] = None,
             measure: bool = True, top_k: int = 8,
             cache: Optional[ScheduleCache] = None, use_cache: bool = True,
             spec: Optional[CacheSpec] = None,
             checksum_rel: float = 1e-6,
             deadline: Optional[Deadline] = None) -> TunedResult:
    """Pick a kernel-specific configuration for ``scop``.

    With ``measure=True`` the ``top_k`` statically-ranked candidates are
    compiled and timed (crunner's result cache makes repeats free); with
    ``measure=False`` the static ranking alone decides.  Winners persist
    in the schedule-cache pool — the second call for the same kernel
    shape returns the tuned config without scheduling or compiling
    anything (``result.source == 'cache'``).

    Failure policy: a candidate whose compile-and-measure attempt dies
    with a typed :class:`~repro.core.resilience.MeasurementError`
    (source blowup, gcc timeout/failure, crashing or hanging binary,
    parse error) is recorded in ``result.failures``, retried once after
    a short backoff, then excluded; checksum mismatches are recorded
    the same way and excluded without retry (a wrong answer is
    deterministic, not transient).  The search never raises for a
    candidate failure — it returns the best *surviving* measured
    candidate, or the analytic winner when nothing could be measured.
    A ``deadline`` is checked at every enumeration and candidate
    boundary; a breach truncates the search with best-so-far and marks
    the result ``degraded`` (degraded winners are never persisted).
    """
    spec = spec or default_spec()
    cache = cache or global_cache()
    scalars = {k: v for k, v in (scalars or {}).items() if k in scop.scalars}
    for sc in scop.scalars:
        scalars.setdefault(sc, 1.0)     # match the oracle's default
    from .crunner import CFLAGS, compiler_version

    space_desc = {
        "version": SPACE_VERSION,
        "strategies": list(TUNE_STRATEGIES),
        "fusion": list(FUSION_STRATEGIES),
        "mixes": sorted(C.COST_MIXES),
        "measure": bool(measure),
        "top_k": int(top_k),
        "analytic_guard": max(3, int(top_k) // 2),
        "measure_bases": True,
        "l1": spec.l1_bytes, "l2": spec.l2_bytes,
        "elem": spec.elem_bytes,
        "scalars": sorted(scalars.items()),
        "checksum_rel": checksum_rel,
        # winners were measured under a specific toolchain: a compiler
        # upgrade or flag change invalidates them, same as crunner's
        # result cache
        "cflags": list(CFLAGS),
        "gcc": compiler_version(),
    }
    key = autotune_key(scop, space_desc) if use_cache else None
    hit = cache.get(key)
    if isinstance(hit, dict) and "config" in hit:
        # winner replay: no enumeration, no scheduling, no compilation
        return TunedResult.from_dict(hit)

    # use_cache=False must mean *no* caching anywhere: candidate
    # schedules go through a throwaway in-memory cache, not the shared
    # pool (else POLYTOPS_NO_CACHE runs would serve stale schedules)
    sched_cache = cache if use_cache else ScheduleCache(disk=False)
    reasons: List[str] = []
    failures: List[dict] = []
    scheds = _schedules_for_space(scop, sched_cache, deadline=deadline,
                                  reasons=reasons)
    cands = candidate_space(scop, scheds)
    if not cands:
        return TunedResult(TunedConfig("pluto"), source="static",
                           degraded=bool(reasons), reasons=reasons)
    trips = {s.index: _stmt_trip(scop, s) for s in scop.statements}
    memo: dict = {}

    # the learned ranker replaces the analytic ordering once the pool
    # holds enough measured triples of the current search space; the
    # analytic cost stays as a feature (and as the fallback)
    from . import ranker as RK
    model = RK.fit_ranker(load_measurements(cache, SPACE_VERSION)
                          if use_cache else [])
    feats_by_label: Dict[str, List[float]] = {}
    scored: List[Tuple[float, int, TunedConfig, float]] = []
    for i, tc in enumerate(cands):
        sched = scheds[tc.base]
        cost = static_cost(scop, sched, tc, spec, trips, memo)
        feats = RK.features(scop, sched, tc, cost, spec, trips, memo)
        feats_by_label[tc.label] = feats
        score = model.predict(feats) if model is not None else cost
        scored.append((score, i, tc, cost))
    scored.sort(key=lambda t: (t[0], t[1]))
    ranked_labels = [tc.label for _, _, tc, _ in scored]
    ranker_name = "learned" if model is not None else "analytic"

    # measured set: the primary ranking's top_k, plus the analytic
    # prior's top picks whenever the learned model decided the order —
    # a cold-start guard: a ridge model fitted on a few kernels can
    # misrank an unseen kernel and silently drop the true winner from
    # the measured set, which the prior's picks cap at a bounded cost
    measured_set: List[Tuple[float, int, TunedConfig, float]] = list(scored[:top_k])
    have = {t[2] for t in measured_set}
    if model is not None:
        by_analytic = sorted(scored, key=lambda t: (t[3], t[1]))
        for t in by_analytic[:max(3, top_k // 2)]:
            if t[2] not in have:
                measured_set.append(t)
                have.add(t[2])
    # every structurally distinct *base* schedule is measured at least
    # once: the strategy/fusion/mix axes change the loop structure, which
    # is exactly where both rankers are least reliable, and the base
    # count is already fingerprint-deduplicated and small.  Ranking
    # prunes only the tile/wavefront fan-out.
    for t in scored:
        if t[2].tile is None and not t[2].wavefront and t[2] not in have:
            measured_set.append(t)
            have.add(t[2])

    best: Optional[TunedResult] = None
    ref = None
    if measure:
        from .crunner import checksums_match, measure_source

        def _measure_once(make_src, tag: str):
            """One compile-and-measure attempt with the shared failure
            policy: a typed MeasurementError is recorded and retried
            once after a backoff; a second failure (or any untyped
            codegen exception) excludes the candidate (returns None)."""
            for attempt in (1, 2):
                try:
                    return measure_source(make_src(), tag=tag,
                                          use_cache=use_cache)
                except MeasurementError as e:
                    failures.append(dict(e.row(), attempt=attempt))
                    if attempt == 1:
                        time.sleep(RETRY_BACKOFF_S)
                except (KeyboardInterrupt, SystemExit):
                    raise
                except Exception as e:   # untyped codegen defect: exclude,
                    failures.append({    # no retry (it is deterministic)
                        "kind": "codegen_error", "tag": tag,
                        "phase": "codegen",
                        "detail": f"{type(e).__name__}: {e}"[:200],
                        "attempt": attempt})
                    return None
            return None

        ref = _measure_once(
            lambda: _ref_source(scop, scalars),
            f"tune_{scop.name}_orig")
        if ref is None:
            reasons.append("reference measurement failed twice: "
                           "no checksum oracle, falling back to static "
                           "ranking")
        triples: List[dict] = []
        for _, _, tc, cost in (measured_set if ref is not None else []):
            if deadline is not None and deadline.expired():
                reasons.append(
                    f"measurement truncated at {tc.label!r}: deadline "
                    f"({deadline.elapsed():.3f}s > {deadline.budget_s:.3f}s)")
                break
            sched = scheds[tc.base]
            r = _measure_once(
                lambda tc=tc, sched=sched:
                    build_source(scop, tc, sched, scalars),
                f"tune_{scop.name}_{tc.label}")
            if r is None:
                continue                 # recorded + retried above: exclude
            if not checksums_match(r.checksum, ref.checksum, checksum_rel):
                # wrong answer: deterministic, so no retry — record the
                # mismatch as a typed failure row and discard
                failures.append(MeasurementError(
                    "checksum_mismatch", tag=f"tune_{scop.name}_{tc.label}",
                    phase="validate",
                    detail=f"got {r.checksum!r}, want {ref.checksum!r}"
                ).row())
                continue
            triples.append({
                "kernel": scop.name, "label": tc.label,
                "feats": feats_by_label[tc.label], "seconds": r.seconds,
                "v": SPACE_VERSION, "fv": RK.FEATURE_VERSION,
            })
            if best is None or r.seconds < best.seconds:
                best = TunedResult(tc, cost, r.seconds, r.checksum,
                                   "measured", ranked_labels, ranker_name)
        if use_cache:
            record_measurements(cache, triples)
        if best is None and ref is not None:
            # every measured candidate was rejected (compile failure or
            # wrong checksum): return the original program order — the
            # reference we just measured and know is correct — and do
            # NOT persist; caching a config we just saw fail (or never
            # validated) would poison every future compile of this
            # kernel shape
            return TunedResult(TunedConfig("original"), seconds=ref.seconds,
                               checksum=ref.checksum, source="measured",
                               ranked=ranked_labels, ranker=ranker_name,
                               degraded=bool(reasons), reasons=reasons,
                               failures=failures)
    if best is None:
        _, _, tc, cost = scored[0]
        best = TunedResult(tc, cost, source="static", ranked=ranked_labels,
                           ranker=ranker_name)
    best.degraded = bool(reasons)
    best.reasons = reasons
    best.failures = failures
    if measure and best.source == "measured" and not best.degraded \
            and key is not None:
        # only clean *measured* winners persist: a static winner can
        # depend on the learned ranker's pool state, which the
        # pool-independent autotune_key cannot encode, and a degraded
        # winner reflects a truncated search — replaying either would
        # serve a stale or unlucky answer to every future compile of
        # this kernel shape
        cache.put(key, best.to_dict())
    return best


# ---------------------------------------------------------------------------
# backend-aware candidate lowering: the same enumerated configuration
# space, ranked by the same static model, but lowered to Pallas
# KernelPlans through the schedule tree instead of C sources — so the
# autotuner can rank TPU kernel plans too.
# ---------------------------------------------------------------------------


@dataclass
class PallasCandidate:
    """One Pallas lowering: a scheduler configuration, its schedule tree
    lowered to a :class:`~repro.core.akg.KernelPlan`, and the analytic
    cost that ranked it (shared with the CPU measurement path)."""
    config: TunedConfig
    plan: object                       # repro.core.akg.KernelPlan
    static_cost: float


def rank_pallas_plans(scop: Scop, *, top_k: int = 4,
                      cache: Optional[ScheduleCache] = None,
                      use_cache: bool = True,
                      spec: Optional[CacheSpec] = None,
                      deadline: Optional[Deadline] = None
                      ) -> List[PallasCandidate]:
    """Enumerate the schedule-determining bases (strategy × fusion ×
    cost mix, fingerprint-deduplicated like :func:`autotune`), rank them
    with the static cost model, and lower the best trees to
    :class:`~repro.core.akg.KernelPlan`\\ s, best-first.

    Tile/wavefront variants are deliberately excluded: BlockSpec tile
    fitting is the lowering's job (VMEM budget + lane/sublane snapping),
    not a search axis.  Deterministic: candidate order, ranking
    tie-breaks and the lowering are all pure functions of the SCoP."""
    from .akg import lower_to_kernel_plan

    spec = spec or default_spec()
    cache = cache or global_cache()
    sched_cache = cache if use_cache else ScheduleCache(disk=False)
    scheds = _schedules_for_space(scop, sched_cache, deadline=deadline)
    bases = [tc for tc in candidate_space(scop, scheds)
             if tc.tile is None and not tc.wavefront]
    trips = {s.index: _stmt_trip(scop, s) for s in scop.statements}
    memo: dict = {}
    scored = sorted(
        ((static_cost(scop, scheds[tc.base], tc, spec, trips, memo), i, tc)
         for i, tc in enumerate(bases)),
        key=lambda t: (t[0], t[1]))
    out: List[PallasCandidate] = []
    for cost, _, tc in scored:
        if len(out) >= top_k:
            break
        if deadline is not None and deadline.expired():
            break          # best-so-far: the list is already best-first
        sched = scheds[tc.base]
        try:
            plan = lower_to_kernel_plan(schedule_tree(sched), sched=sched)
        except ValueError:
            continue       # non-invertible/unbounded schedule: not lowerable
        out.append(PallasCandidate(tc, plan, cost))
    return out
