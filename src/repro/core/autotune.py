"""Kernel-specific autotuning (paper §III-E / §IV-B "kernel-specific").

The paper's headline PolyBench numbers come from *kernel-specific
configurations* — per-kernel choices of cost functions, fusion,
vectorization and tiling.  This module turns the repo's former
"measure every standard strategy, keep the best" stand-in into a real
bounded autotuner:

1. **Candidate space** — scheduling strategy × tile source (none /
   cache-model L1 / cache-model L2 / fixed 32) × wavefront ×
   auto-vectorization, pruned by schedule structure (tile and wavefront
   candidates only exist when the schedule has a tilable band /
   a dependence-carrying first band dim).  Candidate *schedules* are
   near-free: they come through the structural schedule cache
   (:mod:`repro.core.schedcache`) backed by PR 1's incremental ILP core.
2. **Static ranking** — a cost model over the schedule's access strides
   (contiguity of the innermost dim, SIMD legality, temporal reuse
   captured by the tile working set vs the cache budget) ranks all
   candidates without compiling anything.
3. **Measurement** — only the ``top_k`` statically-ranked candidates are
   compiled and timed through :mod:`repro.core.crunner`; each must
   checksum-match the original-program-order reference or it is
   discarded (measurement is also how model mistakes get corrected).
4. **Persistence** — the winner is stored in the schedule-cache pool
   keyed by SCoP structure + search-space version
   (:func:`repro.core.schedcache.autotune_key`), so the second compile
   of the same kernel shape is a dictionary/disk lookup.

Everything is deterministic: candidate order is fixed, ranking
tie-breaks on candidate index, and measurements go through crunner's
on-disk result cache, so re-tuning the same kernel returns the same
configuration.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Tuple, Union

from . import config as CFG
from .cachemodel import (CacheSpec, auto_tile_sizes, band_access_groups,
                         default_spec, working_set_bytes)
from .codegen import (_yvar, iterator_substitution, level_parallel,
                      scan_from_schedule)
from .postproc import find_tilable_bands, tile_schedule
from .schedcache import ScheduleCache, autotune_key, cached_schedule_scop, \
    global_cache
from .scheduler import PolyTOPSScheduler, Schedule
from .scop import Scop

SPACE_VERSION = 1          # bump when the candidate space / model changes

#: strategies the autotuner explores (isl-style is excluded: its dynamic
#: Python callback makes schedules uncacheable — see schedcache)
TUNE_STRATEGIES = ("pluto", "tensor", "bigloops", "feautrier")
TILED_STRATEGIES = ("pluto", "tensor")


@dataclass(frozen=True)
class TunedConfig:
    """One point of the kernel-specific search space."""
    strategy: str                       # key into config.STRATEGIES
    tile: Optional[Union[int, str]] = None   # None | int | 'l1' | 'l2'
    wavefront: bool = False
    autovec: bool = False

    @property
    def label(self) -> str:
        bits = [self.strategy]
        if self.autovec:
            bits.append("autovec")
        if self.tile is not None:
            bits.append(f"tile{self.tile}")
        if self.wavefront:
            bits.append("wave")
        return "+".join(bits)

    def scheduler_config(self) -> CFG.SchedulerConfig:
        if self.strategy == "original":    # untransformed program order
            return CFG.SchedulerConfig()
        cfg = CFG.STRATEGIES[self.strategy]()
        if self.autovec:
            cfg.auto_vectorize = True
        return cfg


@dataclass
class TunedResult:
    config: TunedConfig
    static_cost: float = 0.0
    seconds: Optional[float] = None
    checksum: Optional[float] = None
    source: str = "static"              # 'static' | 'measured' | 'cache'
    ranked: List[str] = field(default_factory=list)   # candidate labels, best-first

    def to_dict(self) -> dict:
        d = asdict(self)
        d["config"] = asdict(self.config)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunedResult":
        cfg = TunedConfig(**d["config"])
        return cls(cfg, d.get("static_cost", 0.0), d.get("seconds"),
                   d.get("checksum"), "cache", list(d.get("ranked", [])))


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------


def candidate_space(scop: Scop, scheds: Dict[Tuple[str, bool], Schedule]
                    ) -> List[TunedConfig]:
    """The bounded, deterministic search space.  ``scheds`` maps
    (strategy, autovec) to the already-computed schedule (needed to know
    whether tiling / wavefronting even applies)."""
    out: List[TunedConfig] = []
    for strat in TUNE_STRATEGIES:
        base = scheds.get((strat, False))
        if base is None:
            continue
        out.append(TunedConfig(strat))
        if strat == "tensor" and (strat, True) in scheds:
            out.append(TunedConfig(strat, autovec=True))
        if strat not in TILED_STRATEGIES:
            continue
        bands = find_tilable_bands(base)
        if not bands:
            continue
        out.append(TunedConfig(strat, tile="l1"))
        out.append(TunedConfig(strat, tile="l2"))
        out.append(TunedConfig(strat, tile=32))
        if any(b.length >= 2 and not b.parallel_first for b in bands):
            # pipelined-parallel shape: wavefront variants
            out.append(TunedConfig(strat, tile="l2", wavefront=True))
            out.append(TunedConfig(strat, tile=32, wavefront=True))
    return out


def _schedules_for_space(scop: Scop, cache: ScheduleCache
                         ) -> Dict[Tuple[str, bool], Schedule]:
    """One schedule per (strategy, autovec) base — structural-cache
    lookups after the first tuning of a kernel shape.  Each miss computes
    its own dependences so cached Schedule objects never share mutable
    dependence state across candidates."""
    scheds: Dict[Tuple[str, bool], Schedule] = {}
    for strat in TUNE_STRATEGIES:
        try:
            scheds[(strat, False)] = cached_schedule_scop(
                scop, CFG.STRATEGIES[strat](), cache=cache)
        except Exception:
            continue
        if strat == "tensor":
            cfg = CFG.STRATEGIES[strat]()
            cfg.auto_vectorize = True
            try:
                scheds[(strat, True)] = cached_schedule_scop(scop, cfg,
                                                             cache=cache)
            except Exception:
                pass
    return scheds


# ---------------------------------------------------------------------------
# static cost model
# ---------------------------------------------------------------------------

# relative per-iteration access costs (arbitrary units ~ cache-line moves)
_COST_INVARIANT = 0.05     # register / L1-resident scalar
_COST_CONTIG = 0.125       # stride-1: one line per line_elems iterations
_COST_STRIDED = 1.0        # one line per iteration
_SIMD_FACTOR = 0.55        # innermost simd-legal all-contiguous loop
_REUSE_FACTOR = 0.35       # temporal reuse captured in-cache
_WAVE_PENALTY = 1.08       # wavefront bound overhead (single-core container)


def _stmt_trip(scop: Scop, stmt) -> float:
    """Box-volume iteration estimate with concrete parameter values.
    Identical across candidate schedules of the same SCoP, so it only
    weights statements against each other."""
    from .polyhedron import maximum, minimum

    cons = list(stmt.domain) + scop.param_rows()
    trip = 1.0
    for it in stmt.iters:
        hi = maximum(cons, {it: Fraction(1)})
        lo = minimum(cons, {it: Fraction(1)})
        if hi is None or lo is None:
            trip *= 100.0
        else:
            trip *= max(1.0, float(hi - lo) + 1.0)
    return trip


def static_cost(scop: Scop, sched: Schedule, tc: TunedConfig,
                spec: Optional[CacheSpec] = None,
                trips: Optional[Dict[int, float]] = None,
                memo: Optional[dict] = None) -> float:
    """Estimated relative runtime of ``tc`` applied to ``sched``.

    ``trips`` (statement index → box-volume iteration estimate) is
    SCoP-invariant and ``memo`` caches the per-(schedule, tile-source)
    intermediates (scan, bands, access groups, cache-model tile sizes):
    candidates share 1-2 schedules, so callers scoring the whole space
    pass both to avoid recomputing LP extents per candidate."""
    spec = spec or default_spec()
    if trips is None:
        trips = {s.index: _stmt_trip(scop, s) for s in scop.statements}
    memo = {} if memo is None else memo
    sid = id(sched)
    if ("scan", sid) not in memo:
        memo[("scan", sid)] = scan_from_schedule(sched)
    scan = memo[("scan", sid)]
    bands = []
    if tc.tile is not None:
        if ("bands", sid) not in memo:
            memo[("bands", sid)] = find_tilable_bands(sched)
        bands = memo[("bands", sid)]
    tiled_ws_ok: Dict[int, bool] = {}
    if tc.tile is not None and bands:
        wskey = ("wsok", sid, str(tc.tile))
        if wskey not in memo:
            sizes_by_band = (
                {b.start: [int(tc.tile)] * b.length for b in bands}
                if isinstance(tc.tile, int)
                else auto_tile_sizes(sched, level=str(tc.tile), spec=spec,
                                     bands=bands)
            )
            ok: Dict[int, bool] = {}
            for b in bands:
                gkey = ("groups", sid, b.start)
                if gkey not in memo:
                    memo[gkey] = band_access_groups(scan, b.start, b.length)
                ws = working_set_bytes(memo[gkey], sizes_by_band.get(
                    b.start, [32] * b.length), spec.elem_bytes)
                ok[b.start] = ws <= spec.l2_bytes
            memo[wskey] = ok
        tiled_ws_ok = memo[wskey]
    total = 0.0
    for ss in scan:
        stmt = ss.stmt
        try:
            subst = iterator_substitution(ss)
        except ValueError:
            total += trips[stmt.index] * _COST_STRIDED * len(stmt.accesses)
            continue
        # innermost linear scan dim
        inner = None
        for d in range(ss.n_dims() - 1, -1, -1):
            phi = ss.dims[d].phi
            if any(it in stmt.iters for it in phi):
                inner = d
                break
        if inner is None:
            continue

        def coeff(e, d):
            c = Fraction(0)
            for it, v in e.items():
                if it in subst:
                    c += v * subst[it].get(_yvar(d), Fraction(0))
            return c

        cost = 0.0
        all_vec_friendly = True
        for acc in stmt.accesses:
            cs = [coeff(e, inner) for e in acc.subscripts]
            moves_inner = any(c != 0 for c in cs)
            contiguous = (
                moves_inner and abs(cs[-1]) == 1
                and all(c == 0 for c in cs[:-1])
            )
            if not moves_inner:
                a = _COST_INVARIANT
            elif contiguous:
                a = _COST_CONTIG
            else:
                a = _COST_STRIDED
                all_vec_friendly = False
            # temporal reuse along a non-innermost band dim: captured when
            # a tile working set fits the budget
            if tc.tile is not None and a >= _COST_CONTIG:
                for b in bands:
                    if not tiled_ws_ok.get(b.start):
                        continue
                    dims_in_b = [d for d in range(b.start, b.start + b.length)
                                 if d != inner]
                    if any(all(coeff(e, d) == 0 for e in acc.subscripts)
                           for d in dims_in_b):
                        a *= _REUSE_FACTOR
                        break
            cost += a
        if all_vec_friendly and level_parallel(sched, [ss], inner):
            cost *= _SIMD_FACTOR
        total += trips[stmt.index] * max(cost, 1e-3)
    if tc.wavefront:
        total *= _WAVE_PENALTY
    return total


# ---------------------------------------------------------------------------
# source building + measurement
# ---------------------------------------------------------------------------


def build_source(scop: Scop, tc: TunedConfig, sched: Schedule,
                 scalars: Optional[Dict[str, float]] = None,
                 repeats: int = 1) -> str:
    from .cbackend import CCodeGenerator

    scan = (tile_schedule(sched, tc.tile, wavefront=tc.wavefront)
            if tc.tile is not None else None)
    return CCodeGenerator(sched, scan=scan, scalars=scalars,
                          repeats=repeats).generate()


def _original_reference(scop: Scop, scalars, use_cache: bool):
    """Checksum of the untransformed program order — the correctness
    anchor every measured candidate must reproduce."""
    from .cbackend import CCodeGenerator
    from .crunner import measure_source

    sched = PolyTOPSScheduler(scop, CFG.SchedulerConfig())._fallback_original()
    src = CCodeGenerator(sched, scalars=scalars).generate()
    return measure_source(src, tag=f"tune_{scop.name}_orig",
                          use_cache=use_cache)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


def autotune(scop: Scop, *, scalars: Optional[Dict[str, float]] = None,
             measure: bool = True, top_k: int = 5,
             cache: Optional[ScheduleCache] = None, use_cache: bool = True,
             spec: Optional[CacheSpec] = None,
             checksum_rel: float = 1e-6) -> TunedResult:
    """Pick a kernel-specific configuration for ``scop``.

    With ``measure=True`` the ``top_k`` statically-ranked candidates are
    compiled and timed (crunner's result cache makes repeats free); with
    ``measure=False`` the static ranking alone decides.  Winners persist
    in the schedule-cache pool — the second call for the same kernel
    shape returns the tuned config without scheduling or compiling
    anything (``result.source == 'cache'``).
    """
    spec = spec or default_spec()
    cache = cache or global_cache()
    scalars = {k: v for k, v in (scalars or {}).items() if k in scop.scalars}
    for sc in scop.scalars:
        scalars.setdefault(sc, 1.0)     # match the oracle's default
    from .crunner import CFLAGS, compiler_version

    space_desc = {
        "version": SPACE_VERSION,
        "strategies": list(TUNE_STRATEGIES),
        "measure": bool(measure),
        "top_k": int(top_k),
        "l1": spec.l1_bytes, "l2": spec.l2_bytes,
        "elem": spec.elem_bytes,
        "scalars": sorted(scalars.items()),
        "checksum_rel": checksum_rel,
        # winners were measured under a specific toolchain: a compiler
        # upgrade or flag change invalidates them, same as crunner's
        # result cache
        "cflags": list(CFLAGS),
        "gcc": compiler_version(),
    }
    key = autotune_key(scop, space_desc) if use_cache else None
    hit = cache.get(key)
    if isinstance(hit, dict) and "config" in hit:
        return TunedResult.from_dict(hit)

    # use_cache=False must mean *no* caching anywhere: candidate
    # schedules go through a throwaway in-memory cache, not the shared
    # pool (else POLYTOPS_NO_CACHE runs would serve stale schedules)
    sched_cache = cache if use_cache else ScheduleCache(disk=False)
    scheds = _schedules_for_space(scop, sched_cache)
    cands = candidate_space(scop, scheds)
    if not cands:
        return TunedResult(TunedConfig("pluto"), source="static")
    trips = {s.index: _stmt_trip(scop, s) for s in scop.statements}
    memo: dict = {}
    scored: List[Tuple[float, int, TunedConfig]] = []
    for i, tc in enumerate(cands):
        sched = scheds[(tc.strategy, tc.autovec)]
        scored.append((static_cost(scop, sched, tc, spec, trips, memo), i, tc))
    scored.sort(key=lambda t: (t[0], t[1]))
    ranked_labels = [tc.label for _, _, tc in scored]

    best: Optional[TunedResult] = None
    if measure:
        from .crunner import checksums_match, measure_source

        ref = _original_reference(scop, scalars, use_cache)
        for cost, _, tc in scored[:top_k]:
            sched = scheds[(tc.strategy, tc.autovec)]
            try:
                src = build_source(scop, tc, sched, scalars)
                r = measure_source(src, tag=f"tune_{scop.name}_{tc.label}",
                                   use_cache=use_cache)
            except Exception:
                continue                 # compile/codegen failure: skip
            if not checksums_match(r.checksum, ref.checksum, checksum_rel):
                continue                 # wrong answer: discard candidate
            if best is None or r.seconds < best.seconds:
                best = TunedResult(tc, cost, r.seconds, r.checksum,
                                   "measured", ranked_labels)
        if best is None:
            # every measured candidate was rejected (compile failure or
            # wrong checksum): return the original program order — the
            # reference we just measured and know is correct — and do
            # NOT persist; caching a config we just saw fail (or never
            # validated) would poison every future compile of this
            # kernel shape
            return TunedResult(TunedConfig("original"), seconds=ref.seconds,
                               checksum=ref.checksum, source="measured",
                               ranked=ranked_labels)
    if best is None:
        cost, _, tc = scored[0]
        best = TunedResult(tc, cost, source="static", ranked=ranked_labels)
    cache.put(key, best.to_dict())
    return best
