"""MindSpore/AKG hybrid custom-operator SCoPs (paper §IV-A, Table I).

The paper evaluates three NPU custom operators: an LU decomposition,
``trsmL_off_diag`` (paper Listing 4) and ``trsmU_transpose``. Shapes are
(rows × cols) with the columns grouped into 16-wide vector lanes
(`l`/`k` loops), matching Ascend's vector unit; on TPU the 16-lane axis
maps to (a slice of) the 128-lane VPU axis, and on the CPU measurement
backend to one SIMD-width strip (DESIGN.md §2).

The paper's directive configuration — *vectorize k* — is expressed with
the same PolyTOPS directive interface; the baseline is the isl-style
strategy, which (as the paper describes) hoists the parallel ``l``/``k``
dims outermost and loses vectorization.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .config import Directive, DimConfig, SchedulerConfig, tensor_style
from .scop import Scop

V = 16  # vector-lane width of the paper's operators


def make_trsml(rows: int = 16, mid: int = 16, cols: int = 16) -> Scop:
    """trsmL_off_diag (paper Listing 4a): row×mid triangular update of a
    row×cols RHS, cols grouped into 16-lane strips."""
    L = max(cols // V, 1)
    k = Scop("trsml", params={"R": rows, "L": L})
    with k.loop("i", 0, "R"):
        with k.loop("j", 0, "i"):          # triangular, as in paper Listing 4
            with k.loop("l", 0, "L"):
                with k.loop("kv", 0, V):
                    k.stmt(f"inv0[i,l*{V}+kv] = a[i,j] * b[j,l*{V}+kv]")
                    k.stmt(f"b[i,l*{V}+kv] = b[i,l*{V}+kv] - inv0[i,l*{V}+kv]")
    return k


def make_trsmu(rows: int = 16, mid: int = 16, cols: int = 16) -> Scop:
    """trsmU_transpose: like trsmL but the triangular operand is accessed
    transposed (a[j,i]) — the interchange matters even more."""
    L = max(cols // V, 1)
    k = Scop("trsmu", params={"R": max(rows, mid), "L": L})
    with k.loop("i", 0, "R"):
        with k.loop("j", 0, "i"):          # triangular; a accessed transposed
            with k.loop("l", 0, "L"):
                with k.loop("kv", 0, V):
                    k.stmt(f"inv0[i,l*{V}+kv] = a[j,i] * b[j,l*{V}+kv]")
                    k.stmt(f"b[i,l*{V}+kv] = b[i,l*{V}+kv] - inv0[i,l*{V}+kv]")
    return k


def make_lu16(n: int = 16) -> Scop:
    """16×16 LU decomposition block (paper Table I row 1)."""
    k = Scop("lu16", params={"N": n})
    with k.loop("i", 0, "N"):
        with k.loop("j", 0, "i"):
            with k.loop("kk", 0, "j"):
                k.stmt("A[i,j] = A[i,j] - A[i,kk] * A[kk,j]")
            k.stmt("A[i,j] = A[i,j] / A[j,j]")
        with k.loop("j2", "i", "N"):
            with k.loop("k2", 0, "i"):
                k.stmt("A[i,j2] = A[i,j2] - A[i,k2] * A[k2,j2]")
    return k


def directive_config() -> SchedulerConfig:
    """The paper's manual configuration (Listing 4a): parallel(l),
    vectorize(kv); contiguity+proximity for the rest."""
    cfg = tensor_style()
    cfg.name = "polytops-directives"
    cfg.directives = [
        Directive("parallel", [0, 1], 2),
        Directive("vectorize", [0], 3),
        Directive("vectorize", [1], 3),
    ]
    return cfg


def autovec_config() -> SchedulerConfig:
    """§IV-A last paragraph: the same effect from auto-vectorization +
    proximity, with no per-kernel manual directives."""
    cfg = tensor_style()
    cfg.name = "polytops-autovec"
    cfg.auto_vectorize = True
    return cfg


def baseline_config() -> SchedulerConfig:
    """AKG's isl behaviour on the NPU (paper §IV-A): detected-parallel
    loops are hoisted outermost (outer parallelism for block mapping), so
    the contiguous dim ends up away from the innermost position and
    vectorization is lost. Modeled as: demand coincidence (zero-distance)
    for the outer dims, plain proximity once no parallelism remains."""

    def strategy(state) -> DimConfig:
        if state.parallel_failed:
            return DimConfig(cost_functions=["proximity"])
        if state.dim < 2:
            return DimConfig(cost_functions=["proximity"], require_parallel=True)
        return DimConfig(cost_functions=["proximity"])

    return SchedulerConfig(name="akg-isl-style", strategy=strategy)


TABLE1_SIZES: Dict[str, Tuple[Tuple[int, int, int], ...]] = {
    "trsml": tuple((16, 16, c) for c in (16, 32, 48, 64, 80, 96, 112)),
    "trsmu": tuple((16, m, 16) for m in (16, 32, 48, 64, 80, 96, 112)),
}
