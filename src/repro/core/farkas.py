"""Farkas-lemma linearization (paper §II-B2).

Given a polyhedron P = {z | A z + b ≥ 0 (+ equalities)} and an affine
form f(z) whose coefficients are themselves affine expressions over ILP
variables (schedule coefficients T, bounding coefficients u/w, ...), the
affine form of Farkas' lemma states:

    f(z) ≥ 0  ∀ z ∈ P   ⟺   f ≡ λ₀ + Σᵢ λᵢ (Aᵢ z + bᵢ),  λ₀, λᵢ ≥ 0

(multipliers of equality rows are sign-free). Equating coefficients of
each z variable and the constant yields *equality* constraints linking
the fresh multipliers λ to the ILP variables — exactly what
:class:`repro.core.ilp.ILPProblem` consumes.
"""
from __future__ import annotations

import itertools
from fractions import Fraction
from typing import Dict, List, Sequence, Tuple

from .affine import Affine
from .ilp import ILPProblem
from .polyhedron import Constraint

_counter = itertools.count()


def add_farkas_nonneg(
    prob: ILPProblem,
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
    tag: str = "",
) -> None:
    """Add constraints enforcing  f(z) = Σ_z coef_of_z[z]·z + const ≥ 0
    over ``poly``. coef_of_z / const_term are affine over ILP vars.
    """
    uid = next(_counter)
    lam0 = prob.var(f"l{uid}_0{tag}", lb=0, integer=False)
    lams: List[Tuple[str, Constraint]] = []
    for i, (expr, kind) in enumerate(poly):
        name = f"l{uid}_{i + 1}{tag}"
        prob.var(name, lb=0 if kind == ">=0" else None, integer=False)
        lams.append((name, (expr, kind)))

    zvars = set()
    for expr, _ in poly:
        zvars.update(k for k in expr if k != 1)
    zvars.update(coef_of_z)

    # coefficient of each z variable: coef_of_z[z] − Σ λᵢ Aᵢ[z] == 0
    for z in sorted(zvars):
        eq: Affine = dict(coef_of_z.get(z, {}))
        for name, (expr, _) in lams:
            c = expr.get(z, Fraction(0))
            if c:
                eq[name] = eq.get(name, Fraction(0)) - c
        if eq:
            prob.add(eq, "==0")
    # constant: const_term − λ₀ − Σ λᵢ bᵢ == 0
    eq = dict(const_term)
    eq[lam0] = eq.get(lam0, Fraction(0)) - 1
    for name, (expr, _) in lams:
        c = expr.get(1, Fraction(0))
        if c:
            eq[name] = eq.get(name, Fraction(0)) - c
    prob.add(eq, "==0")
