"""Farkas-lemma linearization and exact multiplier projection (§II-B2).

Given a polyhedron P = {z | A z + b ≥ 0 (+ equalities)} and an affine
form f(z) whose coefficients are themselves affine expressions over ILP
variables (schedule coefficients T, bounding coefficients u/w, ...), the
affine form of Farkas' lemma states:

    f(z) ≥ 0  ∀ z ∈ P   ⟺   f ≡ λ₀ + Σᵢ λᵢ (Aᵢ z + bᵢ),  λ₀, λᵢ ≥ 0

(multipliers of equality rows are sign-free). Equating coefficients of
each z variable and the constant yields *equality* constraints linking
the fresh multipliers λ to the ILP variables.

The scheduler no longer ships those multipliers to the solver: the λ
are continuous, appear in no objective, and only bloat the ILP (the
historical cost: hundreds of multiplier columns per kernel dimension).
:func:`project_farkas` eliminates them *exactly* — Gaussian substitution
on the coefficient-matching equalities, then Fourier–Motzkin with
Imbert's acceleration (a row whose ancestor set exceeds the number of
eliminations + 1 is provably redundant and dropped without any LP) and
syntactic pruning.  The result is a small system over the schedule
coefficients alone, equivalent to the multiplier form over ℚ — and
therefore over ℤ, since the λ were never integer-constrained.

Projections are pure functions of (P, f) and dimension-independent, so
they are memoized process-wide: every scheduling dimension, both
pipeline modes (seed and incremental), and repeat benchmark runs replay
the same projected rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, FrozenSet, List, Sequence, Tuple

from .affine import Affine
from .ilp import ILPProblem
from .polyhedron import Constraint, _prune
from .resilience import fault_point


@dataclass
class FarkasExpansion:
    """The multiplier variables and equality rows produced by one Farkas
    linearization — a pure, problem-independent value.  Retained as the
    input representation for :func:`project_farkas` and for differential
    tests against the projected form."""
    multipliers: List[Tuple[str, bool]]       # (name, nonneg?)
    rows: List[Tuple[Affine, str]]            # all '==0'


def farkas_expansion(
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
    prefix: str,
) -> FarkasExpansion:
    """Compute constraints enforcing  f(z) = Σ_z coef_of_z[z]·z + const ≥ 0
    over ``poly``. coef_of_z / const_term are affine over ILP vars.
    Multiplier names are ``{prefix}_0 .. {prefix}_n`` — the caller picks a
    prefix unique within any problem the expansion is replayed into.
    """
    lam0 = f"{prefix}_0"
    multipliers: List[Tuple[str, bool]] = [(lam0, True)]
    lams: List[Tuple[str, Constraint]] = []
    for i, (expr, kind) in enumerate(poly):
        name = f"{prefix}_{i + 1}"
        multipliers.append((name, kind == ">=0"))
        lams.append((name, (expr, kind)))

    zvars = set()
    for expr, _ in poly:
        zvars.update(k for k in expr if k != 1)
    zvars.update(coef_of_z)

    rows: List[Tuple[Affine, str]] = []
    # coefficient of each z variable: coef_of_z[z] − Σ λᵢ Aᵢ[z] == 0
    for z in sorted(zvars):
        eq: Affine = dict(coef_of_z.get(z, {}))
        for name, (expr, _) in lams:
            c = expr.get(z, Fraction(0))
            if c:
                eq[name] = eq.get(name, Fraction(0)) - c
        if eq:
            rows.append((eq, "==0"))
    # constant: const_term − λ₀ − Σ λᵢ bᵢ == 0
    eq = dict(const_term)
    eq[lam0] = eq.get(lam0, Fraction(0)) - 1
    for name, (expr, _) in lams:
        c = expr.get(1, Fraction(0))
        if c:
            eq[name] = eq.get(name, Fraction(0)) - c
    rows.append((eq, "==0"))
    return FarkasExpansion(multipliers, rows)


def replay_farkas(prob: ILPProblem, exp: FarkasExpansion) -> None:
    """Add an expansion's multipliers and rows to a problem verbatim
    (the un-projected form; used by differential tests). Row dicts are
    copied so the cached expansion stays pristine."""
    for name, nonneg in exp.multipliers:
        prob.var(name, lb=0 if nonneg else None, integer=False)
    for expr, kind in exp.rows:
        prob.add(dict(expr), kind)


# ---------------------------------------------------------------------------
# exact multiplier elimination
# ---------------------------------------------------------------------------

_Row = Tuple[Affine, str, FrozenSet[int]]     # (expr, kind, ancestor row ids)
# dedup/domination pruning is shared with every other pruner in the
# repo: polyhedron._prune carries the ancestor field through untouched


def _eliminate(rows: List[_Row], var: str, n_elim: int) -> List[_Row]:
    """Eliminate one variable: substitution via an equality row when one
    exists, Fourier–Motzkin otherwise.  FM combinations whose ancestor
    set exceeds ``n_elim + 2`` source rows are dropped (Imbert's first
    acceleration theorem: after E eliminations any irredundant row has
    at most E+1 ancestors; ``n_elim`` counts eliminations *before* this
    one, so the bound here is E+1 with E = n_elim+1).  The drop is exact
    — such rows are implied by the kept ones."""
    sub = None
    for i, (e, k, anc) in enumerate(rows):
        if k == "==0" and e.get(var):
            sub = (i, e, anc)
            break
    out: List[_Row] = []
    if sub is not None:
        i0, e0, anc0 = sub
        c0 = e0[var]
        rest = {k: v for k, v in e0.items() if k != var}
        for j, (e, k, anc) in enumerate(rows):
            if j == i0:
                continue
            c = e.get(var, Fraction(0))
            if c:
                e2 = {kk: vv for kk, vv in e.items() if kk != var}
                for kk, vv in rest.items():
                    e2[kk] = e2.get(kk, Fraction(0)) - c * vv / c0
                out.append((e2, k, anc | anc0))
            else:
                out.append((e, k, anc))
        return _prune(out)
    lowers, uppers = [], []
    for e, k, anc in rows:
        c = e.get(var, Fraction(0))
        if c == 0:
            out.append((e, k, anc))
            continue
        (lowers if c > 0 else uppers).append((e, c, anc))
    budget = n_elim + 2
    for le, lc, la in lowers:
        for ue, uc, ua in uppers:
            anc = la | ua
            if len(anc) > budget:
                continue
            comb: Affine = {}
            for k, v in le.items():
                comb[k] = comb.get(k, Fraction(0)) + (-uc) * v
            for k, v in ue.items():
                comb[k] = comb.get(k, Fraction(0)) + lc * v
            comb.pop(var, None)
            out.append((comb, ">=0", anc))
    return _prune(out)


def _project(exp: FarkasExpansion) -> List[Constraint]:
    rows: List[_Row] = [(dict(e), k, frozenset([i]))
                        for i, (e, k) in enumerate(exp.rows)]
    n0 = len(rows)
    elim = set()
    for i, (name, nonneg) in enumerate(exp.multipliers):
        if nonneg:
            rows.append(({name: Fraction(1)}, ">=0", frozenset([n0 + i])))
        elim.add(name)
    rows = _prune(rows)
    n_elim = 0
    while elim:
        # prefer substitution targets, then the cheapest FM variable
        var = None
        for e, k, _ in rows:
            if k == "==0":
                cands = sorted(v for v in e if v != 1 and v in elim)
                if cands:
                    var = cands[0]
                    break
        if var is None:
            cnt = {v: [0, 0] for v in elim}
            for e, k, _ in rows:
                for v in elim:
                    c = e.get(v, 0)
                    if c > 0:
                        cnt[v][0] += 1
                    elif c < 0:
                        cnt[v][1] += 1
            var = min(sorted(elim), key=lambda v: cnt[v][0] * cnt[v][1])
        rows = _eliminate(rows, var, n_elim)
        elim.discard(var)
        n_elim += 1
    return [(e, k) for e, k, _ in rows]


# process-wide memo: projections are pure values, shared across
# scheduler instances, pipeline modes and benchmark repetitions
_PROJ_MEMO: Dict[tuple, List[Constraint]] = {}


def _memo_key(poly, coef_of_z, const_term) -> tuple:
    def aff(e):
        return tuple(sorted((str(k), v) for k, v in e.items() if v))
    return (
        tuple((aff(e), k) for e, k in poly),
        tuple(sorted((str(z), aff(e)) for z, e in coef_of_z.items())),
        aff(const_term),
    )


def project_farkas(
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
) -> List[Constraint]:
    """Constraint rows over the ILP variables alone enforcing
    f(z) ≥ 0 over ``poly`` — the Farkas expansion with every multiplier
    exactly eliminated.  Memoized process-wide."""
    fault_point("farkas.project")   # before the memo: armed faults must
    key = _memo_key(poly, coef_of_z, const_term)   # fire on warm hits too
    hit = _PROJ_MEMO.get(key)
    if hit is None:
        hit = _PROJ_MEMO[key] = _project(
            farkas_expansion(poly, coef_of_z, const_term, "λ"))
    return hit


def add_farkas_nonneg(
    prob: ILPProblem,
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
    tag: str = "",
) -> None:
    """Add the projected Farkas rows for f(z) ≥ 0 over ``poly`` to
    ``prob`` (no multiplier variables are created)."""
    for expr, kind in project_farkas(poly, coef_of_z, const_term):
        prob.add(dict(expr), kind)
