"""Farkas-lemma linearization (paper §II-B2).

Given a polyhedron P = {z | A z + b ≥ 0 (+ equalities)} and an affine
form f(z) whose coefficients are themselves affine expressions over ILP
variables (schedule coefficients T, bounding coefficients u/w, ...), the
affine form of Farkas' lemma states:

    f(z) ≥ 0  ∀ z ∈ P   ⟺   f ≡ λ₀ + Σᵢ λᵢ (Aᵢ z + bᵢ),  λ₀, λᵢ ≥ 0

(multipliers of equality rows are sign-free). Equating coefficients of
each z variable and the constant yields *equality* constraints linking
the fresh multipliers λ to the ILP variables — exactly what
:class:`repro.core.ilp.ILPProblem` consumes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Sequence, Tuple

from .affine import Affine
from .ilp import ILPProblem
from .polyhedron import Constraint

_counter = itertools.count()


@dataclass
class FarkasExpansion:
    """The multiplier variables and equality rows produced by one Farkas
    linearization — a pure, problem-independent value.

    The scheduler re-adds the *same* expansion for every dependence at
    every scheduling dimension (the schedule-coefficient variable names
    do not mention the dimension), so expansions are computed once per
    (dependence, form) and replayed into each fresh per-dimension ILP
    via :func:`replay_farkas` (see ``PolyTOPSScheduler._farkas_spec``).
    """
    multipliers: List[Tuple[str, bool]]       # (name, nonneg?)
    rows: List[Tuple[Affine, str]]            # all '==0'


def farkas_expansion(
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
    prefix: str,
) -> FarkasExpansion:
    """Compute constraints enforcing  f(z) = Σ_z coef_of_z[z]·z + const ≥ 0
    over ``poly``. coef_of_z / const_term are affine over ILP vars.
    Multiplier names are ``{prefix}_0 .. {prefix}_n`` — the caller picks a
    prefix unique within any problem the expansion is replayed into.
    """
    lam0 = f"{prefix}_0"
    multipliers: List[Tuple[str, bool]] = [(lam0, True)]
    lams: List[Tuple[str, Constraint]] = []
    for i, (expr, kind) in enumerate(poly):
        name = f"{prefix}_{i + 1}"
        multipliers.append((name, kind == ">=0"))
        lams.append((name, (expr, kind)))

    zvars = set()
    for expr, _ in poly:
        zvars.update(k for k in expr if k != 1)
    zvars.update(coef_of_z)

    rows: List[Tuple[Affine, str]] = []
    # coefficient of each z variable: coef_of_z[z] − Σ λᵢ Aᵢ[z] == 0
    for z in sorted(zvars):
        eq: Affine = dict(coef_of_z.get(z, {}))
        for name, (expr, _) in lams:
            c = expr.get(z, Fraction(0))
            if c:
                eq[name] = eq.get(name, Fraction(0)) - c
        if eq:
            rows.append((eq, "==0"))
    # constant: const_term − λ₀ − Σ λᵢ bᵢ == 0
    eq = dict(const_term)
    eq[lam0] = eq.get(lam0, Fraction(0)) - 1
    for name, (expr, _) in lams:
        c = expr.get(1, Fraction(0))
        if c:
            eq[name] = eq.get(name, Fraction(0)) - c
    rows.append((eq, "==0"))
    return FarkasExpansion(multipliers, rows)


def replay_farkas(prob: ILPProblem, exp: FarkasExpansion) -> None:
    """Add a (possibly memoized) expansion's multipliers and rows to a
    problem. Row dicts are copied so the cached expansion stays pristine."""
    for name, nonneg in exp.multipliers:
        prob.var(name, lb=0 if nonneg else None, integer=False)
    for expr, kind in exp.rows:
        prob.add(expr, kind)


def add_farkas_nonneg(
    prob: ILPProblem,
    poly: Sequence[Constraint],
    coef_of_z: Dict[str, Affine],
    const_term: Affine,
    tag: str = "",
) -> None:
    """One-shot convenience: expand with a globally-unique prefix and add
    to ``prob`` immediately (the seed interface, still used by callers
    that don't memoize)."""
    uid = next(_counter)
    replay_farkas(
        prob, farkas_expansion(poly, coef_of_z, const_term, f"l{uid}{tag}")
    )
