"""(I)LP solving for the scheduler.

Two engines:

* ``HiGHSEngine`` — scipy.optimize.linprog(method='highs') with the
  ``integrality`` vector: a real branch-and-cut MILP solver. Primary.
* ``ExactEngine`` — two-phase exact-rational simplex (Bland's rule) +
  branch & bound on integer variables. Dependency-free, exact; used as
  fallback and as a cross-check oracle in tests.

Both are wrapped by :class:`ILPProblem`, which exposes the lexicographic
multi-objective minimization the paper relies on (Section III-A1: cost
functions are "minimized in lexicographic order").

All problem data is rational; solutions are returned as Fractions with
integer variables snapped exactly.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Union

from .affine import Affine

INF = float("inf")


@dataclass
class _Var:
    name: str
    lb: Optional[Fraction]
    ub: Optional[Fraction]
    integer: bool


class ILPProblem:
    """An ILP over named variables with affine constraints.

    Constraints are Affine dicts ({var: coeff, 1: const}) with kind
    '>=0' or '==0'.
    """

    def __init__(self, engine: str = "highs"):
        self.vars: Dict[str, _Var] = {}
        self.cons: List[tuple[Affine, str]] = []
        self.engine = engine

    # -- model building ---------------------------------------------------
    def var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name in self.vars:
            raise ValueError(f"duplicate var {name}")
        self.vars[name] = _Var(
            name,
            None if lb is None else Fraction(lb),
            None if ub is None else Fraction(ub),
            integer,
        )
        return name

    def ensure_var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name not in self.vars:
            self.var(name, lb, ub, integer)
        return name

    def add(self, expr: Affine, kind: str = ">=0") -> None:
        assert kind in (">=0", "==0"), kind
        for k in expr:
            if k != 1 and k not in self.vars:
                raise KeyError(f"unknown var {k!r} in constraint")
        self.cons.append((dict(expr), kind))

    def clone(self) -> "ILPProblem":
        p = ILPProblem(self.engine)
        p.vars = {k: _Var(v.name, v.lb, v.ub, v.integer) for k, v in self.vars.items()}
        p.cons = [(dict(e), k) for e, k in self.cons]
        return p

    # -- solving -----------------------------------------------------------
    def _order(self) -> List[str]:
        return list(self.vars)

    def solve_min(self, objective: Affine) -> Optional[tuple[Fraction, Dict[str, Fraction]]]:
        """Minimize one objective. Returns (value, solution) or None if
        infeasible. Raises Unbounded if unbounded."""
        if self.engine == "exact":
            return _exact_solve(self, objective)
        return _highs_solve(self, objective)

    def lexmin(self, objectives: Sequence[Affine]) -> Optional[Dict[str, Fraction]]:
        """Lexicographic minimization: minimize objectives[0], fix its
        value, then objectives[1], ... Returns the final solution."""
        prob = self.clone()
        sol: Optional[Dict[str, Fraction]] = None
        if not objectives:
            objectives = [{}]
        for i, obj in enumerate(objectives):
            res = prob.solve_min(obj)
            if res is None:
                return None
            val, sol = res
            # fix this objective at its optimum before the next stage
            fixed = dict(obj)
            fixed[1] = fixed.get(1, Fraction(0)) - val
            prob.add(fixed, "==0")
        return sol

    def feasible(self) -> bool:
        return self.solve_min({}) is not None


class Unbounded(Exception):
    pass


# ---------------------------------------------------------------------------
# HiGHS engine (scipy)
# ---------------------------------------------------------------------------

def _highs_solve(prob: ILPProblem, objective: Affine):
    import numpy as np
    from scipy.optimize import linprog

    names = prob._order()
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)
    c = np.zeros(n)
    for k, v in objective.items():
        if k != 1:
            c[idx[k]] = float(v)
    a_ub, b_ub, a_eq, b_eq = [], [], [], []
    for expr, kind in prob.cons:
        row = np.zeros(n)
        for k, v in expr.items():
            if k != 1:
                row[idx[k]] = float(v)
        const = float(expr.get(1, 0))
        if kind == ">=0":  # row·x + const >= 0  →  -row·x <= const
            a_ub.append(-row)
            b_ub.append(const)
        else:
            a_eq.append(row)
            b_eq.append(-const)
    bounds = []
    integrality = np.zeros(n)
    for i, name in enumerate(names):
        v = prob.vars[name]
        bounds.append(
            (None if v.lb is None else float(v.lb), None if v.ub is None else float(v.ub))
        )
        integrality[i] = 1 if v.integer else 0
    res = linprog(
        c,
        A_ub=np.array(a_ub) if a_ub else None,
        b_ub=np.array(b_ub) if b_ub else None,
        A_eq=np.array(a_eq) if a_eq else None,
        b_eq=np.array(b_eq) if b_eq else None,
        bounds=bounds,
        integrality=integrality if integrality.any() else None,
        method="highs",
    )
    if res.status == 2:  # infeasible
        return None
    if res.status == 3:
        raise Unbounded(str(objective))
    if not res.success:
        # numerical trouble: retry with exact engine
        return _exact_solve(prob, objective)
    sol: Dict[str, Fraction] = {}
    for i, name in enumerate(names):
        x = res.x[i]
        if prob.vars[name].integer:
            sol[name] = Fraction(round(x))
        else:
            sol[name] = Fraction(x).limit_denominator(10**9)
    val = Fraction(0)
    for k, v in objective.items():
        val += v if k == 1 else v * sol[k]
    return val, sol


# ---------------------------------------------------------------------------
# Exact engine: two-phase rational simplex + branch & bound
# ---------------------------------------------------------------------------

def _exact_solve(prob: ILPProblem, objective: Affine):
    names = prob._order()
    return _branch_and_bound(prob, names, objective, [])


def _branch_and_bound(prob, names, objective, extra):
    lp = _ExactLP.from_problem(prob, names, objective, extra)
    r = lp.solve()
    if r is None:
        return None
    val, sol = r
    # find fractional integer var
    frac_var = None
    for name in names:
        if prob.vars[name].integer and sol[name].denominator != 1:
            frac_var = name
            break
    if frac_var is None:
        return val, sol
    x = sol[frac_var]
    floor_v = x.numerator // x.denominator
    best = None
    for lo_hi in ("le", "ge"):
        if lo_hi == "le":
            con = ({frac_var: Fraction(-1), 1: Fraction(floor_v)}, ">=0")
        else:
            con = ({frac_var: Fraction(1), 1: Fraction(-(floor_v + 1))}, ">=0")
        sub = _branch_and_bound(prob, names, objective, extra + [con])
        if sub is not None and (best is None or sub[0] < best[0]):
            best = sub
    return best


class _ExactLP:
    """min c·x s.t. Ax = b, x >= 0 — two-phase simplex, Bland's rule.

    General bounds/frees are handled by shifting and splitting at
    construction time.
    """

    def __init__(self, a: List[List[Fraction]], b: List[Fraction], c: List[Fraction]):
        self.a, self.b, self.c = a, b, c

    @classmethod
    def from_problem(cls, prob: ILPProblem, names, objective, extra=()):  # noqa: C901
        # variable mapping: each model var -> expression over nonneg simplex vars
        cols: List[str] = []          # simplex column names
        expr_of: Dict[str, Dict[str, Fraction]] = {}  # model var -> {col: coeff} + const
        const_of: Dict[str, Fraction] = {}
        for name in names:
            v = prob.vars[name]
            if v.lb is not None:
                col = f"x:{name}"
                cols.append(col)
                expr_of[name] = {col: Fraction(1)}
                const_of[name] = v.lb
            else:
                cp, cn = f"xp:{name}", f"xn:{name}"
                cols.extend([cp, cn])
                expr_of[name] = {cp: Fraction(1), cn: Fraction(-1)}
                const_of[name] = Fraction(0)
        rows: List[tuple[Dict[str, Fraction], str, Fraction]] = []

        def add_row(expr: Affine, kind: str):
            row: Dict[str, Fraction] = {}
            const = expr.get(1, Fraction(0))
            for k, coef in expr.items():
                if k == 1:
                    continue
                const += coef * const_of[k]
                for col, cc in expr_of[k].items():
                    row[col] = row.get(col, Fraction(0)) + coef * cc
            rows.append((row, kind, const))

        for expr, kind in list(prob.cons) + list(extra):
            add_row(expr, kind)
        for name in names:
            v = prob.vars[name]
            if v.ub is not None:
                add_row({name: Fraction(-1), 1: v.ub}, ">=0")

        # to standard form Ax = b, x >= 0 with slacks
        ncols = {c: i for i, c in enumerate(cols)}
        nslack = sum(1 for _, kind, _ in rows if kind == ">=0")
        width = len(cols) + nslack
        a: List[List[Fraction]] = []
        b: List[Fraction] = []
        slack_i = 0
        for row, kind, const in rows:
            r = [Fraction(0)] * width
            for col, cc in row.items():
                r[ncols[col]] = cc
            if kind == ">=0":  # r·x + const >= 0 → r·x - s = -const
                r[len(cols) + slack_i] = Fraction(-1)
                slack_i += 1
            a.append(r)
            b.append(-const)
        # objective over simplex columns
        c_vec = [Fraction(0)] * width
        obj_const = objective.get(1, Fraction(0))
        for k, coef in objective.items():
            if k == 1:
                continue
            obj_const += coef * const_of[k]
            for col, cc in expr_of[k].items():
                c_vec[ncols[col]] += coef * cc
        lp = cls(a, b, c_vec)
        lp._cols = cols
        lp._width = width
        lp._expr_of = expr_of
        lp._const_of = const_of
        lp._names = names
        lp._obj_const = obj_const
        lp._prob = prob
        return lp

    def solve(self):
        a = [row[:] for row in self.a]
        b = self.b[:]
        m = len(a)
        if m == 0:
            names = self._names
            sol = {n: self._const_of[n] for n in names}
            return self._obj_const, sol
        width = len(a[0])
        # make b >= 0
        for i in range(m):
            if b[i] < 0:
                a[i] = [-x for x in a[i]]
                b[i] = -b[i]
        # phase 1: artificials
        for i in range(m):
            for j in range(m):
                a[i].append(Fraction(1) if i == j else Fraction(0))
        basis = list(range(width, width + m))
        cost1 = [Fraction(0)] * width + [Fraction(1)] * m
        val = self._simplex(a, b, cost1, basis)
        if val is None or val > 0:
            return None
        # drive artificials out of basis if possible
        for i in range(m):
            if basis[i] >= width:
                piv = None
                for j in range(width):
                    if a[i][j] != 0:
                        piv = j
                        break
                if piv is not None:
                    self._pivot(a, b, basis, i, piv)
        # drop artificial columns & redundant rows
        keep = [i for i in range(m) if basis[i] < width]
        a = [a[i][:width] for i in keep]
        b = [b[i] for i in keep]
        basis = [basis[i] for i in keep]
        cost2 = self.c[:width]
        val = self._simplex(a, b, cost2, basis)
        if val is None:
            raise Unbounded("exact LP unbounded")
        x = [Fraction(0)] * width
        for i, bi in enumerate(basis):
            x[bi] = b[i]
        sol: Dict[str, Fraction] = {}
        ncols = {c: i for i, c in enumerate(self._cols)}
        for name in self._names:
            v = self._const_of[name]
            for col, cc in self._expr_of[name].items():
                v += cc * x[ncols[col]]
            sol[name] = v
        obj = Fraction(0)
        for i in range(min(width, len(self.c))):
            obj += self.c[i] * x[i]
        return obj + self._obj_const, sol

    @staticmethod
    def _pivot(a, b, basis, r, c):
        m, n = len(a), len(a[0])
        pv = a[r][c]
        a[r] = [x / pv for x in a[r]]
        b[r] = b[r] / pv
        for i in range(m):
            if i != r and a[i][c] != 0:
                f = a[i][c]
                a[i] = [x - f * y for x, y in zip(a[i], a[r])]
                b[i] = b[i] - f * b[r]
        basis[r] = c

    @classmethod
    def _simplex(cls, a, b, cost, basis):
        """Min cost·x. Returns objective value, or None if unbounded is
        signalled via exception by caller convention (phase2)."""
        m = len(a)
        n = len(a[0]) if m else 0
        while True:
            # reduced costs: z_j - c_j
            y = {}
            red = [Fraction(0)] * n
            cb = [cost[basis[i]] if basis[i] < len(cost) else Fraction(0) for i in range(m)]
            for j in range(n):
                zj = Fraction(0)
                for i in range(m):
                    if a[i][j] != 0 and cb[i] != 0:
                        zj += cb[i] * a[i][j]
                red[j] = (cost[j] if j < len(cost) else Fraction(0)) - zj
            enter = None
            for j in range(n):  # Bland: first negative reduced cost
                if red[j] < 0 and j not in basis:
                    enter = j
                    break
            if enter is None:
                val = Fraction(0)
                for i in range(m):
                    val += cb[i] * b[i]
                return val
            # ratio test (Bland: smallest index on ties)
            leave = None
            best = None
            for i in range(m):
                if a[i][enter] > 0:
                    ratio = b[i] / a[i][enter]
                    if best is None or ratio < best or (ratio == best and basis[i] < basis[leave]):
                        best = ratio
                        leave = i
            if leave is None:
                return None  # unbounded
            cls._pivot(a, b, basis, leave, enter)
