"""(I)LP solving for the scheduler.

Two engines:

* ``lex`` (default; ``exact`` is an alias) — the exact rational
  lexicographic simplex in :mod:`repro.core.lexsimplex`: fraction-free
  integer tableau, branch & bound on the integer variables, and a
  canonicalizing lexmin whose optimum is *mathematically unique* on the
  schedule coefficients.  Every schedule is bit-reproducible: the seed
  pipeline, the incremental pipeline and repeat runs return identical
  coefficients, which is what the golden-schedule CI gate asserts.
* ``highs`` — scipy.optimize.linprog(method='highs'), a floating-point
  branch-and-cut MILP.  Kept as an opt-in cross-check oracle (the
  hypothesis tests solve random ILPs with both engines) and as the
  pruning/query backend for :mod:`repro.core.polyhedron`, where rational
  relaxations are cheap and a wrong vertex cannot change a schedule.

Both are wrapped by :class:`ILPProblem`, which exposes the lexicographic
multi-objective minimization the paper relies on (Section III-A1: cost
functions are "minimized in lexicographic order").

All problem data is rational; solutions are returned as Fractions with
integer variables snapped exactly.

Incremental core (the compile-time hot path)
--------------------------------------------

The scheduler solves *one* constraint system under many objectives:
each lexicographic stage only appends a single objective-fixing row.

* :class:`CompiledProblem` keeps the constraint system as growing
  CSR-style ``(indptr, indices, data)`` triplets with a stable variable
  index; Fraction→float conversion happens exactly once per row (highs
  engine).  :class:`repro.core.lexsimplex.LexCompiled` is its exact
  twin: integer-scaled rows reused across lexmins (lex engine).
* ``lexmin`` runs append-only: fixing rows are appended per stage on
  one live model/tableau; ``push()``/``pop()`` rewind both the exact
  constraint list and the compiled images.
* Warm-start stage skipping: when the previous stage's solution already
  attains the objective's lower bound implied by variable bounds, the
  stage is provably optimal there and the solve is skipped.

``ILPProblem(..., incremental=False)`` preserves the seed clone+dense
pipeline for benchmarking and differential tests; under the ``lex``
engine both modes share the per-lexmin tableau (the incremental flag
then only controls the *scheduler-level* reuse: Farkas memoization,
per-band base problems, compiled dependence polyhedra).
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from .affine import Affine
from . import lexsimplex
from .lexsimplex import SOLVER_TAG, Unbounded  # re-exported  # noqa: F401

INF = float("inf")


@dataclass
class _Var:
    name: str
    lb: Optional[Fraction]
    ub: Optional[Fraction]
    integer: bool


class CompiledProblem:
    """Append-only numeric (float/CSR) image of an :class:`ILPProblem`
    for the highs engine.

    ``>=0`` rows are stored negated as ``A_ub · x <= b_ub`` and ``==0``
    rows as ``A_eq · x = b_eq`` — exactly the layout scipy's linprog
    consumes, so a solve is triplet→csr_matrix + one HiGHS call with no
    per-row Python work.  ``truncate`` rewinds to an earlier row/var
    count (lexmin fixing rows, temporary feasibility probes).
    """

    def __init__(self):
        self.names: List[str] = []
        self.idx: Dict[str, int] = {}
        self.lb: List[float] = []
        self.ub: List[float] = []
        self.integrality: List[int] = []
        self.kinds: List[str] = []          # source-row kinds, append order
        self.ub_indptr: List[int] = [0]
        self.ub_indices: List[int] = []
        self.ub_data: List[float] = []
        self.ub_rhs: List[float] = []
        self.eq_indptr: List[int] = [0]
        self.eq_indices: List[int] = []
        self.eq_data: List[float] = []
        self.eq_rhs: List[float] = []

    @property
    def n_vars(self) -> int:
        return len(self.names)

    @property
    def n_rows(self) -> int:
        return len(self.kinds)

    def add_var(self, name: str, lb, ub, integer: bool) -> None:
        self.idx[name] = len(self.names)
        self.names.append(name)
        self.lb.append(-INF if lb is None else float(lb))
        self.ub.append(INF if ub is None else float(ub))
        self.integrality.append(1 if integer else 0)

    def add_cons_batch(self, rows) -> None:
        """Append many constraint rows with one batched Fraction→float
        conversion (see ``linalg_q.fractions_to_float_array``)."""
        from .linalg_q import fractions_to_float_array

        flat = []
        meta = []
        for expr, kind in rows:
            cols = []
            for k, v in expr.items():
                if k != 1 and v:
                    cols.append(self.idx[k])
                    flat.append(v)
            flat.append(expr.get(1, 0))
            meta.append((kind, cols))
        arr = fractions_to_float_array(flat)
        pos = 0
        for kind, cols in meta:
            n = len(cols)
            coefs = arr[pos:pos + n]
            const = float(arr[pos + n])
            pos += n + 1
            if kind == ">=0":   # row·x + const >= 0  →  -row·x <= const
                self.ub_indices.extend(cols)
                self.ub_data.extend((-coefs).tolist())
                self.ub_indptr.append(len(self.ub_indices))
                self.ub_rhs.append(const)
            else:
                self.eq_indices.extend(cols)
                self.eq_data.extend(coefs.tolist())
                self.eq_indptr.append(len(self.eq_indices))
                self.eq_rhs.append(-const)
            self.kinds.append(kind)

    def truncate(self, n_vars: int, n_rows: int) -> None:
        while len(self.kinds) > n_rows:
            kind = self.kinds.pop()
            if kind == ">=0":
                self.ub_indptr.pop()
                nz = self.ub_indptr[-1]
                del self.ub_indices[nz:]
                del self.ub_data[nz:]
                self.ub_rhs.pop()
            else:
                self.eq_indptr.pop()
                nz = self.eq_indptr[-1]
                del self.eq_indices[nz:]
                del self.eq_data[nz:]
                self.eq_rhs.pop()
        while len(self.names) > n_vars:
            del self.idx[self.names.pop()]
            self.lb.pop()
            self.ub.pop()
            self.integrality.pop()

    def linprog(self, objective: Affine):
        """One scipy HiGHS call over the compiled arrays. Returns the raw
        scipy result (caller interprets status / converts to exact).

        Goes straight to ``_linprog_highs`` (the exact backend that
        ``linprog(method='highs')`` dispatches to, with the same solver
        and status mapping) — the public wrapper re-validates and
        re-canonicalizes every input on every call, which dominates solve
        time for the scheduler's many small problems.  Falls back to the
        public API if the private one ever changes shape."""
        import numpy as np
        from scipy.optimize import OptimizeResult
        from scipy.sparse import csr_matrix

        n = len(self.names)
        c = np.zeros(n)
        for k, v in objective.items():
            if k != 1:
                c[self.idx[k]] = float(v)
        a_ub = csr_matrix(
            (self.ub_data, self.ub_indices, self.ub_indptr),
            shape=(len(self.ub_rhs), n),
        )
        b_ub = np.asarray(self.ub_rhs, dtype=float)
        a_eq = csr_matrix(
            (self.eq_data, self.eq_indices, self.eq_indptr),
            shape=(len(self.eq_rhs), n),
        )
        b_eq = np.asarray(self.eq_rhs, dtype=float)
        bounds = np.column_stack([self.lb, self.ub])
        integrality = np.asarray(self.integrality)
        if not integrality.any():
            integrality = None
        try:
            from scipy.optimize._linprog_highs import _linprog_highs
            from scipy.optimize._linprog_util import _LPProblem

            lp = _LPProblem(c, a_ub, b_ub, a_eq, b_eq, bounds, None,
                            integrality)
            return OptimizeResult(_linprog_highs(lp, solver=None))
        except (ImportError, TypeError):  # private API moved: public path
            from scipy.optimize import linprog

            return linprog(
                c,
                A_ub=a_ub if len(b_ub) else None,
                b_ub=b_ub if len(b_ub) else None,
                A_eq=a_eq if len(b_eq) else None,
                b_eq=b_eq if len(b_eq) else None,
                bounds=bounds if n else None,
                integrality=integrality,
                method="highs",
            )


class ILPProblem:
    """An ILP over named variables with affine constraints.

    Constraints are Affine dicts ({var: coeff, 1: const}) with kind
    '>=0' or '==0'.
    """

    def __init__(self, engine: str = "lex", incremental: bool = True):
        if engine == "exact":
            engine = "lex"
        self.vars: Dict[str, _Var] = {}
        self.cons: List[tuple[Affine, str]] = []
        self.engine = engine
        self.incremental = incremental
        self.stages_skipped = 0     # warm-skipped stages of the last lexmin
        self.last_pivots = 0        # exact-simplex pivots accumulated
        self._compiled: Optional[CompiledProblem] = None
        self._lex: Optional[lexsimplex.LexCompiled] = None

    # -- model building ---------------------------------------------------
    def var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name in self.vars:
            raise ValueError(f"duplicate var {name}")
        self.vars[name] = _Var(
            name,
            None if lb is None else Fraction(lb),
            None if ub is None else Fraction(ub),
            integer,
        )
        return name

    def ensure_var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name not in self.vars:
            self.var(name, lb, ub, integer)
        return name

    def add(self, expr: Affine, kind: str = ">=0") -> None:
        assert kind in (">=0", "==0"), kind
        for k in expr:
            if k != 1 and k not in self.vars:
                raise KeyError(f"unknown var {k!r} in constraint")
        self.cons.append((dict(expr), kind))

    def clone(self) -> "ILPProblem":
        p = ILPProblem(self.engine, self.incremental)
        p.vars = {k: _Var(v.name, v.lb, v.ub, v.integer) for k, v in self.vars.items()}
        p.cons = [(dict(e), k) for e, k in self.cons]
        return p

    # -- incremental state -------------------------------------------------
    def _compile(self) -> CompiledProblem:
        """Sync the compiled float image with vars/cons added since the
        last call (highs engine)."""
        c = self._compiled
        if c is None:
            c = self._compiled = CompiledProblem()
        if c.n_vars < len(self.vars):
            names = list(self.vars)
            for name in names[c.n_vars:]:
                v = self.vars[name]
                c.add_var(name, v.lb, v.ub, v.integer)
        pending = self.cons[c.n_rows:]
        if pending:
            c.add_cons_batch(pending)
        return c

    def push(self) -> Tuple[int, int]:
        """Mark the model; :meth:`pop` rewinds vars/cons added after."""
        return (len(self.vars), len(self.cons))

    def pop(self, mark: Tuple[int, int]) -> None:
        n_vars, n_cons = mark
        del self.cons[n_cons:]
        if len(self.vars) > n_vars:
            for name in list(self.vars)[n_vars:]:
                del self.vars[name]
        if self._compiled is not None:
            self._compiled.truncate(n_vars, n_cons)
        if self._lex is not None:
            self._lex.truncate(n_vars, n_cons)

    # -- solving -----------------------------------------------------------
    def _order(self) -> List[str]:
        return list(self.vars)

    def solve_min(self, objective: Affine, want=None) -> Optional[tuple[Fraction, Dict[str, Fraction]]]:
        """Minimize one objective. Returns (value, solution) or None if
        infeasible. Raises Unbounded if unbounded.

        ``want``: iterable of variable names to materialize in the
        returned solution, in addition to the objective's own variables.
        ``None`` converts everything."""
        if self.engine == "lex":
            return lexsimplex.solve_min(self, objective, want)
        if self.incremental:
            return _highs_solve_compiled(self, objective, want)
        return _highs_solve(self, objective)

    def _objective_lower_bound(self, objective: Affine) -> Optional[Fraction]:
        """Lower bound of the objective implied by variable bounds alone,
        or None when some needed bound is missing (unbounded side)."""
        lb = objective.get(1, Fraction(0))
        for k, c in objective.items():
            if k == 1 or c == 0:
                continue
            v = self.vars[k]
            b = v.lb if c > 0 else v.ub
            if b is None:
                return None
            lb += c * b
        return lb

    def lexmin(self, objectives: Sequence[Affine], want=None,
               canon=None) -> Optional[Dict[str, Fraction]]:
        """Lexicographic minimization: minimize objectives[0], fix its
        value, then objectives[1], ... Returns the final solution.

        Under the ``lex`` engine this is exact and *canonical*: after
        the given objectives, the ``canon`` variables (default: every
        box-bounded integer variable, in declaration order) are
        minimized lexicographically, so the returned values of those
        variables are a pure function of the mathematical problem —
        identical across the seed path, the incremental path and repeat
        runs.  ``want`` limits solution materialization as in
        :meth:`solve_min`."""
        if self.engine == "lex":
            return lexsimplex.lexmin(self, objectives, want=want, canon=canon)
        if not self.incremental:
            return self._lexmin_cloned(objectives)
        if not objectives:
            objectives = [{}]
        if want is not None:
            want = set(want)
            for obj in objectives:
                want.update(k for k in obj if k != 1)
        mark = self.push()
        try:
            self.stages_skipped = 0
            sol: Optional[Dict[str, Fraction]] = None
            for obj in objectives:
                val: Optional[Fraction] = None
                if sol is not None:
                    bound = self._objective_lower_bound(obj)
                    if bound is not None:
                        cur = obj.get(1, Fraction(0))
                        for k, c in obj.items():
                            if k != 1:
                                cur += c * sol[k]
                        if cur == bound:
                            val = cur   # provably optimal: skip the solve
                            self.stages_skipped += 1
                if val is None:
                    res = self.solve_min(obj, want)
                    if res is None:
                        return None
                    val, sol = res
                # fix this objective at its optimum before the next stage
                fixed = {k: -c for k, c in obj.items()}
                fixed[1] = fixed.get(1, Fraction(0)) + val
                self.add(fixed, ">=0")
            return sol
        finally:
            self.pop(mark)

    def _lexmin_cloned(self, objectives: Sequence[Affine]) -> Optional[Dict[str, Fraction]]:
        """The seed clone-per-lexmin path (kept for benchmarking the
        highs engine; the lex engine handles both modes above)."""
        prob = self.clone()
        sol: Optional[Dict[str, Fraction]] = None
        if not objectives:
            objectives = [{}]
        for obj in objectives:
            res = prob.solve_min(obj)
            if res is None:
                return None
            val, sol = res
            fixed = {k: -c for k, c in obj.items()}
            fixed[1] = fixed.get(1, Fraction(0)) + val
            prob.add(fixed, ">=0")
        return sol

    def feasible(self) -> bool:
        return self.solve_min({}, want=()) is not None


def stage_values(stages: Sequence[Affine], sol: Dict[str, Fraction]
                 ) -> List[Fraction]:
    """Exact value of each lexicographic objective stage at ``sol``.

    ``lexmin(want=...)`` always materializes the objectives' own
    variables, so the returned solution is sufficient to evaluate every
    stage — this is the engine-agnostic ground truth the differential
    tests compare between the exact core and the HiGHS oracle (two
    engines may pick different alternate optima, but the stage values of
    a lexicographic optimum are unique)."""
    out: List[Fraction] = []
    for obj in stages:
        v = Fraction(obj.get(1, 0))
        for k, c in obj.items():
            if k != 1:
                v += Fraction(c) * sol[k]
        out.append(v)
    return out


# ---------------------------------------------------------------------------
# HiGHS engine (scipy) — opt-in cross-check / polyhedron-query backend
# ---------------------------------------------------------------------------

def _highs_solve(prob: ILPProblem, objective: Affine):
    import numpy as np
    from scipy.optimize import linprog

    names = prob._order()
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)
    c = np.zeros(n)
    for k, v in objective.items():
        if k != 1:
            c[idx[k]] = float(v)
    a_ub, b_ub, a_eq, b_eq = [], [], [], []
    for expr, kind in prob.cons:
        row = np.zeros(n)
        for k, v in expr.items():
            if k != 1:
                row[idx[k]] = float(v)
        const = float(expr.get(1, 0))
        if kind == ">=0":  # row·x + const >= 0  →  -row·x <= const
            a_ub.append(-row)
            b_ub.append(const)
        else:
            a_eq.append(row)
            b_eq.append(-const)
    bounds = []
    integrality = np.zeros(n)
    for i, name in enumerate(names):
        v = prob.vars[name]
        bounds.append(
            (None if v.lb is None else float(v.lb), None if v.ub is None else float(v.ub))
        )
        integrality[i] = 1 if v.integer else 0
    res = linprog(
        c,
        A_ub=np.array(a_ub) if a_ub else None,
        b_ub=np.array(b_ub) if b_ub else None,
        A_eq=np.array(a_eq) if a_eq else None,
        b_eq=np.array(b_eq) if b_eq else None,
        bounds=bounds,
        integrality=integrality if integrality.any() else None,
        method="highs",
    )
    return _interpret_highs(prob, res, objective, None, names, idx)


def _highs_solve_compiled(prob: ILPProblem, objective: Affine, want=None):
    """Incremental-path twin of :func:`_highs_solve`: the constraint
    matrices come from the cached :class:`CompiledProblem` arrays and
    only the requested variables (``want`` + objective vars; None = all)
    are converted to Fractions."""
    comp = prob._compile()
    res = comp.linprog(objective)
    return _interpret_highs(prob, res, objective, want, comp.names, comp.idx)


def _point_valid(prob, names, x, tol: float = 1e-6) -> bool:
    """Float-level residual/bounds/integrality check of a HiGHS point.
    HiGHS can report an invalid point as optimal (MIP fixing-row chains,
    ill-scaled rational relaxations); an invalid point is re-solved with
    the exact engine rather than silently accepted — the polyhedron
    query layer is pinned to ``highs`` and must never abort a
    compilation over a tolerance hiccup.  (The float-era *scheduling*
    recovery — incumbent pinning on mis-reported lexmin infeasibility —
    stays deleted: the schedule path defaults to the exact engine.)"""
    idx = {n: i for i, n in enumerate(names)}
    for expr, kind in prob.cons:
        v = float(expr.get(1, 0))
        scale = 1.0 + abs(v)
        for k, c in expr.items():
            if k != 1:
                v += float(c) * x[idx[k]]
        if kind == ">=0" and v < -tol * scale:
            return False
        if kind == "==0" and abs(v) > tol * scale:
            return False
    for i, name in enumerate(names):
        var = prob.vars[name]
        if var.lb is not None and x[i] < float(var.lb) - tol:
            return False
        if var.ub is not None and x[i] > float(var.ub) + tol:
            return False
        if var.integer and abs(x[i] - round(x[i])) > 1e-5:
            return False
    return True


def _interpret_highs(prob, res, objective, want, names, idx):
    if res.status == 2:  # infeasible
        return None
    if res.status == 3:
        raise Unbounded(str(objective))
    if not res.success or not _point_valid(prob, names, res.x):
        # numerical trouble: the exact engine answers instead
        return lexsimplex.solve_min(prob, objective, want)
    if want is None:
        sel = names
    else:
        sel = {k for k in objective if k != 1}
        sel.update(k for k in want if k in idx)
    sol: Dict[str, Fraction] = {}
    for name in sel:
        x = res.x[idx[name]]
        if prob.vars[name].integer:
            sol[name] = Fraction(round(x))
        else:
            sol[name] = Fraction(x).limit_denominator(10**9)
    val = Fraction(0)
    for k, v in objective.items():
        val += v if k == 1 else v * sol[k]
    return val, sol
