"""(I)LP solving for the scheduler.

Two engines:

* ``HiGHSEngine`` — scipy.optimize.linprog(method='highs') with the
  ``integrality`` vector: a real branch-and-cut MILP solver. Primary.
* ``ExactEngine`` — two-phase exact-rational simplex (Bland's rule) +
  branch & bound on integer variables. Dependency-free, exact; used as
  fallback and as a cross-check oracle in tests.

Both are wrapped by :class:`ILPProblem`, which exposes the lexicographic
multi-objective minimization the paper relies on (Section III-A1: cost
functions are "minimized in lexicographic order").

All problem data is rational; solutions are returned as Fractions with
integer variables snapped exactly.

Incremental core (the compile-time hot path)
--------------------------------------------

The scheduler solves *one* constraint system under many objectives:
each lexicographic stage only appends a single objective-fixing row.
The seed implementation cloned the whole model per ``lexmin`` and
re-materialized dense numpy matrices from Fraction dicts on every
``solve_min``.  Now:

* :class:`CompiledProblem` keeps the constraint system as growing
  CSR-style ``(indptr, indices, data)`` triplets with a stable variable
  index; Fraction→float conversion happens exactly once per row.
* ``lexmin`` runs append-only on the live problem — ``push()`` marks the
  model, fixing rows are appended per stage, ``pop()`` rewinds both the
  exact constraint list and the compiled arrays.  The exact-rational
  engine reads the same appended constraint list, so the cross-check
  oracle (highs vs exact) exercises the identical incremental path.
* Warm-start stage skipping: every objective the scheduler emits is
  over integer variables, so when the previous stage's solution already
  attains the objective's lower bound implied by variable bounds, the
  stage is provably optimal at that point and the LP call is skipped
  (only the fixing row is appended).

``ILPProblem(..., incremental=False)`` preserves the seed clone+dense
pipeline verbatim for benchmarking and differential tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .affine import Affine

INF = float("inf")


@dataclass
class _Var:
    name: str
    lb: Optional[Fraction]
    ub: Optional[Fraction]
    integer: bool


class CompiledProblem:
    """Append-only numeric (float/CSR) image of an :class:`ILPProblem`.

    ``>=0`` rows are stored negated as ``A_ub · x <= b_ub`` and ``==0``
    rows as ``A_eq · x = b_eq`` — exactly the layout scipy's linprog
    consumes, so a solve is triplet→csr_matrix + one HiGHS call with no
    per-row Python work.  ``truncate`` rewinds to an earlier row/var
    count (lexmin fixing rows, temporary feasibility probes).
    """

    def __init__(self):
        self.names: List[str] = []
        self.idx: Dict[str, int] = {}
        self.lb: List[float] = []
        self.ub: List[float] = []
        self.integrality: List[int] = []
        self.kinds: List[str] = []          # source-row kinds, append order
        self.ub_indptr: List[int] = [0]
        self.ub_indices: List[int] = []
        self.ub_data: List[float] = []
        self.ub_rhs: List[float] = []
        self.eq_indptr: List[int] = [0]
        self.eq_indices: List[int] = []
        self.eq_data: List[float] = []
        self.eq_rhs: List[float] = []
        self._mats = None   # matrices of the last linprog() call

    @property
    def n_vars(self) -> int:
        return len(self.names)

    @property
    def n_rows(self) -> int:
        return len(self.kinds)

    def add_var(self, name: str, lb, ub, integer: bool) -> None:
        self.idx[name] = len(self.names)
        self.names.append(name)
        self.lb.append(-INF if lb is None else float(lb))
        self.ub.append(INF if ub is None else float(ub))
        self.integrality.append(1 if integer else 0)

    def add_cons_batch(self, rows) -> None:
        """Append many constraint rows with one batched Fraction→float
        conversion (see ``linalg_q.fractions_to_float_array``) — the sync
        point where whole Farkas expansions cross into float-land."""
        from .linalg_q import fractions_to_float_array

        flat = []
        meta = []
        for expr, kind in rows:
            cols = []
            for k, v in expr.items():
                if k != 1 and v:
                    cols.append(self.idx[k])
                    flat.append(v)
            flat.append(expr.get(1, 0))
            meta.append((kind, cols))
        arr = fractions_to_float_array(flat)
        pos = 0
        for kind, cols in meta:
            n = len(cols)
            coefs = arr[pos:pos + n]
            const = float(arr[pos + n])
            pos += n + 1
            if kind == ">=0":   # row·x + const >= 0  →  -row·x <= const
                self.ub_indices.extend(cols)
                self.ub_data.extend((-coefs).tolist())
                self.ub_indptr.append(len(self.ub_indices))
                self.ub_rhs.append(const)
            else:
                self.eq_indices.extend(cols)
                self.eq_data.extend(coefs.tolist())
                self.eq_indptr.append(len(self.eq_indices))
                self.eq_rhs.append(-const)
            self.kinds.append(kind)

    def add_con(self, expr: Affine, kind: str) -> None:
        idx = self.idx
        const = float(expr.get(1, 0))
        if kind == ">=0":   # row·x + const >= 0  →  -row·x <= const
            for k, v in expr.items():
                if k != 1 and v:
                    self.ub_indices.append(idx[k])
                    self.ub_data.append(-float(v))
            self.ub_indptr.append(len(self.ub_indices))
            self.ub_rhs.append(const)
        else:
            for k, v in expr.items():
                if k != 1 and v:
                    self.eq_indices.append(idx[k])
                    self.eq_data.append(float(v))
            self.eq_indptr.append(len(self.eq_indices))
            self.eq_rhs.append(-const)
        self.kinds.append(kind)

    def truncate(self, n_vars: int, n_rows: int) -> None:
        while len(self.kinds) > n_rows:
            kind = self.kinds.pop()
            if kind == ">=0":
                self.ub_indptr.pop()
                nz = self.ub_indptr[-1]
                del self.ub_indices[nz:]
                del self.ub_data[nz:]
                self.ub_rhs.pop()
            else:
                self.eq_indptr.pop()
                nz = self.eq_indptr[-1]
                del self.eq_indices[nz:]
                del self.eq_data[nz:]
                self.eq_rhs.pop()
        while len(self.names) > n_vars:
            del self.idx[self.names.pop()]
            self.lb.pop()
            self.ub.pop()
            self.integrality.pop()

    def linprog(self, objective: Affine):
        """One scipy HiGHS call over the compiled arrays. Returns the raw
        scipy result (caller interprets status / converts to exact).

        Goes straight to ``_linprog_highs`` (the exact backend that
        ``linprog(method='highs')`` dispatches to, with the same solver
        and status mapping) — the public wrapper re-validates and
        re-canonicalizes every input on every call, which dominates solve
        time for the scheduler's many small problems.  Falls back to the
        public API if the private one ever changes shape."""
        import numpy as np
        from scipy.optimize import OptimizeResult
        from scipy.sparse import csr_matrix

        n = len(self.names)
        c = np.zeros(n)
        for k, v in objective.items():
            if k != 1:
                c[self.idx[k]] = float(v)
        a_ub = csr_matrix(
            (self.ub_data, self.ub_indices, self.ub_indptr),
            shape=(len(self.ub_rhs), n),
        )
        b_ub = np.asarray(self.ub_rhs, dtype=float)
        a_eq = csr_matrix(
            (self.eq_data, self.eq_indices, self.eq_indptr),
            shape=(len(self.eq_rhs), n),
        )
        b_eq = np.asarray(self.eq_rhs, dtype=float)
        bounds = np.column_stack([self.lb, self.ub])
        integrality = np.asarray(self.integrality)
        if not integrality.any():
            integrality = None
        self._mats = (a_ub, b_ub, a_eq, b_eq)
        try:
            from scipy.optimize._linprog_highs import _linprog_highs
            from scipy.optimize._linprog_util import _LPProblem

            lp = _LPProblem(c, a_ub, b_ub, a_eq, b_eq, bounds, None,
                            integrality)
            return OptimizeResult(_linprog_highs(lp, solver=None))
        except (ImportError, TypeError):  # private API moved: public path
            from scipy.optimize import linprog

            return linprog(
                c,
                A_ub=a_ub if len(b_ub) else None,
                b_ub=b_ub if len(b_ub) else None,
                A_eq=a_eq if len(b_eq) else None,
                b_eq=b_eq if len(b_eq) else None,
                bounds=bounds if n else None,
                integrality=integrality,
                method="highs",
            )

    def check_solution(self, x, tol: float = 1e-6) -> bool:
        """Float-level sanity check of a solver solution against the
        compiled system (the seed's public-``linprog`` path ran scipy's
        ``_check_result``; going straight to the backend skips it, and
        HiGHS MIP occasionally reports an infeasible point as optimal).
        """
        import numpy as np

        a_ub, b_ub, a_eq, b_eq = self._mats
        if len(b_ub) and np.max(a_ub @ x - b_ub, initial=0.0) > tol * (
                1.0 + float(np.max(np.abs(b_ub), initial=0.0))):
            return False
        if len(b_eq) and np.max(np.abs(a_eq @ x - b_eq), initial=0.0) > tol * (
                1.0 + float(np.max(np.abs(b_eq), initial=0.0))):
            return False
        lb = np.asarray(self.lb)
        ub = np.asarray(self.ub)
        if np.any(x < lb - tol) or np.any(x > ub + tol):
            return False
        integ = np.asarray(self.integrality, dtype=bool)
        if integ.any() and np.max(np.abs(x[integ] - np.round(x[integ])),
                                  initial=0.0) > 1e-5:
            return False
        return True


class ILPProblem:
    """An ILP over named variables with affine constraints.

    Constraints are Affine dicts ({var: coeff, 1: const}) with kind
    '>=0' or '==0'.
    """

    def __init__(self, engine: str = "highs", incremental: bool = True):
        self.vars: Dict[str, _Var] = {}
        self.cons: List[tuple[Affine, str]] = []
        self.engine = engine
        self.incremental = incremental
        self.stages_skipped = 0     # warm-skipped stages of the last lexmin
        self._compiled: Optional[CompiledProblem] = None

    # -- model building ---------------------------------------------------
    def var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name in self.vars:
            raise ValueError(f"duplicate var {name}")
        self.vars[name] = _Var(
            name,
            None if lb is None else Fraction(lb),
            None if ub is None else Fraction(ub),
            integer,
        )
        return name

    def ensure_var(self, name: str, lb=0, ub=None, integer: bool = True) -> str:
        if name not in self.vars:
            self.var(name, lb, ub, integer)
        return name

    def add(self, expr: Affine, kind: str = ">=0") -> None:
        assert kind in (">=0", "==0"), kind
        for k in expr:
            if k != 1 and k not in self.vars:
                raise KeyError(f"unknown var {k!r} in constraint")
        self.cons.append((dict(expr), kind))

    def clone(self) -> "ILPProblem":
        p = ILPProblem(self.engine, self.incremental)
        p.vars = {k: _Var(v.name, v.lb, v.ub, v.integer) for k, v in self.vars.items()}
        p.cons = [(dict(e), k) for e, k in self.cons]
        return p

    # -- incremental state -------------------------------------------------
    def _compile(self) -> CompiledProblem:
        """Sync the compiled image with vars/cons added since last call."""
        c = self._compiled
        if c is None:
            c = self._compiled = CompiledProblem()
        if c.n_vars < len(self.vars):
            names = list(self.vars)
            for name in names[c.n_vars:]:
                v = self.vars[name]
                c.add_var(name, v.lb, v.ub, v.integer)
        pending = self.cons[c.n_rows:]
        if pending:
            c.add_cons_batch(pending)
        return c

    def push(self) -> Tuple[int, int]:
        """Mark the model; :meth:`pop` rewinds vars/cons added after."""
        return (len(self.vars), len(self.cons))

    def pop(self, mark: Tuple[int, int]) -> None:
        n_vars, n_cons = mark
        del self.cons[n_cons:]
        if len(self.vars) > n_vars:
            for name in list(self.vars)[n_vars:]:
                del self.vars[name]
        if self._compiled is not None:
            self._compiled.truncate(n_vars, n_cons)

    # -- solving -----------------------------------------------------------
    def _order(self) -> List[str]:
        return list(self.vars)

    def solve_min(self, objective: Affine, want=None) -> Optional[tuple[Fraction, Dict[str, Fraction]]]:
        """Minimize one objective. Returns (value, solution) or None if
        infeasible. Raises Unbounded if unbounded.

        ``want`` (incremental highs path only): iterable of variable
        names to convert to exact Fractions in the returned solution, in
        addition to the objective's own variables — the float→Fraction
        snap of hundreds of Farkas multipliers per solve is pure waste
        for callers that only read schedule coefficients.  ``None``
        converts everything (the seed behaviour)."""
        if self.engine == "exact":
            return _exact_solve(self, objective)
        if self.incremental:
            return _highs_solve_compiled(self, objective, want)
        return _highs_solve(self, objective)

    def _objective_lower_bound(self, objective: Affine) -> Optional[Fraction]:
        """Lower bound of the objective implied by variable bounds alone,
        or None when some needed bound is missing (unbounded side)."""
        lb = objective.get(1, Fraction(0))
        for k, c in objective.items():
            if k == 1 or c == 0:
                continue
            v = self.vars[k]
            b = v.lb if c > 0 else v.ub
            if b is None:
                return None
            lb += c * b
        return lb

    # big-M weights above this are unsafe under HiGHS float tolerances
    _MAX_COMBINE_WEIGHT = 10 ** 6

    def _stage_box(self, obj: Affine) -> Tuple[Fraction, Fraction]:
        """(min, max) of obj over the variable boxes (vars box-bounded)."""
        lo = hi = obj.get(1, Fraction(0))
        for k, c in obj.items():
            if k == 1 or c == 0:
                continue
            v = self.vars[k]
            lo += c * (v.lb if c > 0 else v.ub)
            hi += c * (v.ub if c > 0 else v.lb)
        return lo, hi

    def _combine_tail(self, objectives: Sequence[Affine]):
        """Split the stage list into ``(head, combined, suffix)``: the
        maximal safe suffix collapsed into one exact weighted objective
        (``combined`` is None and ``suffix`` empty when nothing combines;
        ``suffix`` keeps the original stages as the fallback plan).

        Valid whenever every combined stage is integer-valued (integer
        coefficients over integer variables) with finite variable boxes:
        with W > (box range of the lower-priority remainder), minimizing
        W·f + g forces f to its lexicographic optimum exactly, because f
        moves in integer steps.  The scheduler's canonical tail
        (Σ T_par, Σ T_it, weighted order, Σ T_cst) — typically 4 MILP
        solves per lexmin — becomes a single solve.  Weights are capped
        so float objectives stay well inside HiGHS tolerances."""
        def combinable(obj: Affine) -> bool:
            for k, c in obj.items():
                if k == 1 or c == 0:
                    continue
                if c.denominator != 1:
                    return False
                v = self.vars[k]
                if (not v.integer or v.lb is None or v.ub is None
                        or v.lb.denominator != 1 or v.ub.denominator != 1):
                    return False
            return True

        n = len(objectives)
        if n < 2 or not combinable(objectives[-1]):
            return list(objectives), None, []
        combined = dict(objectives[-1])
        clo, chi = self._stage_box(combined)
        first = n - 1                      # index of first absorbed stage
        while first > 0 and combinable(objectives[first - 1]):
            w = chi - clo + 1
            if w > self._MAX_COMBINE_WEIGHT:
                break
            stage = objectives[first - 1]
            slo, shi = self._stage_box(stage)
            for k, c in stage.items():
                combined[k] = combined.get(k, Fraction(0)) + w * c
            clo, chi = w * slo + clo, w * shi + chi
            first -= 1
        if first == n - 1:
            return list(objectives), None, []
        return (list(objectives[:first]), combined,
                [dict(o) for o in objectives[first:]])

    def lexmin(self, objectives: Sequence[Affine], want=None) -> Optional[Dict[str, Fraction]]:
        """Lexicographic minimization: minimize objectives[0], fix its
        value, then objectives[1], ... Returns the final solution.

        Incremental mode appends one fixing row per stage to the live
        model (rewound on exit) instead of cloning; box-bounded integer
        suffix stages are collapsed into one weighted solve; a stage
        whose previous-stage solution already attains the bound-implied
        optimum is skipped outright (see module docstring).  ``want``
        limits exact solution conversion as in :meth:`solve_min` (every
        stage objective's variables are converted regardless)."""
        if not self.incremental:
            return self._lexmin_cloned(objectives)
        if not objectives:
            objectives = [{}]
        head, combined, suffix = self._combine_tail(objectives)
        if want is not None:
            want = set(want)
            for obj in objectives:
                want.update(k for k in obj if k != 1)
        mark = self.push()
        try:
            self.stages_skipped = 0
            sol, ok = self._run_stages(head, None, want)
            if not ok:
                return None
            if combined is not None:
                try:
                    sol, ok = self._run_stages([combined], sol, want,
                                               raise_trouble=True)
                except NumericalTrouble:
                    # HiGHS choked on the big-M objective: solve the
                    # original suffix stage by stage instead
                    sol, ok = self._run_stages(suffix, sol, want)
                if not ok:
                    return None
            return sol
        finally:
            self.pop(mark)

    def _run_stages(self, objs, sol, want, raise_trouble: bool = False):
        """Run lexicographic stages on the live model, appending one
        fixing row per stage.  Returns (solution, feasible)."""
        for obj in objs:
            val: Optional[Fraction] = None
            if sol is not None:
                bound = self._objective_lower_bound(obj)
                if bound is not None:
                    cur = obj.get(1, Fraction(0))
                    for k, c in obj.items():
                        if k != 1:
                            cur += c * sol[k]
                    if cur == bound:
                        val = cur   # provably optimal: skip the solve
                        self.stages_skipped += 1
            if val is None:
                if raise_trouble and self.engine != "exact":
                    res = _highs_solve_compiled(self, obj, want,
                                                on_trouble="raise")
                else:
                    res = self.solve_min(obj, want)
                if res is None and sol is not None:
                    # a later lexmin stage can never be infeasible: the
                    # previous stage's optimum satisfies its own fixing
                    # row.  This is HiGHS mis-reporting infeasibility —
                    # keep the incumbent and pin the stage at the value
                    # it attains: legal and deterministic (at worst
                    # suboptimal in lower-priority stages; an exact
                    # re-solve here costs minutes on large kernels).
                    val = obj.get(1, Fraction(0))
                    for k, c in obj.items():
                        if k != 1:
                            val += c * sol[k]
                elif res is None:
                    return None, False
                else:
                    val, sol = res
            # fix this objective at its optimum before the next stage.
            # obj ≤ val (with obj ≥ val implied by optimality) — the
            # one-sided form is equivalent to the seed's equality row but
            # measurably gentler on HiGHS: the equality chains it builds
            # can make HiGHS mis-report optimality/infeasibility (see
            # check_solution), the inequality form does not.
            fixed = {k: -c for k, c in obj.items()}
            fixed[1] = fixed.get(1, Fraction(0)) + val
            self.add(fixed, ">=0")
        return sol, True

    def _lexmin_cloned(self, objectives: Sequence[Affine]) -> Optional[Dict[str, Fraction]]:
        """The seed clone-per-lexmin path (kept for benchmarking).

        Fixing rows use the same one-sided ``obj <= val`` form as the
        incremental path (``obj >= val`` is implied by optimality): the
        seed's equality chains could push HiGHS MIP into mis-reported
        optimality/infeasibility on later stages — the source of the
        5/140 kernel×strategy divergences noted in ROADMAP.md."""
        prob = self.clone()
        sol: Optional[Dict[str, Fraction]] = None
        if not objectives:
            objectives = [{}]
        for i, obj in enumerate(objectives):
            res = prob.solve_min(obj)
            if res is None and sol is not None:
                # later stages cannot be infeasible (the previous optimum
                # satisfies its fixing row): HiGHS mis-report — keep the
                # incumbent, pin the stage at the value it attains (same
                # recovery as the incremental path's _run_stages)
                val = obj.get(1, Fraction(0))
                for k, c in obj.items():
                    if k != 1:
                        val += c * sol[k]
            elif res is None:
                return None
            else:
                val, sol = res
            fixed = {k: -c for k, c in obj.items()}
            fixed[1] = fixed.get(1, Fraction(0)) + val
            prob.add(fixed, ">=0")
        return sol

    def feasible(self) -> bool:
        return self.solve_min({}, want=()) is not None


class Unbounded(Exception):
    pass


# ---------------------------------------------------------------------------
# HiGHS engine (scipy)
# ---------------------------------------------------------------------------

def _highs_solve(prob: ILPProblem, objective: Affine):
    import numpy as np
    from scipy.optimize import linprog

    names = prob._order()
    idx = {n: i for i, n in enumerate(names)}
    n = len(names)
    c = np.zeros(n)
    for k, v in objective.items():
        if k != 1:
            c[idx[k]] = float(v)
    a_ub, b_ub, a_eq, b_eq = [], [], [], []
    for expr, kind in prob.cons:
        row = np.zeros(n)
        for k, v in expr.items():
            if k != 1:
                row[idx[k]] = float(v)
        const = float(expr.get(1, 0))
        if kind == ">=0":  # row·x + const >= 0  →  -row·x <= const
            a_ub.append(-row)
            b_ub.append(const)
        else:
            a_eq.append(row)
            b_eq.append(-const)
    bounds = []
    integrality = np.zeros(n)
    for i, name in enumerate(names):
        v = prob.vars[name]
        bounds.append(
            (None if v.lb is None else float(v.lb), None if v.ub is None else float(v.ub))
        )
        integrality[i] = 1 if v.integer else 0
    res = linprog(
        c,
        A_ub=np.array(a_ub) if a_ub else None,
        b_ub=np.array(b_ub) if b_ub else None,
        A_eq=np.array(a_eq) if a_eq else None,
        b_eq=np.array(b_eq) if b_eq else None,
        bounds=bounds,
        integrality=integrality if integrality.any() else None,
        method="highs",
    )
    if res.status == 2:  # infeasible
        return None
    if res.status == 3:
        raise Unbounded(str(objective))
    if not res.success or not _seed_point_valid(prob, names, res.x):
        # numerical trouble (or HiGHS MIP reporting an infeasible point
        # as optimal — same failure mode the incremental path validates
        # against in CompiledProblem.check_solution): exact engine
        return _exact_solve(prob, objective)
    sol: Dict[str, Fraction] = {}
    for i, name in enumerate(names):
        x = res.x[i]
        if prob.vars[name].integer:
            sol[name] = Fraction(round(x))
        else:
            sol[name] = Fraction(x).limit_denominator(10**9)
    val = Fraction(0)
    for k, v in objective.items():
        val += v if k == 1 else v * sol[k]
    return val, sol


def _seed_point_valid(prob: ILPProblem, names, x, tol: float = 1e-6) -> bool:
    """Float-level validation of a solver point for the seed
    (non-compiled) path — the twin of CompiledProblem.check_solution:
    constraint residuals, variable bounds, and integrality."""
    idx = {n: i for i, n in enumerate(names)}
    for expr, kind in prob.cons:
        v = float(expr.get(1, 0))
        scale = 1.0 + abs(v)
        for k, c in expr.items():
            if k != 1:
                v += float(c) * x[idx[k]]
        if kind == ">=0" and v < -tol * scale:
            return False
        if kind == "==0" and abs(v) > tol * scale:
            return False
    for i, name in enumerate(names):
        var = prob.vars[name]
        if var.lb is not None and x[i] < float(var.lb) - tol:
            return False
        if var.ub is not None and x[i] > float(var.ub) + tol:
            return False
        if var.integer and abs(x[i] - round(x[i])) > 1e-5:
            return False
    return True


class NumericalTrouble(Exception):
    """HiGHS reported success but the point fails validation (or reported
    a non-status error). Raised only when the caller asked to handle the
    retry itself (``on_trouble='raise'``)."""


def _highs_solve_compiled(prob: ILPProblem, objective: Affine, want=None,
                          on_trouble: str = "exact"):
    """Incremental-path twin of :func:`_highs_solve`: same status
    handling and exact solution snapping, but the constraint matrices
    come from the cached :class:`CompiledProblem` arrays and only the
    requested variables (``want`` + objective vars; None = all) are
    converted to Fractions.  Every accepted point is validated against
    the compiled system; invalid points go to the exact engine (seed
    semantics) or raise :class:`NumericalTrouble` (``on_trouble='raise'``)."""
    comp = prob._compile()
    res = comp.linprog(objective)
    if res.status == 2:  # infeasible
        return None
    if res.status == 3:
        raise Unbounded(str(objective))
    if not res.success or not comp.check_solution(res.x):
        # numerical trouble: retry with exact engine
        if on_trouble == "raise":
            raise NumericalTrouble(str(objective))
        return _exact_solve(prob, objective)
    if want is None:
        names = comp.names
    else:
        names = {k for k in objective if k != 1}
        names.update(k for k in want if k in comp.idx)
    sol: Dict[str, Fraction] = {}
    idx = comp.idx
    for name in names:
        x = res.x[idx[name]]
        if prob.vars[name].integer:
            sol[name] = Fraction(round(x))
        else:
            sol[name] = Fraction(x).limit_denominator(10**9)
    val = Fraction(0)
    for k, v in objective.items():
        val += v if k == 1 else v * sol[k]
    return val, sol


# ---------------------------------------------------------------------------
# Exact engine: two-phase rational simplex + branch & bound
# ---------------------------------------------------------------------------

def _exact_solve(prob: ILPProblem, objective: Affine):
    names = prob._order()
    return _branch_and_bound(prob, names, objective, [])


def _branch_and_bound(prob, names, objective, extra):
    lp = _ExactLP.from_problem(prob, names, objective, extra)
    r = lp.solve()
    if r is None:
        return None
    val, sol = r
    # find fractional integer var
    frac_var = None
    for name in names:
        if prob.vars[name].integer and sol[name].denominator != 1:
            frac_var = name
            break
    if frac_var is None:
        return val, sol
    x = sol[frac_var]
    floor_v = x.numerator // x.denominator
    best = None
    for lo_hi in ("le", "ge"):
        if lo_hi == "le":
            con = ({frac_var: Fraction(-1), 1: Fraction(floor_v)}, ">=0")
        else:
            con = ({frac_var: Fraction(1), 1: Fraction(-(floor_v + 1))}, ">=0")
        sub = _branch_and_bound(prob, names, objective, extra + [con])
        if sub is not None and (best is None or sub[0] < best[0]):
            best = sub
    return best


class _ExactLP:
    """min c·x s.t. Ax = b, x >= 0 — two-phase simplex, Bland's rule.

    General bounds/frees are handled by shifting and splitting at
    construction time.
    """

    def __init__(self, a: List[List[Fraction]], b: List[Fraction], c: List[Fraction]):
        self.a, self.b, self.c = a, b, c

    @classmethod
    def from_problem(cls, prob: ILPProblem, names, objective, extra=()):  # noqa: C901
        # variable mapping: each model var -> expression over nonneg simplex vars
        cols: List[str] = []          # simplex column names
        expr_of: Dict[str, Dict[str, Fraction]] = {}  # model var -> {col: coeff} + const
        const_of: Dict[str, Fraction] = {}
        for name in names:
            v = prob.vars[name]
            if v.lb is not None:
                col = f"x:{name}"
                cols.append(col)
                expr_of[name] = {col: Fraction(1)}
                const_of[name] = v.lb
            else:
                cp, cn = f"xp:{name}", f"xn:{name}"
                cols.extend([cp, cn])
                expr_of[name] = {cp: Fraction(1), cn: Fraction(-1)}
                const_of[name] = Fraction(0)
        rows: List[tuple[Dict[str, Fraction], str, Fraction]] = []

        def add_row(expr: Affine, kind: str):
            row: Dict[str, Fraction] = {}
            const = expr.get(1, Fraction(0))
            for k, coef in expr.items():
                if k == 1:
                    continue
                const += coef * const_of[k]
                for col, cc in expr_of[k].items():
                    row[col] = row.get(col, Fraction(0)) + coef * cc
            rows.append((row, kind, const))

        for expr, kind in list(prob.cons) + list(extra):
            add_row(expr, kind)
        for name in names:
            v = prob.vars[name]
            if v.ub is not None:
                add_row({name: Fraction(-1), 1: v.ub}, ">=0")

        # to standard form Ax = b, x >= 0 with slacks
        ncols = {c: i for i, c in enumerate(cols)}
        nslack = sum(1 for _, kind, _ in rows if kind == ">=0")
        width = len(cols) + nslack
        a: List[List[Fraction]] = []
        b: List[Fraction] = []
        slack_i = 0
        for row, kind, const in rows:
            r = [Fraction(0)] * width
            for col, cc in row.items():
                r[ncols[col]] = cc
            if kind == ">=0":  # r·x + const >= 0 → r·x - s = -const
                r[len(cols) + slack_i] = Fraction(-1)
                slack_i += 1
            a.append(r)
            b.append(-const)
        # objective over simplex columns
        c_vec = [Fraction(0)] * width
        obj_const = objective.get(1, Fraction(0))
        for k, coef in objective.items():
            if k == 1:
                continue
            obj_const += coef * const_of[k]
            for col, cc in expr_of[k].items():
                c_vec[ncols[col]] += coef * cc
        lp = cls(a, b, c_vec)
        lp._cols = cols
        lp._width = width
        lp._expr_of = expr_of
        lp._const_of = const_of
        lp._names = names
        lp._obj_const = obj_const
        lp._prob = prob
        return lp

    def solve(self):
        a = [row[:] for row in self.a]
        b = self.b[:]
        m = len(a)
        if m == 0:
            names = self._names
            sol = {n: self._const_of[n] for n in names}
            return self._obj_const, sol
        width = len(a[0])
        # make b >= 0
        for i in range(m):
            if b[i] < 0:
                a[i] = [-x for x in a[i]]
                b[i] = -b[i]
        # phase 1: artificials
        for i in range(m):
            for j in range(m):
                a[i].append(Fraction(1) if i == j else Fraction(0))
        basis = list(range(width, width + m))
        cost1 = [Fraction(0)] * width + [Fraction(1)] * m
        val = self._simplex(a, b, cost1, basis)
        if val is None or val > 0:
            return None
        # drive artificials out of basis if possible
        for i in range(m):
            if basis[i] >= width:
                piv = None
                for j in range(width):
                    if a[i][j] != 0:
                        piv = j
                        break
                if piv is not None:
                    self._pivot(a, b, basis, i, piv)
        # drop artificial columns & redundant rows
        keep = [i for i in range(m) if basis[i] < width]
        a = [a[i][:width] for i in keep]
        b = [b[i] for i in keep]
        basis = [basis[i] for i in keep]
        cost2 = self.c[:width]
        val = self._simplex(a, b, cost2, basis)
        if val is None:
            raise Unbounded("exact LP unbounded")
        x = [Fraction(0)] * width
        for i, bi in enumerate(basis):
            x[bi] = b[i]
        sol: Dict[str, Fraction] = {}
        ncols = {c: i for i, c in enumerate(self._cols)}
        for name in self._names:
            v = self._const_of[name]
            for col, cc in self._expr_of[name].items():
                v += cc * x[ncols[col]]
            sol[name] = v
        obj = Fraction(0)
        for i in range(min(width, len(self.c))):
            obj += self.c[i] * x[i]
        return obj + self._obj_const, sol

    @staticmethod
    def _pivot(a, b, basis, r, c):
        m, n = len(a), len(a[0])
        pv = a[r][c]
        a[r] = [x / pv for x in a[r]]
        b[r] = b[r] / pv
        for i in range(m):
            if i != r and a[i][c] != 0:
                f = a[i][c]
                a[i] = [x - f * y for x, y in zip(a[i], a[r])]
                b[i] = b[i] - f * b[r]
        basis[r] = c

    @classmethod
    def _simplex(cls, a, b, cost, basis):
        """Min cost·x. Returns objective value, or None if unbounded is
        signalled via exception by caller convention (phase2)."""
        m = len(a)
        n = len(a[0]) if m else 0
        while True:
            # reduced costs: z_j - c_j
            y = {}
            red = [Fraction(0)] * n
            cb = [cost[basis[i]] if basis[i] < len(cost) else Fraction(0) for i in range(m)]
            for j in range(n):
                zj = Fraction(0)
                for i in range(m):
                    if a[i][j] != 0 and cb[i] != 0:
                        zj += cb[i] * a[i][j]
                red[j] = (cost[j] if j < len(cost) else Fraction(0)) - zj
            enter = None
            for j in range(n):  # Bland: first negative reduced cost
                if red[j] < 0 and j not in basis:
                    enter = j
                    break
            if enter is None:
                val = Fraction(0)
                for i in range(m):
                    val += cb[i] * b[i]
                return val
            # ratio test (Bland: smallest index on ties)
            leave = None
            best = None
            for i in range(m):
                if a[i][enter] > 0:
                    ratio = b[i] / a[i][enter]
                    if best is None or ratio < best or (ratio == best and basis[i] < basis[leave]):
                        best = ratio
                        leave = i
            if leave is None:
                return None  # unbounded
            cls._pivot(a, b, basis, leave, enter)
