"""Post-processing: tiling and wavefront skewing (paper Fig. 1, §III).

Per the paper, *no tile-size decision* happens in the core scheduler —
sizes are provided externally. Tiling applies to maximal runs of linear
dimensions sharing a band id (those are fully permutable by
construction: every active dependence was weakly enforced at each dim of
the band). Each tiled dim φ gets a tile counter y with
``T·y ≤ φ ≤ T·y + T − 1`` — an inequality-defined scan dimension that
flows through the same Fourier–Motzkin codegen machinery.

Wavefront skewing (for pipelined parallelism on bands whose first dim
carries dependences) replaces the first two tile counters (t0, t1) by
(t0 + t1, t1): the new outer wave dimension is sequential while t1
becomes parallel within a wave.
"""
from __future__ import annotations

from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, List, Sequence, Tuple

from .schedtree import DimSpec, ScanStmt, scan_from_schedule, yvar as _yvar
from .scheduler import Schedule


@dataclass
class Band:
    start: int            # first schedule dim of the band (linear run)
    length: int
    parallel_first: bool  # first dim already parallel → no wavefront needed


def find_tilable_bands(sched: Schedule, min_len: int = 2) -> List[Band]:
    """Maximal runs of linear dims with equal band id (≥ min_len)."""
    bands: List[Band] = []
    d = 0
    n = sched.n_dims
    # a dim is 'linear' if any statement has a non-constant row there
    def is_linear(dim: int) -> bool:
        for s in sched.scop.statements:
            row = sched.rows[s.index][dim]
            if row.kind == "linear" and any(
                k[0] == "it" for k in row.coeffs
            ):
                return True
        return False

    while d < n:
        if not is_linear(d):
            d += 1
            continue
        start = d
        bid = sched.bands[d]
        while d < n and sched.bands[d] == bid and is_linear(d):
            d += 1
        if d - start >= min_len:
            bands.append(Band(start, d - start, sched.parallel[start]))
    return bands


def tile_schedule(
    sched: Schedule,
    tile_sizes: Dict[int, Sequence[int]] | Sequence[int] | int | str = 32,
    wavefront: bool = False,
    min_band: int = 2,
) -> List[ScanStmt]:
    """Build codegen scan specs with tile dimensions inserted.

    tile_sizes: int (uniform), list (per band-dim), {band_start: [..]},
    or a cache-model level: ``"l1"`` / ``"l2"`` / ``"auto"`` (= l2) pick
    per-band per-dim sizes from the SCoP's access functions so the tile
    working set fits that cache (see :mod:`repro.core.cachemodel`).
    """
    scan = scan_from_schedule(sched)
    bands = find_tilable_bands(sched, min_band)
    if not bands:
        return scan
    if isinstance(tile_sizes, str):
        from .cachemodel import auto_tile_sizes
        tile_sizes = auto_tile_sizes(
            sched, level="l2" if tile_sizes == "auto" else tile_sizes,
            bands=bands)

    def sizes_for(band: Band) -> List[int]:
        if isinstance(tile_sizes, int):
            return [tile_sizes] * band.length
        if isinstance(tile_sizes, dict):
            ts = tile_sizes.get(band.start)
            if ts is None:
                return [32] * band.length
            return list(ts) + [ts[-1]] * (band.length - len(ts))
        return list(tile_sizes)[: band.length] + [list(tile_sizes)[-1]] * max(
            0, band.length - len(tile_sizes)
        )

    for ss in scan:
        new_dims: List[DimSpec] = []
        d = 0
        nd = len(ss.dims)
        inserted: List[Tuple[int, Band]] = []   # (insert position, band)
        while d < nd:
            band = next((b for b in bands if b.start == d), None)
            if band is None:
                new_dims.append(ss.dims[d])
                d += 1
                continue
            sizes = sizes_for(band)
            pos = len(new_dims)
            for k in range(band.length):
                spec = ss.dims[band.start + k]
                new_dims.append(
                    DimSpec("tile", dict(spec.phi), tile=sizes[k],
                            sched_dim=band.start, role="tile")
                )
            for k in range(band.length):
                new_dims.append(ss.dims[band.start + k])
            inserted.append((pos, band))
            d += band.length
        if wavefront:
            # outermost-first; each insertion shifts deeper y references
            for i, (pos, band) in enumerate(inserted):
                if band.length >= 2 and not band.parallel_first:
                    _insert_wavefront(new_dims, pos)
                    inserted[i + 1:] = [(p + 1, b) for p, b in inserted[i + 1:]]
        ss.dims = new_dims
    return scan


def _insert_wavefront(dims: List[DimSpec], pos: int) -> None:
    """Insert y_pos == y_{pos+1} + y_{pos+2} before the two tile dims at
    ``pos``. Any existing dim phi referencing y variables with index ≥ pos
    is renumbered (+1)."""
    for spec in dims:
        shifted = {}
        for k, v in spec.phi.items():
            if isinstance(k, str) and k.startswith("y_") and k[2:].isdigit() and int(k[2:]) >= pos:
                shifted[_yvar(int(k[2:]) + 1)] = v
            else:
                shifted[k] = v
        spec.phi = shifted
    wave_phi = {_yvar(pos + 1): Fraction(1), _yvar(pos + 2): Fraction(1)}
    dims.insert(pos, DimSpec("eq", wave_phi, sched_dim=dims[pos].sched_dim,
                             role="wave"))
    # the first tile counter inside the wave spans the wavefront (the
    # second is pinned by the equality): mark it parallel for the shared
    # level_parallel marking (legal by band permutability)
    dims[pos + 1].role = "wave_par"
